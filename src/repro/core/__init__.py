"""The paper's primary contribution, as a composable module.

"Towards an Arrow-native Storage System" contributes a *design paradigm*:
embed the stock data-access library into a programmable object store so
that dataset scanning (decode + filter + project) can execute at either
placement behind one API.  The pieces:

  ObjectStore / ObjectHandle   programmable store + RandomAccessObject
  register_default_classes     the ObjectClass SDK methods (scan_op, ...)
  CephFS / DirectObjectAccess  POSIX shim + filename->object translation
  write_striped / write_split / write_flat   self-contained-fragment layouts
  dataset / Query / Scanner    the Dataset API (lazy query plans)
  ParquetFormat                client-side scan      (their baseline)
  PushdownParquetFormat        storage-side scan     (their RADOS Parquet)
  AdaptiveFormat / ScanScheduler   runtime placement from live OSD load,
                               with hedged scans + a columnar result cache

``make_cluster`` assembles the standard stack used by the examples, tests
and benchmarks.
"""

from __future__ import annotations

from repro.dataset import (AdaptiveFormat, AggSpec, CommitConflict, Dataset,
                           MutableDataset, ParquetFormat,
                           PushdownParquetFormat, Query, ScanScheduler,
                           Scanner, Shed, TaskContext, TenantRegistry,
                           TenantSpec, dataset)
from repro.storage.cephfs import CephFS, DirectObjectAccess
from repro.storage.layouts import write_flat, write_split, write_striped
from repro.storage.objclass import register_default_classes
from repro.storage.objstore import ObjectStore


def make_cluster(num_osds: int = 8, *, replication: int = 3,
                 threads_per_osd: int = 8) -> CephFS:
    """ObjectStore + default object classes + CephFS, ready to use."""
    store = ObjectStore(num_osds, replication=replication,
                        threads_per_osd=threads_per_osd)
    register_default_classes(store)
    return CephFS(store)


__all__ = ["AggSpec", "Dataset", "MutableDataset", "CommitConflict",
           "ParquetFormat", "PushdownParquetFormat", "AdaptiveFormat",
           "Query", "ScanScheduler", "Scanner", "dataset", "CephFS",
           "DirectObjectAccess", "write_flat", "write_split",
           "write_striped", "register_default_classes", "ObjectStore",
           "make_cluster",
           "Shed", "TaskContext", "TenantRegistry", "TenantSpec"]
