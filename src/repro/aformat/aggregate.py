"""Partial aggregation — the storage-side SUM/MIN/MAX/MEAN/COUNT engine.

The paper's pushdown ships *filtered columns*; an aggregate only needs a
few numbers, so shipping columns wastes exactly the wire and client CPU
the paper targets.  This module is the placement-agnostic kernel both
sides run (the same-code-at-both-placements principle of ``scan_op``):

``AggSpec``
    One aggregate: ``(op, column)`` with op in sum/min/max/mean/count
    (``column=None`` means COUNT(*)).

``partial_aggregate(table, specs, group_by=...)``
    Fold a decoded fragment into an :class:`AggState` — optionally hash
    group-by over one key column.  Storage nodes pass ``max_groups``: a
    fragment whose key cardinality exceeds the bound raises
    :class:`CardinalityError` and the caller falls back to a scan (the
    spill-to-scan path), so a hostile key can never balloon the node's
    memory or the wire payload.

``AggState.merge``
    Associative, commutative-up-to-float-rounding combination of partial
    states: count/sum add, min/max compare, mean carries (sum, count).
    Integer sums are carried as exact Python ints, so any merge order
    yields the same result for count/min/max/sum-of-int/mean-of-int;
    float sums can differ in the last ulp across merge orders (inherent
    to float addition, same as any parallel aggregation engine).

``partial_from_stats``
    The zero-I/O path: ungrouped, predicate-free count/min/max are
    provable from footer statistics alone, so those fragments never touch
    storage at all.  Float min/max is excluded — footer stats skip
    non-finite values, so they cannot speak for a column that may hold
    ±inf.

``AggState.finalize(schema)``
    Produce the result Table: one row (ungrouped) or one row per group,
    sorted by key for determinism.  Empty input follows NumPy: sum=0,
    count=0, mean/min/max are null.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Sequence

import numpy as np

from repro.aformat.schema import Field, Schema
from repro.aformat.statistics import ColumnStats
from repro.aformat.table import Column, Table

AGG_OPS = ("sum", "min", "max", "mean", "count")

#: Default storage-side group-cardinality bound (spill-to-scan past it).
DEFAULT_MAX_GROUPS = 4096

_INT_TYPES = ("int32", "int64", "bool")


class CardinalityError(ValueError):
    """Group-by key cardinality exceeded the storage-side bound."""


@dataclasses.dataclass(frozen=True)
class AggSpec:
    """One aggregate: op in sum/min/max/mean/count; column=None => rows."""

    op: str
    column: str | None = None

    def __post_init__(self):
        if self.op not in AGG_OPS:
            raise ValueError(f"unsupported aggregate op {self.op!r}")
        if self.column is None and self.op != "count":
            raise ValueError(f"{self.op} requires a column")

    @property
    def name(self) -> str:
        return f"{self.op}_{self.column}" if self.column else "count"

    def to_json(self) -> dict:
        return {"op": self.op, "column": self.column}

    @staticmethod
    def from_json(d: dict) -> "AggSpec":
        return AggSpec(d["op"], d.get("column"))


def parse_aggs(aggs) -> list[AggSpec]:
    """Normalize user input: AggSpec | (op, column) | "op(column)"."""
    out: list[AggSpec] = []
    for a in aggs:
        if isinstance(a, AggSpec):
            out.append(a)
        elif isinstance(a, str):
            if "(" in a:
                op, col = a.rstrip(")").split("(", 1)
                col = col.strip()
                out.append(AggSpec(op.strip(),
                                   None if col in ("", "*") else col))
            else:
                out.append(AggSpec(a.strip()))
        else:
            op, col = a
            out.append(AggSpec(op, col))
    return out


def needed_columns(specs: Sequence[AggSpec], group_by: str | None,
                   schema: Schema, predicate=None) -> list[str]:
    """Columns a fragment scan must decode to answer these aggregates —
    in schema order.  A pure COUNT(*) needs one column only to carry the
    row count: a predicate column if filtering, else the narrowest-by-
    position first field."""
    names = {s.column for s in specs if s.column is not None}
    if group_by is not None:
        names.add(group_by)
    if not names:
        if predicate is not None:
            names.add(sorted(predicate.columns())[0])
        else:
            names.add(schema.names[0])
    return sorted(names, key=schema.index)


# ---------------------------------------------------------------------------
# Partial cells: JSON-native per-aggregate accumulators
#   count -> int;  sum -> int|float;  min/max -> scalar|None (no rows);
#   mean -> [sum, count]
# ---------------------------------------------------------------------------


def _identity(spec: AggSpec):
    if spec.op == "count":
        return 0
    if spec.op == "sum":
        return 0
    if spec.op == "mean":
        return [0, 0]
    return None                       # min/max over zero rows


def _merge_cell(spec: AggSpec, a, b):
    if spec.op in ("count", "sum"):
        return a + b
    if spec.op == "mean":
        return [a[0] + b[0], a[1] + b[1]]
    if a is None:
        return b
    if b is None:
        return a
    return min(a, b) if spec.op == "min" else max(a, b)


def _py(v):
    """numpy scalar -> exact JSON-able Python scalar."""
    if isinstance(v, np.generic):
        return v.item()
    return v


def _sum_scalar(vals: np.ndarray, field_type: str):
    """Exact sums: integer columns accumulate into Python int (no float
    rounding, so merge order can never change the result)."""
    if len(vals) == 0:
        return 0
    if field_type in _INT_TYPES:
        return int(np.sum(vals, dtype=np.int64))
    return float(np.sum(vals))


def _cell_from_values(spec: AggSpec, vals: np.ndarray, field_type: str):
    """One partial cell from the *valid* values of one column."""
    if spec.op == "count":
        return int(len(vals))
    if field_type == "string" and spec.op not in ("min", "max"):
        raise TypeError(f"{spec.op} over string column {spec.column!r}")
    if spec.op == "sum":
        return _sum_scalar(vals, field_type)
    if spec.op == "mean":
        return [_sum_scalar(vals, field_type), int(len(vals))]
    if len(vals) == 0:
        return None
    if field_type == "string":
        svals = [str(v) for v in vals]
        return min(svals) if spec.op == "min" else max(svals)
    return _py(vals.min() if spec.op == "min" else vals.max())


class AggState:
    """Mergeable partial-aggregate state (the agg_op wire payload).

    Ungrouped: ``cells`` is one accumulator per spec.  Grouped:
    ``groups`` maps key -> accumulator list.  ``rows`` counts the input
    rows folded in (post-predicate) — the accounting figure TaskRecords
    report."""

    def __init__(self, specs: Sequence[AggSpec], group_by: str | None, *,
                 cells: list | None = None,
                 groups: dict | None = None, rows: int = 0):
        self.specs = list(specs)
        self.group_by = group_by
        if group_by is None:
            self.cells = cells if cells is not None else \
                [_identity(s) for s in self.specs]
            self.groups = None
        else:
            self.cells = None
            self.groups = groups if groups is not None else {}
        self.rows = rows

    @staticmethod
    def empty(specs: Sequence[AggSpec],
              group_by: str | None) -> "AggState":
        return AggState(specs, group_by)

    def merge(self, other: "AggState") -> "AggState":
        """Associative in-place combine; returns self."""
        if (len(other.specs) != len(self.specs)
                or other.group_by != self.group_by):
            raise ValueError("merging incompatible aggregate states")
        if self.group_by is None:
            self.cells = [_merge_cell(s, a, b) for s, a, b in
                          zip(self.specs, self.cells, other.cells)]
        else:
            for key, cells in other.groups.items():
                mine = self.groups.get(key)
                if mine is None:
                    self.groups[key] = list(cells)
                else:
                    self.groups[key] = [
                        _merge_cell(s, a, b)
                        for s, a, b in zip(self.specs, mine, cells)]
        self.rows += other.rows
        return self

    @property
    def num_groups(self) -> int:
        return len(self.groups) if self.groups is not None else 0

    # -- wire format ---------------------------------------------------------
    def serialize(self) -> bytes:
        body: dict = {"aggs": [s.to_json() for s in self.specs],
                      "group_by": self.group_by, "rows": self.rows}
        if self.group_by is None:
            body["cells"] = self.cells
        else:
            body["groups"] = [[k, c] for k, c in self.groups.items()]
        return json.dumps(body, separators=(",", ":")).encode()

    @staticmethod
    def deserialize(raw: bytes) -> "AggState":
        d = json.loads(raw)
        specs = [AggSpec.from_json(s) for s in d["aggs"]]
        if d["group_by"] is None:
            return AggState(specs, None, cells=d["cells"], rows=d["rows"])
        groups = {_group_key(k): c for k, c in d["groups"]}
        return AggState(specs, d["group_by"], groups=groups,
                        rows=d["rows"])

    # -- result --------------------------------------------------------------
    def finalize(self, schema: Schema) -> Table:
        """Materialize the merged state as a result Table."""
        fields = result_fields(self.specs, self.group_by, schema)
        if self.group_by is None:
            rows = [self.cells]
            keys = None
        else:
            keys = sorted(self.groups)      # deterministic output order
            rows = [self.groups[k] for k in keys]
        cols: list[Column] = []
        fi = 0
        if self.group_by is not None:
            cols.append(_key_column(fields[0], keys))
            fi = 1
        for j, spec in enumerate(self.specs):
            cols.append(_agg_column(fields[fi + j],
                                    [r[j] for r in rows], spec))
        return Table(Schema(tuple(fields)), cols)


def _group_key(k):
    """JSON round-trips group keys as-is except tuples; keys are scalars
    (int/float/str/bool) so identity is enough."""
    return k


def result_fields(specs: Sequence[AggSpec], group_by: str | None,
                  schema: Schema) -> list[Field]:
    fields: list[Field] = []
    if group_by is not None:
        src = schema.field(group_by)
        fields.append(Field(src.name, src.type))
    for s in specs:
        if s.op == "count":
            t = "int64"
        elif s.op == "mean":
            t = "float64"
        elif s.op == "sum":
            t = "int64" if schema.field(s.column).type in _INT_TYPES \
                else "float64"
        else:
            t = schema.field(s.column).type
        fields.append(Field(s.name, t, nullable=True))
    return fields


def _key_column(field: Field, keys: list) -> Column:
    if field.type == "string":
        return Column(field, np.asarray(keys, object))
    return Column(field, np.asarray(keys, field.numpy_dtype))


def _agg_column(field: Field, cells: list, spec: AggSpec) -> Column:
    n = len(cells)
    if spec.op == "mean":
        vals = np.empty(n, np.float64)
        valid = np.ones(n, "?")
        for i, (s, c) in enumerate(cells):
            if c:
                vals[i] = s / c
            else:
                vals[i], valid[i] = 0.0, False
        return Column(field, vals, valid)
    if spec.op in ("min", "max"):
        valid = np.asarray([c is not None for c in cells], "?")
        if field.type == "string":
            vals = np.asarray(["" if c is None else c for c in cells],
                              object)
        else:
            vals = np.asarray([0 if c is None else c for c in cells],
                              field.numpy_dtype)
        return Column(field, vals, valid)
    # count / sum: always defined (0 over zero rows, matching np.sum)
    return Column(field, np.asarray(cells, field.numpy_dtype))


# ---------------------------------------------------------------------------
# Folding a decoded table into partial state
# ---------------------------------------------------------------------------


def partial_aggregate(table: Table, specs: Sequence[AggSpec],
                      group_by: str | None = None,
                      max_groups: int | None = None) -> AggState:
    """Fold one (already filtered) table into an AggState.

    ``max_groups`` bounds grouped-key cardinality (storage-side callers);
    exceeding it raises :class:`CardinalityError` — the spill-to-scan
    signal.  Rows whose group key is null are dropped, mirroring SQL
    GROUP BY."""
    if group_by is None:
        cells = []
        for s in specs:
            if s.column is None:
                cells.append(int(len(table)))
                continue
            col = table.column(s.column)
            vals = col.values
            if col.validity is not None:
                vals = vals[col.validity]
            cells.append(_cell_from_values(s, vals, col.field.type))
        return AggState(specs, None, cells=cells, rows=len(table))

    key_col = table.column(group_by)
    if key_col.validity is not None:
        table = table.filter(key_col.validity)
        key_col = table.column(group_by)
    kvals = key_col.values
    if key_col.field.type == "string":
        kvals = np.asarray([str(v) for v in kvals], object)
    uniq, inv = np.unique(kvals, return_inverse=True)
    if max_groups is not None and len(uniq) > max_groups:
        raise CardinalityError(
            f"group-by {group_by!r}: {len(uniq)} groups exceed the "
            f"storage-side bound of {max_groups}")
    n_groups = len(uniq)
    per_spec = [_grouped_cells(table, s, inv, n_groups) for s in specs]
    groups = {_py(uniq[g]): [per_spec[j][g] for j in range(len(specs))]
              for g in range(n_groups)}
    return AggState(specs, group_by, groups=groups, rows=len(table))


def _grouped_cells(table: Table, spec: AggSpec, inv: np.ndarray,
                   n_groups: int) -> list:
    """Per-group partial cells for one aggregate over one fragment."""
    if spec.column is None:             # COUNT(*)
        return np.bincount(inv, minlength=n_groups).tolist()
    col = table.column(spec.column)
    vals, ginv = col.values, inv
    if col.validity is not None:
        vals, ginv = vals[col.validity], inv[col.validity]
    ftype = col.field.type
    if spec.op == "count":
        return np.bincount(ginv, minlength=n_groups).tolist()
    if ftype == "string" and spec.op not in ("min", "max"):
        raise TypeError(f"{spec.op} over string column {spec.column!r}")
    if spec.op in ("sum", "mean"):
        if ftype in _INT_TYPES:
            acc = np.zeros(n_groups, np.int64)
            np.add.at(acc, ginv, vals.astype(np.int64, copy=False))
            sums = [int(v) for v in acc]
        else:
            sums = np.bincount(ginv, weights=vals.astype(np.float64),
                               minlength=n_groups).tolist()
        if spec.op == "sum":
            return sums
        counts = np.bincount(ginv, minlength=n_groups)
        return [[s, int(c)] for s, c in zip(sums, counts)]
    # min/max: sort rows by group, slice per group (cardinality-bounded)
    order = np.argsort(ginv, kind="stable")
    sg, sv = ginv[order], vals[order]
    starts = np.searchsorted(sg, np.arange(n_groups), side="left")
    ends = np.searchsorted(sg, np.arange(n_groups), side="right")
    out = []
    for g in range(n_groups):
        if starts[g] == ends[g]:
            out.append(None)
        else:
            part = sv[starts[g]:ends[g]]
            if ftype == "string":
                svals = [str(v) for v in part]
                out.append(min(svals) if spec.op == "min" else max(svals))
            else:
                out.append(_py(part.min() if spec.op == "min"
                               else part.max()))
    return out


# ---------------------------------------------------------------------------
# Metadata-only answers from footer statistics
# ---------------------------------------------------------------------------


def stats_answerable(spec: AggSpec, schema: Schema) -> bool:
    """Can footer stats answer this aggregate exactly?  count always;
    min/max except over floats (footer stats skip non-finite values, so
    they cannot speak for a column that may hold ±inf); sum/mean never
    (stats carry no sums)."""
    if spec.op == "count":
        return True
    if spec.op in ("min", "max"):
        return schema.field(spec.column).type not in ("float32", "float64")
    return False


def partial_from_stats(specs: Sequence[AggSpec],
                       stats: Mapping[str, ColumnStats], num_rows: int,
                       schema: Schema) -> "AggState | None":
    """Build a fragment's partial state from footer stats alone (the
    zero-I/O path for ungrouped, predicate-free aggregates).  Returns
    None when any spec needs real data."""
    cells: list[Any] = []
    for s in specs:
        if not stats_answerable(s, schema):
            return None
        if s.column is None:
            cells.append(int(num_rows))
            continue
        st = stats.get(s.column)
        if st is None or st.count != num_rows:
            return None                 # stats absent or partial
        if s.op == "count":
            cells.append(int(st.count - st.null_count))
        else:
            # all-null chunk: min/max stats are None, and so is the cell
            cells.append(_py(st.min if s.op == "min" else st.max))
    return AggState(specs, None, cells=cells, rows=num_rows)
