"""Per-column-chunk statistics (Parquet footer analogue)."""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass
class ColumnStats:
    min: Any = None
    max: Any = None
    null_count: int = 0
    count: int = 0
    #: Optional physical-design index block for the same chunk
    #: (``repro.aformat.indexes.ColumnIndex``): attached by
    #: ``RowGroupMeta.column_stats`` so ``Expr.prune`` can upgrade a
    #: stats-SOME verdict to an index-proven NONE.  Never serialized
    #: here — the chunk footer entry owns the block.
    index: Any = dataclasses.field(default=None, compare=False, repr=False)

    def to_json(self):
        def py(v):
            return v.item() if isinstance(v, np.generic) else v

        return {"min": py(self.min), "max": py(self.max),
                "null_count": self.null_count, "count": self.count}

    @staticmethod
    def from_json(d):
        return ColumnStats(d["min"], d["max"], d["null_count"], d["count"])


def compute_stats(column) -> ColumnStats:
    vals = column.values
    validity = column.validity
    count = len(vals)
    if validity is not None:
        nulls = int(count - validity.sum())
        vals = vals[validity]
    else:
        nulls = 0
    if len(vals) == 0:
        return ColumnStats(None, None, nulls, count)
    if column.field.type == "string":
        svals = [str(v) for v in vals]
        return ColumnStats(min(svals), max(svals), nulls, count)
    if column.field.type in ("float32", "float64"):
        finite = vals[np.isfinite(vals)]
        if len(finite) == 0:
            return ColumnStats(None, None, nulls, count)
        return ColumnStats(finite.min(), finite.max(), nulls, count)
    return ColumnStats(vals.min(), vals.max(), nulls, count)
