"""Column-chunk encodings: PLAIN / DICTIONARY / RLE / DELTA / BITPACK.

Each encoder maps a values array -> list of raw buffers; the footer records
which encoding was used.  The *decode* cost of these encodings (plus the
codec) is exactly the client-CPU work the paper offloads to storage.

Hardware-adaptation note (DESIGN.md §2): DICTIONARY decode *is* wired to
the TPU — ``repro.aformat.decode.PallasBackend`` routes DICT chunks
through the ``repro.kernels`` gather kernel (with predicate fusion and
selection packing) whenever a scan runs with ``decode_backend="pallas"``.
The byte-stream pieces stay here on the host path by design: RLE run
expansion is variable-length sequential, and DELTA's int8 delta stream
plus the string offset/payload buffers are decoded faster on the host
than they could be staged onto an accelerator — the documented
non-transferable remainder the Pallas backend falls back to per column.
"""

from __future__ import annotations

import numpy as np

from repro.aformat.table import strings_from_buffers

PLAIN, DICT, RLE, DELTA, BITPACK = "plain", "dict", "rle", "delta", "bitpack"


def _string_buffers(values) -> list[bytes]:
    raw = [("" if v is None else str(v)).encode() for v in values]
    offsets = np.zeros(len(raw) + 1, np.int64)
    np.cumsum([len(r) for r in raw], out=offsets[1:])
    return [offsets.tobytes(), b"".join(raw)]


def _string_from_buffers(bufs, n):
    return strings_from_buffers(np.frombuffer(bufs[0], np.int64),
                                bufs[1], n)


def choose_encoding(field_type: str, values: np.ndarray) -> str:
    if field_type == "bool":
        return BITPACK
    if field_type == "string":
        uniq = len(set(map(str, values[:4096])))
        return DICT if uniq <= max(1, len(values) // 4) else PLAIN
    if field_type in ("int32", "int64"):
        sample = values[: min(len(values), 4096)]
        if len(sample) > 1:
            d = np.diff(sample)
            if len(d) and d.min() >= 0 and d.max() <= 127:
                return DELTA
            runs = int(np.count_nonzero(d)) + 1
            if runs <= len(sample) // 8:
                return RLE
        uniq = len(np.unique(sample))
        if uniq <= max(1, min(len(values) // 4, 60_000)):
            return DICT
        return PLAIN
    # floats: dictionary only when very low cardinality
    uniq = len(np.unique(values[: min(len(values), 4096)]))
    if uniq <= max(1, len(values) // 16):
        return DICT
    return PLAIN


def encode(field_type: str, encoding: str, values: np.ndarray) -> list[bytes]:
    if encoding == PLAIN:
        if field_type == "string":
            return _string_buffers(values)
        return [np.ascontiguousarray(values).tobytes()]
    if encoding == BITPACK:
        return [np.packbits(values.astype("?")).tobytes()]
    if encoding == DICT:
        if field_type == "string":
            svals = np.asarray([str(v) for v in values], object)
            uniq, inv = np.unique(svals.astype(str), return_inverse=True)
            return [inv.astype(np.int32).tobytes(),
                    *_string_buffers(uniq.astype(object))]
        uniq, inv = np.unique(values, return_inverse=True)
        return [inv.astype(np.int32).tobytes(),
                np.ascontiguousarray(uniq).tobytes()]
    if encoding == DELTA:
        base = values[:1].astype(np.int64)
        deltas = np.diff(values.astype(np.int64))
        if len(deltas) and (deltas.min() < -128 or deltas.max() > 127):
            raise ValueError("delta overflow; caller should fall back")
        return [base.tobytes(), deltas.astype(np.int8).tobytes()]
    if encoding == RLE:
        values = np.asarray(values)
        if len(values) == 0:
            return [b"", b""]
        change = np.nonzero(np.diff(values))[0] + 1
        starts = np.concatenate([[0], change])
        ends = np.concatenate([change, [len(values)]])
        return [np.ascontiguousarray(values[starts]).tobytes(),
                (ends - starts).astype(np.int32).tobytes()]
    raise ValueError(encoding)


def decode(field_type: str, encoding: str, bufs: list[bytes], n: int,
           numpy_dtype) -> np.ndarray:
    if encoding == PLAIN:
        if field_type == "string":
            return _string_from_buffers(bufs, n)
        return np.frombuffer(bufs[0], numpy_dtype)[:n].copy()
    if encoding == BITPACK:
        return np.unpackbits(np.frombuffer(bufs[0], np.uint8))[:n].astype("?")
    if encoding == DICT:
        idx = np.frombuffer(bufs[0], np.int32)[:n]
        if field_type == "string":
            dict_n = (len(np.frombuffer(bufs[1], np.int64)) - 1)
            uniq = _string_from_buffers(bufs[1:], dict_n)
        else:
            uniq = np.frombuffer(bufs[1], numpy_dtype)
        return uniq[idx]
    if encoding == DELTA:
        base = np.frombuffer(bufs[0], np.int64)
        out = np.empty(n, np.int64)
        if n:
            out[0] = base[0]
        if n > 1:
            deltas = np.frombuffer(bufs[1], np.int8).astype(np.int64)
            np.cumsum(deltas[:n - 1], out=out[1:])
            out[1:] += base[0]
        return out.astype(numpy_dtype)
    if encoding == RLE:
        vals = np.frombuffer(bufs[0], numpy_dtype)
        runs = np.frombuffer(bufs[1], np.int32)
        return np.repeat(vals, runs)[:n]
    raise ValueError(encoding)
