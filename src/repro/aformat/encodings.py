"""Column-chunk encodings: PLAIN / DICT / DICTP / RLE / DELTA / BITPACK.

Each encoder maps a values array -> list of raw buffers; the footer records
which encoding was used.  The *decode* cost of these encodings (plus the
codec) is exactly the client-CPU work the paper offloads to storage.

``choose_encoding`` is the cheap one-shot heuristic the append/write hot
path uses; ``repro.aformat.advisor`` is the measured alternative — it
encodes every applicable candidate and picks by stored bytes weighted
with the decode plane's per-backend rate priors (compaction's default).

Hardware-adaptation note (DESIGN.md §2): dictionary decode *is* wired to
the TPU — ``repro.aformat.decode.PallasBackend`` routes DICT chunks (and
DICTP chunks, after a host-side index unpack) through the
``repro.kernels`` gather kernel whenever a scan runs with
``decode_backend="pallas"``.  RLE run expansion, DELTA's int8 delta
stream, the string offset/payload buffers, and the width-bit unpack
steps run on the host path: they are byte-stream transforms whose
output (not input) is what the kernels consume, so the host decodes
them and the accelerator takes over from the decoded arrays.

Encodings:

PLAIN    raw little-endian values (strings: int64 offsets + payload).
DICT     int32 indices + unique values.
DICTP    width-bit packed indices + unique values (width = bits needed
         for the dictionary size; buffer 0 = 1-byte width + packed bits).
RLE      run values + int32 run lengths.
DELTA    int64 base + int8 deltas (monotone-ish integer columns).
BITPACK  bool: 1 bit per value (``np.packbits``).  int32/int64: values
         rebased to their minimum and packed at the smallest width that
         holds the range (buffer 0 = <int64 base, uint8 width> header,
         buffer 1 = packed bits) — the width-parameterized integer form.
"""

from __future__ import annotations

import struct

import numpy as np

from repro.aformat.table import strings_from_buffers

PLAIN, DICT, RLE, DELTA, BITPACK = "plain", "dict", "rle", "delta", "bitpack"
DICTP = "dictp"


def _string_buffers(values) -> list[bytes]:
    raw = [("" if v is None else str(v)).encode() for v in values]
    offsets = np.zeros(len(raw) + 1, np.int64)
    np.cumsum([len(r) for r in raw], out=offsets[1:])
    return [offsets.tobytes(), b"".join(raw)]


def _string_from_buffers(bufs, n):
    return strings_from_buffers(np.frombuffer(bufs[0], np.int64),
                                bufs[1], n)


def choose_encoding(field_type: str, values: np.ndarray) -> str:
    if field_type == "bool":
        return BITPACK
    if field_type == "string":
        # compare the sample's uniq count against the SAMPLE size: the
        # old `len(values) // 4` denominator made any column over ~16k
        # rows dictionary-encode regardless of its true cardinality
        sample = values[:4096]
        uniq = len(set(map(str, sample)))
        return DICT if uniq <= max(1, len(sample) // 4) else PLAIN
    if field_type in ("int32", "int64"):
        sample = values[: min(len(values), 4096)]
        if len(sample) > 1:
            d = np.diff(sample)
            if len(d) and d.min() >= 0 and d.max() <= 127:
                return DELTA
            runs = int(np.count_nonzero(d)) + 1
            if runs <= len(sample) // 8:
                return RLE
        uniq = len(np.unique(sample))
        if uniq <= max(1, min(len(values) // 4, 60_000)):
            return DICT
        return PLAIN
    # floats: dictionary only when very low cardinality
    uniq = len(np.unique(values[: min(len(values), 4096)]))
    if uniq <= max(1, len(values) // 16):
        return DICT
    return PLAIN


def pack_width(rel: np.ndarray, width: int) -> bytes:
    """Pack nonnegative values into ``width``-bit little-endian cells."""
    if len(rel) == 0:
        return b""
    rel = rel.astype(np.uint64)
    bitmat = ((rel[:, None] >> np.arange(width, dtype=np.uint64))
              & np.uint64(1)).astype(np.uint8)
    return np.packbits(bitmat.reshape(-1), bitorder="little").tobytes()


def unpack_width(buf: bytes, n: int, width: int) -> np.ndarray:
    """Inverse of :func:`pack_width` -> uint64 array of length ``n``."""
    if n == 0:
        return np.zeros(0, np.uint64)
    bits = np.unpackbits(np.frombuffer(buf, np.uint8),
                         bitorder="little")[:n * width]
    bitmat = bits.reshape(n, width).astype(np.uint64)
    return (bitmat << np.arange(width, dtype=np.uint64)).sum(
        axis=1, dtype=np.uint64)


def _dict_parts(field_type: str, values: np.ndarray):
    if field_type == "string":
        svals = np.asarray([str(v) for v in values], object)
        uniq, inv = np.unique(svals.astype(str), return_inverse=True)
        return inv, _string_buffers(uniq.astype(object)), len(uniq)
    uniq, inv = np.unique(values, return_inverse=True)
    return inv, [np.ascontiguousarray(uniq).tobytes()], len(uniq)


def encode(field_type: str, encoding: str, values: np.ndarray) -> list[bytes]:
    if encoding == PLAIN:
        if field_type == "string":
            return _string_buffers(values)
        return [np.ascontiguousarray(values).tobytes()]
    if encoding == BITPACK:
        if field_type == "bool":
            return [np.packbits(values.astype("?")).tobytes()]
        if field_type not in ("int32", "int64"):
            raise ValueError("bitpack: bool or integer columns only")
        v = values.astype(np.int64)
        if len(v) == 0:
            return [struct.pack("<qB", 0, 1), b""]
        base = int(v.min())
        span = int(v.max()) - base
        if span >= 2 ** 63:
            raise ValueError("bitpack range overflow; caller falls back")
        width = max(1, span.bit_length())
        rel = (v - np.int64(base)).astype(np.uint64)
        return [struct.pack("<qB", base, width), pack_width(rel, width)]
    if encoding == DICT:
        inv, uniq_bufs, _ = _dict_parts(field_type, values)
        return [inv.astype(np.int32).tobytes(), *uniq_bufs]
    if encoding == DICTP:
        inv, uniq_bufs, n_uniq = _dict_parts(field_type, values)
        width = max(1, max(n_uniq - 1, 0).bit_length())
        return [struct.pack("<B", width) + pack_width(inv, width),
                *uniq_bufs]
    if encoding == DELTA:
        base = values[:1].astype(np.int64)
        deltas = np.diff(values.astype(np.int64))
        if len(deltas) and (deltas.min() < -128 or deltas.max() > 127):
            raise ValueError("delta overflow; caller should fall back")
        return [base.tobytes(), deltas.astype(np.int8).tobytes()]
    if encoding == RLE:
        values = np.asarray(values)
        if len(values) == 0:
            return [b"", b""]
        change = np.nonzero(values[1:] != values[:-1])[0] + 1
        starts = np.concatenate([[0], change])
        ends = np.concatenate([change, [len(values)]])
        return [np.ascontiguousarray(values[starts]).tobytes(),
                (ends - starts).astype(np.int32).tobytes()]
    raise ValueError(encoding)


def decode(field_type: str, encoding: str, bufs: list[bytes], n: int,
           numpy_dtype) -> np.ndarray:
    if encoding == PLAIN:
        if field_type == "string":
            return _string_from_buffers(bufs, n)
        return np.frombuffer(bufs[0], numpy_dtype)[:n].copy()
    if encoding == BITPACK:
        if field_type == "bool":
            return np.unpackbits(
                np.frombuffer(bufs[0], np.uint8))[:n].astype("?")
        base, width = struct.unpack("<qB", bufs[0][:9])
        rel = unpack_width(bufs[1], n, width)
        return (rel.astype(np.int64) + np.int64(base)).astype(numpy_dtype)
    if encoding in (DICT, DICTP):
        if encoding == DICT:
            idx = np.frombuffer(bufs[0], np.int32)[:n]
        else:
            width = bufs[0][0]
            idx = unpack_width(bufs[0][1:], n, width).astype(np.int64)
        if field_type == "string":
            dict_n = (len(np.frombuffer(bufs[1], np.int64)) - 1)
            uniq = _string_from_buffers(bufs[1:], dict_n)
        else:
            uniq = np.frombuffer(bufs[1], numpy_dtype)
        return uniq[idx]
    if encoding == DELTA:
        base = np.frombuffer(bufs[0], np.int64)
        out = np.empty(n, np.int64)
        if n:
            out[0] = base[0]
        if n > 1:
            deltas = np.frombuffer(bufs[1], np.int8).astype(np.int64)
            np.cumsum(deltas[:n - 1], out=out[1:])
            out[1:] += base[0]
        return out.astype(numpy_dtype)
    if encoding == RLE:
        vals = np.frombuffer(bufs[0], numpy_dtype)
        runs = np.frombuffer(bufs[1], np.int32)
        return np.repeat(vals, runs)[:n]
    raise ValueError(encoding)
