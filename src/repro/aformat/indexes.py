"""Per-row-group physical-design indexes (beyond min/max zone maps).

A :class:`ColumnIndex` is a per-column, per-row-group auxiliary index: a
bloom filter over the chunk's non-null values (the double-hash core is
shared with ``expressions.BloomIn`` so both sides of the wire hash
identically) plus an exact distinct-value count.  The writer builds one
per column chunk (``parquet.encode_row_group``); it serializes as a
versioned block inside the chunk's footer entry, and readers that meet
an unknown version simply ignore the block — min/max statistics alone
keep every pruning verdict sound, the index only ever upgrades a MAYBE
(SOME) verdict to a provable NONE.

Probing canonicalizes values into the build-side key domain first
(integers widen to int64, floats take their float64 bit pattern, strings
hash an 8-byte blake2b digest — exactly ``expressions._key_words``), so
an ``Eq``/``IsIn``/``BloomIn`` probe can never false-negative on a value
the chunk actually holds.  A probe value that cannot be represented in
the build domain returns ``None`` ("no verdict"), never ``False``.
"""

from __future__ import annotations

import base64
import dataclasses

import numpy as np

from repro.aformat.expressions import _key_words, _mix64

#: Version tag written into every serialized index block.  Readers skip
#: blocks whose version they do not understand (forward compatibility);
#: footers written before index blocks existed simply lack the field
#: (backward compatibility) — both degrade to stats-only pruning.
INDEX_VERSION = 1

#: Bloom sizing: bits per *distinct* value (not per row — run-heavy and
#: dictionary-friendly chunks get proportionally tiny filters).
BITS_PER_DISTINCT = 8

#: Hard cap on one filter's size (bits): 2**20 bits = 128 KiB.  Past the
#: cap the filter saturates gracefully (higher FPR, still sound).
MAX_BITS = 1 << 20

_SEED_1 = 0x9E3779B97F4A7C15
_SEED_2 = 0xD1B54A32D192ED03


def value_kind(field_type: str) -> str:
    """The canonical key domain of a schema type: "i" (integer-like),
    "f" (float bit pattern), or "s" (string digest)."""
    if field_type in ("bool", "int32", "int64"):
        return "i"
    if field_type in ("float32", "float64"):
        return "f"
    return "s"


def canonical_words(kind: str, values) -> np.ndarray | None:
    """Canonicalize probe values into the ``kind`` key domain and hash
    them to uint64 words.  Returns None when any value cannot be
    represented exactly — the caller must treat that as "no verdict"
    (a lossy coercion could manufacture a false NONE)."""
    try:
        if kind == "i":
            out = []
            for v in values:
                if isinstance(v, (float, np.floating)):
                    if not float(v).is_integer():
                        return None
                iv = int(v)
                if not -(2**63) <= iv < 2**63:
                    return None
                out.append(iv)
            arr = np.asarray(out, np.int64)
        elif kind == "f":
            arr = np.asarray([float(v) for v in values], np.float64)
        else:
            arr = np.asarray([str(v) for v in values], object)
    except (TypeError, ValueError, OverflowError):
        return None
    return _key_words(arr)


@dataclasses.dataclass
class ColumnIndex:
    """Bloom filter + distinct count for one column chunk."""

    kind: str  # "i" | "f" | "s" — the build-side key domain
    bits: bytes
    num_bits: int
    num_hashes: int
    distinct: int  # exact distinct non-null values in the chunk
    count: int  # non-null values inserted
    version: int = INDEX_VERSION

    # -- construction ------------------------------------------------------
    @staticmethod
    def build(
        column, *, bits_per_distinct: int = BITS_PER_DISTINCT
    ) -> "ColumnIndex":
        """Build the index for one column chunk (``column`` is any object
        with ``.values``, ``.validity`` and ``.field.type``)."""
        vals = np.asarray(column.values)
        if column.validity is not None:
            vals = vals[column.validity]
        kind = value_kind(column.field.type)
        # vectorized canonicalization: schema-typed arrays coerce exactly
        if kind == "i":
            words = _key_words(vals.astype(np.int64))
        elif kind == "f":
            words = _key_words(vals.astype(np.float64))
        else:
            words = _key_words(np.asarray([str(v) for v in vals], object))
        uniq = np.unique(words)
        distinct = int(len(uniq))
        n = max(1, distinct)
        num_bits = max(64, 1 << int(np.ceil(np.log2(n * bits_per_distinct))))
        num_bits = min(num_bits, MAX_BITS)
        num_hashes = min(8, max(1, int(round(0.7 * num_bits / n))))
        bitarr = np.zeros(num_bits // 8, np.uint8)
        if distinct:
            h1 = _mix64(uniq, _SEED_1)
            h2 = _mix64(uniq, _SEED_2) | np.uint64(1)
            for i in range(num_hashes):
                with np.errstate(over="ignore"):
                    pos = (h1 + np.uint64(i) * h2) % np.uint64(num_bits)
                np.bitwise_or.at(
                    bitarr,
                    (pos >> np.uint64(3)).astype(np.int64),
                    np.uint8(1) << (pos & np.uint64(7)).astype(np.uint8),
                )
        return ColumnIndex(
            kind, bitarr.tobytes(), num_bits, num_hashes, distinct, len(vals)
        )

    # -- probes ------------------------------------------------------------
    def _probe_words(self, words: np.ndarray) -> np.ndarray:
        bitarr = np.frombuffer(self.bits, np.uint8)
        h1 = _mix64(words, _SEED_1)
        h2 = _mix64(words, _SEED_2) | np.uint64(1)
        mask = np.ones(len(words), "?")
        for i in range(self.num_hashes):
            with np.errstate(over="ignore"):
                pos = (h1 + np.uint64(i) * h2) % np.uint64(self.num_bits)
            bit = bitarr[(pos >> np.uint64(3)).astype(np.int64)] & (
                np.uint8(1) << (pos & np.uint64(7)).astype(np.uint8)
            )
            mask &= bit != 0
        return mask

    def contains_any(self, values) -> bool | None:
        """Tri-state membership: False = provably none of ``values`` is
        in the chunk (safe to prune), True = at least one may be, None =
        no verdict (a value could not be canonicalized)."""
        words = canonical_words(self.kind, values)
        if words is None or len(words) == 0:
            return None
        return bool(self._probe_words(words).any())

    def contains_any_words(self, words: np.ndarray) -> bool:
        """Membership over pre-hashed key words (the semi-join probe path:
        the build side hashed its keys once with ``_key_words``)."""
        words = np.asarray(words, np.uint64)
        if len(words) == 0:
            return True
        return bool(self._probe_words(words).any())

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict:
        return {
            "v": self.version,
            "kind": self.kind,
            "bloom": base64.b64encode(self.bits).decode("ascii"),
            "num_bits": self.num_bits,
            "num_hashes": self.num_hashes,
            "distinct": self.distinct,
            "count": self.count,
        }

    @staticmethod
    def from_json(d: dict | None) -> "ColumnIndex | None":
        """None (absent field: pre-index footer) and unknown versions both
        load as "no index" — old files scan unchanged, future blocks are
        skipped rather than misread."""
        if not d or d.get("v") != INDEX_VERSION:
            return None
        return ColumnIndex(
            d["kind"],
            base64.b64decode(d["bloom"]),
            d["num_bits"],
            d["num_hashes"],
            d["distinct"],
            d["count"],
            d["v"],
        )
