"""Decode engine: pluggable backends behind every client-side scan.

One row group is the unit of work: decompressed column-chunk buffers plus
their encodings (and an optional predicate) go in, a filtered ``Table``
comes out.  Two backends implement that contract:

``NumPyBackend``
    The host path — ``encodings.decode`` per column, ``Expr.evaluate``
    for the mask, ``Table.filter`` for the selection.  This is the code
    that used to live inline in ``parquet.scan_row_group``; storage-side
    ``scan_op`` still runs it (OSDs have no accelerator).

``PallasBackend``
    The accelerator path (``repro.kernels``): DICT (and, after a host
    width-bit unpack of the index buffer, DICTP) columns batch through
    the ``decode_dictionary`` gather kernel, supported predicates lower
    via ``build_program``/``fused_predicate`` so mask evaluation fuses
    across columns in one pass, and selections compact through
    ``pack_tokens``.  Everything the kernels cannot express — RLE/DELTA
    byte streams, strings, float64, integers outside the f32-exact
    domain, IsIn/Bloom/mixed-logic expression nodes — falls back
    per-column / per-predicate to the host path.  Off-accelerator the
    kernels run ``interpret=True`` (see ``repro.kernels.*.ops``), so the
    two backends are byte-identical everywhere; ``tests/test_decode.py``
    pins that equivalence across the encoding x dtype x validity x
    predicate grid.

The scheduler prices the two regimes separately: each backend carries a
``decode_rate_prior`` (stored bytes per second of decode+filter) that
seeds the client-side EWMA in ``repro.dataset.scheduler``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.aformat import compression, encodings
from repro.aformat.expressions import And, Cmp, Expr, Not, Or
from repro.aformat.schema import Field
from repro.aformat.table import Column, Table

#: |integers| below this round-trip float32 exactly — the kernels compute
#: in f32, so columns/constants outside the domain stay on the host path.
F32_EXACT = 2 ** 24

#: Expression ops -> kernel Term ops (repro.kernels.predicate_fused).
_KERNEL_OPS = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge",
               "==": "eq", "!=": "ne"}

#: Numeric types the kernels can represent exactly (f32 compute): bool
#: and f32 always, 32/64-bit ints only inside the f32-exact domain —
#: checked against the live values.  float64 would truncate, so: host.
_KERNEL_TYPES = ("int32", "int64", "float32", "bool")


def n_data_buffers(field_type: str, encoding: str) -> int:
    """How many of a chunk's buffers hold data (the rest is validity)."""
    if encoding == encodings.PLAIN:
        return 2 if field_type == "string" else 1
    if encoding in (encodings.DICT, encodings.DICTP):
        return 3 if field_type == "string" else 2
    if encoding in (encodings.DELTA, encodings.RLE):
        return 2
    # bitpack: bool is a single bit buffer; integers carry a
    # <base, width> header buffer plus the packed bits
    return 1 if field_type == "bool" else 2


@dataclasses.dataclass
class ChunkData:
    """One column chunk of one row group: decompressed, not yet decoded."""

    field: Field
    encoding: str
    bufs: list[bytes]           # data buffers, then optional validity
    num_rows: int

    @property
    def data_bufs(self) -> list[bytes]:
        return self.bufs[:n_data_buffers(self.field.type, self.encoding)]

    def validity(self) -> np.ndarray | None:
        nd = n_data_buffers(self.field.type, self.encoding)
        if len(self.bufs) <= nd:
            return None
        return np.unpackbits(np.frombuffer(self.bufs[nd], np.uint8)
                             )[:self.num_rows].astype("?")


def read_chunk(src, meta, rg, name: str) -> ChunkData:
    """Read + decompress one column chunk (``meta``/``rg`` are the
    ``parquet.FileMeta``/``RowGroupMeta`` footer objects, duck-typed so
    this module never imports the file format)."""
    field = meta.schema.field(name)
    chunk = rg.chunks[meta.schema.index(name)]
    bufs = []
    off = chunk.offset
    for ln in chunk.buffer_lengths:
        bufs.append(compression.decompress(chunk.codec, src.read(off, ln)))
        off += ln
    return ChunkData(field, chunk.encoding, bufs, rg.num_rows)


# ---------------------------------------------------------------------------
# Backend interface
# ---------------------------------------------------------------------------


class DecodeBackend:
    """Decode + filter + select one row group.  Subclasses override the
    three hooks (column decode, mask evaluation, selection compaction);
    the row-group template is shared so the backends can never disagree
    about column ordering, validity handling, or projection."""

    name = "abstract"
    #: stored-bytes/s prior seeding the scheduler's client-side EWMA
    decode_rate_prior = 150e6

    def decode_column(self, chunk: ChunkData) -> Column:
        raise NotImplementedError

    def evaluate_predicate(self, tbl: Table, predicate: Expr,
                           report: dict | None = None) -> np.ndarray:
        raise NotImplementedError

    def compact(self, tbl: Table, mask: np.ndarray,
                report: dict | None = None) -> Table:
        raise NotImplementedError

    def scan_row_group(self, src, meta, rg,
                       columns: Sequence[str] | None = None,
                       predicate: Expr | None = None,
                       report: dict | None = None) -> Table:
        """Decode + filter + project one row group (the scan_op payload).
        ``report``, when given, is filled with the per-column / predicate
        routing this call actually took (kernel vs host fallback)."""
        names = list(columns) if columns is not None else meta.schema.names
        needed = set(names)
        if predicate is not None:
            needed |= predicate.columns()
        order = sorted(needed, key=meta.schema.index)
        cols = {n: self.decode_column(read_chunk(src, meta, rg, n))
                for n in order}
        if report is not None:
            report["columns"] = {n: getattr(cols[n], "_decode_route",
                                            "host") for n in order}
            for n in order:
                if hasattr(cols[n], "_decode_route"):
                    del cols[n]._decode_route
        tbl = Table(meta.schema.select(order), [cols[n] for n in order])
        if predicate is not None:
            mask = np.asarray(self.evaluate_predicate(tbl, predicate,
                                                      report), "?")
            tbl = self.compact(tbl, mask, report)
        return tbl.select(names)

    def describe(self, meta, rg, columns: Sequence[str] | None,
                 predicate: Expr | None) -> str:
        """Static routing summary from footer metadata alone — what
        ``explain()`` prints before any byte is read."""
        return self.name


class NumPyBackend(DecodeBackend):
    """The host decode path (exactly the code ``parquet.scan_row_group``
    used to inline)."""

    name = "numpy"
    decode_rate_prior = 150e6    # matches the paper-testbed Xeon prior

    def decode_column(self, chunk: ChunkData) -> Column:
        values = encodings.decode(chunk.field.type, chunk.encoding,
                                  chunk.data_bufs, chunk.num_rows,
                                  chunk.field.numpy_dtype)
        return Column(chunk.field, values, chunk.validity())

    def evaluate_predicate(self, tbl, predicate, report=None):
        if report is not None:
            report["predicate"] = "host"
        return predicate.evaluate(tbl)

    def compact(self, tbl, mask, report=None):
        if report is not None:
            report["compact"] = "host"
        return tbl.filter(mask)


# ---------------------------------------------------------------------------
# Pallas backend
# ---------------------------------------------------------------------------


def _f32_exact_values(values: np.ndarray) -> bool:
    """True when every value survives the kernels' f32 compute exactly."""
    if values.dtype.kind == "b":
        return True
    if values.dtype == np.float32:
        return True
    if values.dtype.kind in "iu":
        return len(values) == 0 or \
            int(np.abs(values).max()) < F32_EXACT
    return False


def _f32_exact_scalar(v) -> bool:
    """A comparison constant the kernel can hold exactly in f32."""
    if isinstance(v, bool) or isinstance(v, np.bool_):
        return True
    if not isinstance(v, (int, float, np.integer, np.floating)):
        return False
    f = float(v)
    return np.isfinite(f) and float(np.float32(f)) == f


def _flatten(pred: Expr):
    """Flatten an expression into (leaves, combine, negate) when it is a
    flat AND- or OR-tree of Cmp leaves (optionally under one Not); None
    when any other node type (IsIn / Bloom / mixed logic) appears."""
    negate = False
    if isinstance(pred, Not):
        pred, negate = pred.expr, True
    stack, leaves, kinds = [pred], [], set()
    while stack:
        node = stack.pop()
        if isinstance(node, Cmp):
            leaves.append(node)
        elif isinstance(node, (And, Or)):
            kinds.add("and" if isinstance(node, And) else "or")
            stack += [node.lhs, node.rhs]
        else:
            return None
    if len(kinds) > 1:
        return None
    return leaves, (kinds.pop() if kinds else "and"), negate


class PallasBackend(DecodeBackend):
    """The accelerator decode path (``repro.kernels``), with per-column /
    per-predicate host fallback for everything the kernels cannot express
    exactly.  Safe to share across scan threads: it holds no per-call
    state (kernel jit caches are process-global)."""

    name = "pallas"
    # Dictionary gather / fused compare are HBM-bandwidth bound on the
    # accelerator (see benchmarks/kernel_bench.py rooflines): ~an order
    # of magnitude over the host prior.  The EWMA corrects from there.
    decode_rate_prior = 1.5e9

    def decode_column(self, chunk: ChunkData) -> Column:
        route = "host"
        values = None
        if (chunk.encoding in (encodings.DICT, encodings.DICTP)
                and chunk.field.type in ("int32", "int64", "float32")):
            from repro.kernels import decode_dictionary

            if chunk.encoding == encodings.DICT:
                codes = np.frombuffer(chunk.data_bufs[0],
                                      np.int32)[:chunk.num_rows]
            else:
                # DICTP: width-bit unpack is a byte-stream transform
                # (host), the gather itself still runs on the kernel
                buf = chunk.data_bufs[0]
                codes = encodings.unpack_width(
                    buf[1:], chunk.num_rows, buf[0]).astype(np.int32)
            dic = np.frombuffer(chunk.data_bufs[1],
                                chunk.field.numpy_dtype)
            try:
                # raises ValueError when an int dictionary leaves the
                # f32-exact domain — exactly the host-fallback condition
                values = np.asarray(decode_dictionary(codes, dic))
                route = "kernel"
            except ValueError:
                values = None
        if values is None:
            values = encodings.decode(chunk.field.type, chunk.encoding,
                                      chunk.data_bufs, chunk.num_rows,
                                      chunk.field.numpy_dtype)
        col = Column(chunk.field, values, chunk.validity())
        col._decode_route = route        # scraped into the scan report
        return col

    # -- predicate ---------------------------------------------------------
    def _lower(self, tbl: Table, predicate: Expr):
        """(kernel Program, referenced Columns) or (None, reason)."""
        flat = _flatten(predicate)
        if flat is None:
            return None, "unsupported-node"
        leaves, combine, negate = flat
        cols: list[Column] = []
        col_idx: dict[str, int] = {}
        terms = []
        for leaf in leaves:
            col = tbl.column(leaf.column)
            if col.field.type not in _KERNEL_TYPES:
                return None, f"{leaf.column}:{col.field.type}"
            if not _f32_exact_scalar(leaf.value):
                return None, f"{leaf.column}:value"
            if not _f32_exact_values(col.values):
                return None, f"{leaf.column}:f32-domain"
            if col.validity is not None and (combine != "and" or negate):
                # nulls distribute over AND (mask & every validity) but
                # not over OR / NOT — those mixes stay on the host
                return None, f"{leaf.column}:validity"
            if leaf.column not in col_idx:
                col_idx[leaf.column] = len(cols)
                cols.append(col)
            terms.append((col_idx[leaf.column], _KERNEL_OPS[leaf.op],
                          float(leaf.value)))
        from repro.kernels import build_program

        return (build_program(terms, combine, negate), cols), None

    def evaluate_predicate(self, tbl, predicate, report=None):
        lowered, reason = self._lower(tbl, predicate)
        if lowered is None:
            if report is not None:
                report["predicate"] = f"host:{reason}"
            return predicate.evaluate(tbl)
        from repro.kernels import fused_predicate

        prog, cols = lowered
        mask = np.asarray(fused_predicate(
            [np.asarray(c.values, np.float32) for c in cols], prog))
        for c in cols:
            if c.validity is not None:     # AND-combine only (see _lower)
                mask = mask & c.validity
        if report is not None:
            report["predicate"] = "kernel"
        return mask

    # -- selection ---------------------------------------------------------
    def compact(self, tbl, mask, report=None):
        from repro.kernels import pack_tokens

        idx = np.flatnonzero(mask)
        n_sel = len(idx)
        # round the pack capacity up to a power of two: the kernel is
        # jitted per (n, capacity) shape, so exact capacities would
        # retrace on every new selectivity — bucketing keeps the trace
        # cache hot and the [:n_sel] slice restores the exact result
        capacity = 1 << (n_sel - 1).bit_length() if n_sel else 0
        routes = {}
        out_cols = []
        for c in tbl.columns:
            if (capacity and c.field.type in _KERNEL_TYPES
                    and _f32_exact_values(c.values)):
                packed, _ = pack_tokens(c.values, mask, capacity)
                validity = None if c.validity is None else c.validity[idx]
                out_cols.append(Column(c.field,
                                       np.asarray(packed)[:n_sel],
                                       validity))
                routes[c.field.name] = "kernel"
            else:
                out_cols.append(c.take(idx))
                routes[c.field.name] = "host"
        if report is not None:
            report["compact"] = routes
        return Table(tbl.schema, out_cols)

    # -- explain -----------------------------------------------------------
    def describe(self, meta, rg, columns, predicate):
        """Per-column routing from footer metadata (encoding, dtype, and
        min/max stats for the int f32-domain check); the live scan makes
        the same calls against the actual buffers."""
        names = list(columns) if columns is not None else meta.schema.names
        needed = set(names)
        if predicate is not None:
            needed |= predicate.columns()
        kernel, host = [], []
        for n in sorted(needed, key=meta.schema.index):
            field = meta.schema.field(n)
            chunk = rg.chunks[meta.schema.index(n)]
            ok = (chunk.encoding in (encodings.DICT, encodings.DICTP)
                  and field.type in ("int32", "int64", "float32"))
            if ok and field.type != "float32":
                st = chunk.stats
                ok = (st.min is not None
                      and max(abs(int(st.min)), abs(int(st.max)))
                      < F32_EXACT)
            (kernel if ok else host).append(
                n if ok else f"{n}({chunk.encoding})")
        pred = ""
        if predicate is not None:
            pred = " pred=fused" if _flatten(predicate) is not None \
                else " pred=host"
        detail = "; ".join(p for p in (
            f"kernel={','.join(kernel)}" if kernel else "",
            f"host={','.join(host)}" if host else "") if p)
        return f"pallas[{detail}]{pred}"


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

_BACKENDS: dict[str, DecodeBackend] = {}


def resolve_backend(backend: "DecodeBackend | str | None") -> DecodeBackend:
    """Resolve a ``decode_backend=`` argument: None -> the NumPy host
    path, a known name ("numpy" / "pallas") -> a shared instance (so
    kernel jit caches are reused), an instance passes through."""
    if isinstance(backend, DecodeBackend):
        return backend
    if backend is None:
        backend = "numpy"
    if isinstance(backend, str):
        inst = _BACKENDS.get(backend)
        if inst is None:
            if backend == "numpy":
                inst = _BACKENDS.setdefault("numpy", NumPyBackend())
            elif backend == "pallas":
                inst = _BACKENDS.setdefault("pallas", PallasBackend())
        if inst is not None:
            return inst
    raise ValueError(
        f"unknown decode backend {backend!r}: pass 'numpy', 'pallas', or "
        "a DecodeBackend instance")
