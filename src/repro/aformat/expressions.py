"""Predicate expression AST with vectorized evaluation and stats pruning.

``Expr.evaluate(table)`` -> bool mask (client- or storage-side scan).
``Expr.prune(stats)``    -> {ALL, NONE, SOME}: whether a row group can be
skipped (NONE) or fully taken (ALL) from its footer min/max statistics —
Parquet predicate pushdown (paper §2.3).
"""

from __future__ import annotations

import base64
import dataclasses
import hashlib
from typing import Any, Mapping

import numpy as np

ALL, SOME, NONE = "all", "some", "none"


class Expr:
    def evaluate(self, table) -> np.ndarray:
        raise NotImplementedError

    def prune(self, stats: Mapping[str, "ColumnStats"]) -> str:
        return SOME

    def columns(self) -> set[str]:
        return set()

    # sugar
    def __and__(self, o):
        return And(self, o)

    def __or__(self, o):
        return Or(self, o)

    def __invert__(self):
        return Not(self)

    def to_json(self) -> dict:
        raise NotImplementedError

    @staticmethod
    def from_json(d: dict | None) -> "Expr | None":
        if d is None:
            return None
        kind = d["kind"]
        if kind == "cmp":
            return Cmp(d["op"], d["column"], d["value"])
        if kind == "and":
            return And(Expr.from_json(d["lhs"]), Expr.from_json(d["rhs"]))
        if kind == "or":
            return Or(Expr.from_json(d["lhs"]), Expr.from_json(d["rhs"]))
        if kind == "not":
            return Not(Expr.from_json(d["expr"]))
        if kind == "isin":
            return IsIn(d["column"], d["values"])
        if kind == "bloom":
            return BloomIn(
                d["column"],
                base64.b64decode(d["bits"]),
                d["num_bits"], d["num_hashes"], d["count"],
                d.get("lo"), d.get("hi"))
        raise ValueError(kind)


_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


@dataclasses.dataclass
class Cmp(Expr):
    op: str
    column: str
    value: Any

    def evaluate(self, table):
        col = table.column(self.column)
        vals = col.values
        if col.field.type == "string":
            vals = np.asarray([str(v) for v in vals])
        mask = _OPS[self.op](vals, self.value)
        if col.validity is not None:
            mask = mask & col.validity
        return np.asarray(mask, "?")

    def prune(self, stats):
        st = stats.get(self.column)
        if st is None:
            return SOME
        if st.min is not None:
            lo, hi, v = st.min, st.max, self.value
            full = st.null_count == 0
            if self.op == "==":
                if v < lo or v > hi:
                    return NONE
                if lo == hi == v and full:
                    return ALL
            elif self.op == "!=":
                if lo == hi == v:
                    return NONE
                if (v < lo or v > hi) and full:
                    return ALL
            elif self.op == "<":
                if lo >= v:
                    return NONE
                if hi < v and full:
                    return ALL
            elif self.op == "<=":
                if lo > v:
                    return NONE
                if hi <= v and full:
                    return ALL
            elif self.op == ">":
                if hi <= v:
                    return NONE
                if lo > v and full:
                    return ALL
            elif self.op == ">=":
                if hi < v:
                    return NONE
                if lo >= v and full:
                    return ALL
        if self.op == "==":
            # bloom-index probe: upgrade the stats MAYBE to a provable
            # NONE (False = definitely absent; True/None stay SOME)
            idx = getattr(st, "index", None)
            if idx is not None and idx.contains_any([self.value]) is False:
                return NONE
        return SOME

    def columns(self):
        return {self.column}

    def to_json(self):
        v = self.value
        if isinstance(v, np.generic):
            v = v.item()
        return {"kind": "cmp", "op": self.op, "column": self.column,
                "value": v}


@dataclasses.dataclass
class IsIn(Expr):
    column: str
    values: list

    def evaluate(self, table):
        col = table.column(self.column)
        vals = col.values
        if col.field.type == "string":
            vals = np.asarray([str(v) for v in vals])
        mask = np.isin(vals, np.asarray(self.values))
        if col.validity is not None:
            mask = mask & col.validity
        return np.asarray(mask, "?")

    def prune(self, stats):
        st = stats.get(self.column)
        if st is None:
            return SOME
        if st.min is not None and all(
                v < st.min or v > st.max for v in self.values):
            return NONE
        idx = getattr(st, "index", None)
        if (idx is not None and self.values
                and idx.contains_any(self.values) is False):
            return NONE
        return SOME

    def columns(self):
        return {self.column}

    def to_json(self):
        return {"kind": "isin", "column": self.column,
                "values": [v.item() if isinstance(v, np.generic) else v
                           for v in self.values]}


def _mix64(x: np.ndarray, seed: int) -> np.ndarray:
    """SplitMix64-style avalanche over a uint64 array (wrapping mults)."""
    x = x.astype(np.uint64, copy=True) ^ np.uint64(seed)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xFF51AFD7ED558CCD)
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xC4CEB9FE1A85EC53)
        x ^= x >> np.uint64(33)
    return x


def _key_words(values: np.ndarray) -> np.ndarray:
    """Canonical uint64 word per key value, identical no matter which side
    of the wire hashes it: integers widen, floats take their bit pattern
    (-0.0 normalized to 0.0), strings take an 8-byte blake2b digest."""
    arr = np.asarray(values)
    if arr.dtype.kind in ("i", "u", "b"):
        return arr.astype(np.int64).view(np.uint64).copy()
    if arr.dtype.kind == "f":
        f = arr.astype(np.float64).copy()
        f[f == 0.0] = 0.0
        return f.view(np.uint64).copy()
    return np.fromiter(
        (int.from_bytes(
            hashlib.blake2b(str(v).encode("utf-8"),
                            digest_size=8).digest(), "little")
         for v in arr),
        np.uint64, len(arr))


@dataclasses.dataclass
class BloomIn(Expr):
    """Bloom-filter membership: ``column``'s value hashes into a bit array
    built from a join's build-side keys.  May pass values that were never
    inserted (false positives — callers that need exactness re-verify
    against the true key set), never rejects an inserted value.  Carries
    the inserted keys' min/max so footer-stats pruning stays exact:
    a fragment whose range is disjoint from [lo, hi] is provably empty of
    matches (NONE); ALL is never claimed."""

    column: str
    bits: bytes
    num_bits: int
    num_hashes: int
    count: int                    # keys inserted (explain/selectivity)
    lo: Any = None                # min/max of the inserted keys (numeric
    hi: Any = None                # keys only; None disables range pruning)
    #: In-memory only (never serialized — the wire form is unchanged):
    #: the build keys' canonical hash words and their key domain, kept by
    #: ``build`` for small key sets so ``prune`` can probe a row group's
    #: ColumnIndex bloom before the fragment ships.
    key_kind: "str | None" = dataclasses.field(default=None, compare=False)
    words: Any = dataclasses.field(default=None, compare=False, repr=False)

    #: Probe-side key retention cap: past this, per-row-group bloom
    #: probes cost more than they prune and ``prune`` stays stats-only.
    MAX_PROBE_KEYS = 4096

    @staticmethod
    def build(column: str, values, *, bits_per_key: int = 10) -> "BloomIn":
        arr = np.asarray(values)
        n = max(1, len(arr))
        num_bits = max(64, 1 << int(np.ceil(np.log2(n * bits_per_key))))
        num_hashes = max(1, int(round(0.7 * num_bits / n)))
        num_hashes = min(num_hashes, 8)
        bitarr = np.zeros(num_bits // 8, np.uint8)
        words = _key_words(arr)
        h1 = _mix64(words, 0x9E3779B97F4A7C15)
        h2 = _mix64(words, 0xD1B54A32D192ED03) | np.uint64(1)
        for i in range(num_hashes):
            with np.errstate(over="ignore"):
                pos = (h1 + np.uint64(i) * h2) % np.uint64(num_bits)
            np.bitwise_or.at(bitarr, (pos >> np.uint64(3)).astype(np.int64),
                             np.uint8(1) << (pos & np.uint64(7)).astype(
                                 np.uint8))
        lo = hi = None
        if arr.dtype.kind in ("i", "u", "f") and len(arr):
            lo, hi = arr.min().item(), arr.max().item()
        bl = BloomIn(column, bitarr.tobytes(), num_bits, num_hashes,
                     len(arr), lo, hi)
        bl.key_kind = ("i" if arr.dtype.kind in ("i", "u", "b")
                       else "f" if arr.dtype.kind == "f" else "s")
        if len(arr) <= BloomIn.MAX_PROBE_KEYS:
            bl.words = np.unique(words)
        return bl

    def _test(self, values: np.ndarray) -> np.ndarray:
        bitarr = np.frombuffer(self.bits, np.uint8)
        words = _key_words(values)
        h1 = _mix64(words, 0x9E3779B97F4A7C15)
        h2 = _mix64(words, 0xD1B54A32D192ED03) | np.uint64(1)
        mask = np.ones(len(words), "?")
        for i in range(self.num_hashes):
            with np.errstate(over="ignore"):
                pos = (h1 + np.uint64(i) * h2) % np.uint64(self.num_bits)
            bit = bitarr[(pos >> np.uint64(3)).astype(np.int64)] \
                & (np.uint8(1) << (pos & np.uint64(7)).astype(np.uint8))
            mask &= bit != 0
        return mask

    def evaluate(self, table):
        col = table.column(self.column)
        mask = self._test(col.values)
        if col.validity is not None:
            mask = mask & col.validity
        return np.asarray(mask, "?")

    def prune(self, stats):
        st = stats.get(self.column)
        if st is None:
            return SOME
        if (st.min is not None and self.lo is not None
                and self.hi is not None
                and (st.max < self.lo or st.min > self.hi)):
            return NONE
        # probe the row group's own bloom with the build keys' words:
        # both sides hash through _key_words, so domains must match
        idx = getattr(st, "index", None)
        if (idx is not None and self.words is not None
                and len(self.words) and self.key_kind == idx.kind
                and not idx.contains_any_words(self.words)):
            return NONE
        return SOME               # never ALL: the filter is approximate

    def columns(self):
        return {self.column}

    def digest(self) -> str:
        """Short content digest — result-cache keys and explain() use it
        instead of the (possibly kilobytes-long) bit array."""
        h = hashlib.blake2s(digest_size=8)
        h.update(self.bits)
        h.update(f"{self.num_bits}/{self.num_hashes}/{self.count}".encode())
        return h.hexdigest()

    def to_json(self):
        d = {"kind": "bloom", "column": self.column,
             "bits": base64.b64encode(self.bits).decode("ascii"),
             "num_bits": self.num_bits, "num_hashes": self.num_hashes,
             "count": self.count}
        if self.lo is not None:
            v = self.lo
            d["lo"] = v.item() if isinstance(v, np.generic) else v
            v = self.hi
            d["hi"] = v.item() if isinstance(v, np.generic) else v
        return d


@dataclasses.dataclass
class And(Expr):
    lhs: Expr
    rhs: Expr

    def evaluate(self, table):
        return self.lhs.evaluate(table) & self.rhs.evaluate(table)

    def prune(self, stats):
        a, b = self.lhs.prune(stats), self.rhs.prune(stats)
        if NONE in (a, b):
            return NONE
        if a == ALL and b == ALL:
            return ALL
        return SOME

    def columns(self):
        return self.lhs.columns() | self.rhs.columns()

    def to_json(self):
        return {"kind": "and", "lhs": self.lhs.to_json(),
                "rhs": self.rhs.to_json()}


@dataclasses.dataclass
class Or(Expr):
    lhs: Expr
    rhs: Expr

    def evaluate(self, table):
        return self.lhs.evaluate(table) | self.rhs.evaluate(table)

    def prune(self, stats):
        a, b = self.lhs.prune(stats), self.rhs.prune(stats)
        if ALL in (a, b):
            return ALL
        if a == NONE and b == NONE:
            return NONE
        return SOME

    def columns(self):
        return self.lhs.columns() | self.rhs.columns()

    def to_json(self):
        return {"kind": "or", "lhs": self.lhs.to_json(),
                "rhs": self.rhs.to_json()}


@dataclasses.dataclass
class Not(Expr):
    expr: Expr

    def evaluate(self, table):
        return ~self.expr.evaluate(table)

    def prune(self, stats):
        inner = self.expr.prune(stats)
        if inner == ALL:
            return NONE
        if inner == NONE:
            return ALL
        return SOME

    def columns(self):
        return self.expr.columns()

    def to_json(self):
        return {"kind": "not", "expr": self.expr.to_json()}


def field(name: str):
    """field("x") > 3  -> Cmp(">", "x", 3)."""
    return _FieldRef(name)


@dataclasses.dataclass
class _FieldRef:
    name: str

    def __eq__(self, v):  # type: ignore[override]
        return Cmp("==", self.name, v)

    def __ne__(self, v):  # type: ignore[override]
        return Cmp("!=", self.name, v)

    def __lt__(self, v):
        return Cmp("<", self.name, v)

    def __le__(self, v):
        return Cmp("<=", self.name, v)

    def __gt__(self, v):
        return Cmp(">", self.name, v)

    def __ge__(self, v):
        return Cmp(">=", self.name, v)

    def isin(self, values):
        return IsIn(self.name, list(values))
