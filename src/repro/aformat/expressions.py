"""Predicate expression AST with vectorized evaluation and stats pruning.

``Expr.evaluate(table)`` -> bool mask (client- or storage-side scan).
``Expr.prune(stats)``    -> {ALL, NONE, SOME}: whether a row group can be
skipped (NONE) or fully taken (ALL) from its footer min/max statistics —
Parquet predicate pushdown (paper §2.3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import numpy as np

ALL, SOME, NONE = "all", "some", "none"


class Expr:
    def evaluate(self, table) -> np.ndarray:
        raise NotImplementedError

    def prune(self, stats: Mapping[str, "ColumnStats"]) -> str:
        return SOME

    def columns(self) -> set[str]:
        return set()

    # sugar
    def __and__(self, o):
        return And(self, o)

    def __or__(self, o):
        return Or(self, o)

    def __invert__(self):
        return Not(self)

    def to_json(self) -> dict:
        raise NotImplementedError

    @staticmethod
    def from_json(d: dict | None) -> "Expr | None":
        if d is None:
            return None
        kind = d["kind"]
        if kind == "cmp":
            return Cmp(d["op"], d["column"], d["value"])
        if kind == "and":
            return And(Expr.from_json(d["lhs"]), Expr.from_json(d["rhs"]))
        if kind == "or":
            return Or(Expr.from_json(d["lhs"]), Expr.from_json(d["rhs"]))
        if kind == "not":
            return Not(Expr.from_json(d["expr"]))
        if kind == "isin":
            return IsIn(d["column"], d["values"])
        raise ValueError(kind)


_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


@dataclasses.dataclass
class Cmp(Expr):
    op: str
    column: str
    value: Any

    def evaluate(self, table):
        col = table.column(self.column)
        vals = col.values
        if col.field.type == "string":
            vals = np.asarray([str(v) for v in vals])
        mask = _OPS[self.op](vals, self.value)
        if col.validity is not None:
            mask = mask & col.validity
        return np.asarray(mask, "?")

    def prune(self, stats):
        st = stats.get(self.column)
        if st is None or st.min is None:
            return SOME
        lo, hi, v = st.min, st.max, self.value
        full = st.null_count == 0
        if self.op == "==":
            if v < lo or v > hi:
                return NONE
            if lo == hi == v and full:
                return ALL
        elif self.op == "!=":
            if lo == hi == v:
                return NONE
            if (v < lo or v > hi) and full:
                return ALL
        elif self.op == "<":
            if lo >= v:
                return NONE
            if hi < v and full:
                return ALL
        elif self.op == "<=":
            if lo > v:
                return NONE
            if hi <= v and full:
                return ALL
        elif self.op == ">":
            if hi <= v:
                return NONE
            if lo > v and full:
                return ALL
        elif self.op == ">=":
            if hi < v:
                return NONE
            if lo >= v and full:
                return ALL
        return SOME

    def columns(self):
        return {self.column}

    def to_json(self):
        v = self.value
        if isinstance(v, np.generic):
            v = v.item()
        return {"kind": "cmp", "op": self.op, "column": self.column,
                "value": v}


@dataclasses.dataclass
class IsIn(Expr):
    column: str
    values: list

    def evaluate(self, table):
        col = table.column(self.column)
        vals = col.values
        if col.field.type == "string":
            vals = np.asarray([str(v) for v in vals])
        mask = np.isin(vals, np.asarray(self.values))
        if col.validity is not None:
            mask = mask & col.validity
        return np.asarray(mask, "?")

    def prune(self, stats):
        st = stats.get(self.column)
        if st is None or st.min is None:
            return SOME
        if all(v < st.min or v > st.max for v in self.values):
            return NONE
        return SOME

    def columns(self):
        return {self.column}

    def to_json(self):
        return {"kind": "isin", "column": self.column,
                "values": [v.item() if isinstance(v, np.generic) else v
                           for v in self.values]}


@dataclasses.dataclass
class And(Expr):
    lhs: Expr
    rhs: Expr

    def evaluate(self, table):
        return self.lhs.evaluate(table) & self.rhs.evaluate(table)

    def prune(self, stats):
        a, b = self.lhs.prune(stats), self.rhs.prune(stats)
        if NONE in (a, b):
            return NONE
        if a == ALL and b == ALL:
            return ALL
        return SOME

    def columns(self):
        return self.lhs.columns() | self.rhs.columns()

    def to_json(self):
        return {"kind": "and", "lhs": self.lhs.to_json(),
                "rhs": self.rhs.to_json()}


@dataclasses.dataclass
class Or(Expr):
    lhs: Expr
    rhs: Expr

    def evaluate(self, table):
        return self.lhs.evaluate(table) | self.rhs.evaluate(table)

    def prune(self, stats):
        a, b = self.lhs.prune(stats), self.rhs.prune(stats)
        if ALL in (a, b):
            return ALL
        if a == NONE and b == NONE:
            return NONE
        return SOME

    def columns(self):
        return self.lhs.columns() | self.rhs.columns()

    def to_json(self):
        return {"kind": "or", "lhs": self.lhs.to_json(),
                "rhs": self.rhs.to_json()}


@dataclasses.dataclass
class Not(Expr):
    expr: Expr

    def evaluate(self, table):
        return ~self.expr.evaluate(table)

    def prune(self, stats):
        inner = self.expr.prune(stats)
        if inner == ALL:
            return NONE
        if inner == NONE:
            return ALL
        return SOME

    def columns(self):
        return self.expr.columns()

    def to_json(self):
        return {"kind": "not", "expr": self.expr.to_json()}


def field(name: str):
    """field("x") > 3  -> Cmp(">", "x", 3)."""
    return _FieldRef(name)


@dataclasses.dataclass
class _FieldRef:
    name: str

    def __eq__(self, v):  # type: ignore[override]
        return Cmp("==", self.name, v)

    def __ne__(self, v):  # type: ignore[override]
        return Cmp("!=", self.name, v)

    def __lt__(self, v):
        return Cmp("<", self.name, v)

    def __le__(self, v):
        return Cmp("<=", self.name, v)

    def __gt__(self, v):
        return Cmp(">", self.name, v)

    def __ge__(self, v):
        return Cmp(">=", self.name, v)

    def isin(self, values):
        return IsIn(self.name, list(values))
