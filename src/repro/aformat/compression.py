"""Buffer codecs.  zlib (stdlib) stands in for snappy/zstd."""

from __future__ import annotations

import zlib

NONE, ZLIB = "none", "zlib"


def compress(codec: str, buf: bytes, level: int = 1) -> bytes:
    if codec == NONE:
        return buf
    if codec == ZLIB:
        return zlib.compress(buf, level)
    raise ValueError(codec)


def decompress(codec: str, buf: bytes) -> bytes:
    if codec == NONE:
        return buf
    if codec == ZLIB:
        return zlib.decompress(buf)
    raise ValueError(codec)
