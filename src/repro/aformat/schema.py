"""Minimal Arrow-like schema/type system.

Types: int32, int64, float32, float64, bool, string.  Columns are numpy
arrays (strings use object/str arrays externally; the file format stores
them Arrow-style as offsets + utf8 bytes).
"""

from __future__ import annotations

import dataclasses

import numpy as np

_TYPES = {
    "int32": np.dtype("<i4"),
    "int64": np.dtype("<i8"),
    "float32": np.dtype("<f4"),
    "float64": np.dtype("<f8"),
    "bool": np.dtype("?"),
    "string": None,  # offsets + utf8 payload
}


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    type: str
    nullable: bool = False

    def __post_init__(self):
        if self.type not in _TYPES:
            raise ValueError(f"unsupported type {self.type!r}")

    @property
    def numpy_dtype(self):
        return _TYPES[self.type]

    def to_json(self):
        return {"name": self.name, "type": self.type,
                "nullable": self.nullable}

    @staticmethod
    def from_json(d):
        return Field(d["name"], d["type"], d.get("nullable", False))


@dataclasses.dataclass(frozen=True)
class Schema:
    fields: tuple[Field, ...]

    def __post_init__(self):
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names: {names}")

    def __iter__(self):
        return iter(self.fields)

    def __len__(self):
        return len(self.fields)

    @property
    def names(self):
        return [f.name for f in self.fields]

    def field(self, name: str) -> Field:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(name)

    def index(self, name: str) -> int:
        for i, f in enumerate(self.fields):
            if f.name == name:
                return i
        raise KeyError(name)

    def select(self, names) -> "Schema":
        return Schema(tuple(self.field(n) for n in names))

    def to_json(self):
        return {"fields": [f.to_json() for f in self.fields]}

    @staticmethod
    def from_json(d):
        return Schema(tuple(Field.from_json(f) for f in d["fields"]))


def schema(*pairs, nullable=()) -> Schema:
    """schema(("a","int64"), ("b","float32"), ...)."""
    return Schema(tuple(Field(n, t, n in nullable) for n, t in pairs))


def infer_type(arr: np.ndarray) -> str:
    if arr.dtype == np.dtype("?"):
        return "bool"
    if arr.dtype.kind in ("U", "O", "T"):
        return "string"
    for name, dt in _TYPES.items():
        if dt is not None and arr.dtype == dt:
            return name
    if arr.dtype.kind == "i":
        return "int64"
    if arr.dtype.kind == "f":
        return "float64"
    raise TypeError(f"cannot infer arrow type for dtype {arr.dtype}")
