"""ARW1 — the Parquet-analogue binary columnar file format.

Layout (byte order little-endian):

    [b"ARW1"]
    row group 0: column chunk 0 buffers | column chunk 1 buffers | ...
    row group 1: ...
    [footer JSON]
    [uint32 footer length][b"ARW1"]

The footer carries the schema, per-row-group / per-column-chunk byte ranges,
encodings, codecs and min/max/null statistics — everything needed for
predicate pushdown (read footer, prune row groups on stats, read only the
projected column chunks).  Structurally faithful to Apache Parquet; not
byte-compatible (Thrift is not the paper's contribution — DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
import json
import struct
from typing import Sequence

import numpy as np

from repro.aformat import compression, encodings, indexes
from repro.aformat import decode as decode_mod
from repro.aformat.schema import Schema
from repro.aformat.statistics import ColumnStats, compute_stats
from repro.aformat.table import Column, Table

MAGIC = b"ARW1"


@dataclasses.dataclass
class ChunkMeta:
    offset: int                 # absolute file offset of first buffer
    buffer_lengths: list[int]   # compressed buffer lengths, in order
    encoding: str
    codec: str
    stats: ColumnStats
    #: Versioned physical-design index block (bloom + distinct count);
    #: None on footers written before index blocks existed, and on
    #: blocks whose version this reader does not understand.
    index: "indexes.ColumnIndex | None" = None

    def to_json(self, *, include_indexes: bool = True):
        d = {"offset": self.offset, "buffer_lengths": self.buffer_lengths,
             "encoding": self.encoding, "codec": self.codec,
             "stats": self.stats.to_json()}
        if include_indexes and self.index is not None:
            d["index"] = self.index.to_json()
        return d

    @staticmethod
    def from_json(d):
        return ChunkMeta(d["offset"], d["buffer_lengths"], d["encoding"],
                         d["codec"], ColumnStats.from_json(d["stats"]),
                         indexes.ColumnIndex.from_json(d.get("index")))


@dataclasses.dataclass
class RowGroupMeta:
    num_rows: int
    offset: int
    total_bytes: int
    chunks: list[ChunkMeta]     # one per schema field, in order

    def to_json(self, *, include_indexes: bool = True):
        return {"num_rows": self.num_rows, "offset": self.offset,
                "total_bytes": self.total_bytes,
                "chunks": [c.to_json(include_indexes=include_indexes)
                           for c in self.chunks]}

    @staticmethod
    def from_json(d):
        return RowGroupMeta(d["num_rows"], d["offset"], d["total_bytes"],
                            [ChunkMeta.from_json(c) for c in d["chunks"]])

    def column_stats(self, schema: Schema) -> dict[str, ColumnStats]:
        """Per-column stats with the chunk's index block (if any) riding
        along — every pruning choke point receives this mapping, so a
        footer that carries indexes makes ``Expr.prune`` index-aware
        with no signature change anywhere."""
        out = {}
        for f, c in zip(schema, self.chunks):
            if c.stats.index is not c.index:
                c.stats.index = c.index
            out[f.name] = c.stats
        return out


@dataclasses.dataclass
class FileMeta:
    schema: Schema
    row_groups: list[RowGroupMeta]
    num_rows: int
    created_by: str = "repro-arw1"

    def to_json(self, *, include_indexes: bool = True):
        return {"schema": self.schema.to_json(),
                "row_groups": [r.to_json(include_indexes=include_indexes)
                               for r in self.row_groups],
                "num_rows": self.num_rows, "created_by": self.created_by}

    @staticmethod
    def from_json(d):
        return FileMeta(Schema.from_json(d["schema"]),
                        [RowGroupMeta.from_json(r) for r in d["row_groups"]],
                        d["num_rows"], d.get("created_by", "?"))

    def serialize(self, *, include_indexes: bool = True) -> bytes:
        """``include_indexes=False`` strips the (possibly kilobytes-long)
        bloom blocks — the wire form for request payloads and metadata
        replies, where min/max stats are all the receiver prunes with."""
        return json.dumps(
            self.to_json(include_indexes=include_indexes)).encode()

    @staticmethod
    def deserialize(b: bytes) -> "FileMeta":
        return FileMeta.from_json(json.loads(b))


# ---------------------------------------------------------------------------
# Writer
# ---------------------------------------------------------------------------


def encode_row_group(part: Table, codec: str, *, build_indexes: bool = True,
                     advise: bool = False) -> tuple[bytes, RowGroupMeta]:
    """Encode one row group; ChunkMeta offsets are relative to the group.

    ``build_indexes`` attaches a per-column bloom/distinct index block to
    each chunk's footer entry.  ``advise=True`` swaps the one-shot
    ``choose_encoding`` heuristic for the measured advisor (encode every
    candidate, keep the cheapest — the compaction write path)."""
    out = bytearray()
    chunks = []
    for col in part.columns:
        if advise:
            from repro.aformat import advisor as advisor_mod

            advice = advisor_mod.advise_column(
                col.field.type, col.values, codec)
            enc, bufs = advice.encoding, list(advice.buffers)
        else:
            enc = encodings.choose_encoding(col.field.type, col.values)
            try:
                bufs = encodings.encode(col.field.type, enc, col.values)
            except ValueError:  # e.g. DELTA overflow found on full data
                enc = encodings.PLAIN
                bufs = encodings.encode(col.field.type, enc, col.values)
        if col.validity is not None:
            bufs.append(np.packbits(col.validity).tobytes())
        comp = [compression.compress(codec, b) for b in bufs]
        meta = ChunkMeta(len(out), [len(b) for b in comp], enc, codec,
                         compute_stats(col),
                         indexes.ColumnIndex.build(col)
                         if build_indexes else None)
        for b in comp:
            out.extend(b)
        chunks.append(meta)
    return bytes(out), RowGroupMeta(len(part), 0, len(out), chunks)


def _shift_group(rg: RowGroupMeta, offset: int) -> RowGroupMeta:
    return RowGroupMeta(rg.num_rows, offset, rg.total_bytes, [
        ChunkMeta(c.offset + offset, c.buffer_lengths, c.encoding, c.codec,
                  c.stats, c.index) for c in rg.chunks])


def iter_row_groups(table: Table, row_group_rows: int):
    n = len(table)
    if n == 0:
        yield table
        return
    for start in range(0, n, row_group_rows):
        yield table.slice(start, min(row_group_rows, n - start))


def write_table(table: Table, *, row_group_rows: int = 65536,
                codec: str = compression.ZLIB,
                pad_row_groups_to: int = 0,
                build_indexes: bool = True, advise: bool = False) -> bytes:
    """Serialize a table.  ``pad_row_groups_to`` pads every row group to a
    multiple of that many bytes — the Striped layout's equal-size row-group
    rewrite (paper Fig. 3).  ``build_indexes``/``advise`` are the
    physical-design knobs (bloom index blocks; measured encoding
    selection — see ``repro.aformat.advisor``)."""
    out = bytearray(MAGIC)
    groups: list[RowGroupMeta] = []
    for part in iter_row_groups(table, row_group_rows):
        data, rg = encode_row_group(part, codec,
                                    build_indexes=build_indexes,
                                    advise=advise)
        g_off = len(out)
        out.extend(data)
        total = rg.total_bytes
        if pad_row_groups_to and total % pad_row_groups_to:
            pad = pad_row_groups_to - total % pad_row_groups_to
            out.extend(b"\x00" * pad)
            total += pad
        shifted = _shift_group(rg, g_off)
        shifted.total_bytes = total
        groups.append(shifted)
    footer = FileMeta(table.schema, groups, len(table)).serialize()
    out.extend(footer)
    out.extend(struct.pack("<I", len(footer)))
    out.extend(MAGIC)
    return bytes(out)


# ---------------------------------------------------------------------------
# Reader — operates on any random-access source (file bytes, object view)
# ---------------------------------------------------------------------------


class RandomAccessSource:
    """Interface: read(offset, length) -> bytes; size() -> int."""

    def read(self, offset: int, length: int) -> bytes:
        raise NotImplementedError

    def size(self) -> int:
        raise NotImplementedError


class BytesSource(RandomAccessSource):
    def __init__(self, data: bytes):
        self._d = data

    def read(self, offset, length):
        return self._d[offset:offset + length]

    def size(self):
        return len(self._d)


def read_footer(src: RandomAccessSource) -> FileMeta:
    sz = src.size()
    tail = src.read(sz - 8, 8)
    if tail[4:] != MAGIC:
        raise ValueError("bad ARW1 trailing magic")
    (flen,) = struct.unpack("<I", tail[:4])
    return FileMeta.deserialize(src.read(sz - 8 - flen, flen))


def read_column(src: RandomAccessSource, meta: FileMeta, rg: RowGroupMeta,
                name: str, backend=None) -> Column:
    """Decode one column chunk through a decode backend (host by
    default — see ``repro.aformat.decode``)."""
    return decode_mod.resolve_backend(backend).decode_column(
        decode_mod.read_chunk(src, meta, rg, name))


def _n_data_buffers(field_type: str, encoding: str) -> int:
    # kept as an alias: the layout rule moved to the decode-engine layer
    return decode_mod.n_data_buffers(field_type, encoding)


def scan_row_group(src: RandomAccessSource, meta: FileMeta, rg: RowGroupMeta,
                   columns: Sequence[str] | None = None,
                   predicate=None, backend=None) -> Table:
    """Decode + filter + project one row group (the scan_op payload).
    ``backend`` picks the decode engine (None -> the NumPy host path;
    "pallas" routes DICT decode / predicate / selection through the
    ``repro.kernels`` Pallas ops with per-column host fallback)."""
    return decode_mod.resolve_backend(backend).scan_row_group(
        src, meta, rg, columns, predicate)


def scan_file(src: RandomAccessSource, columns=None, predicate=None,
              meta: FileMeta | None = None, backend=None) -> Table:
    """Whole-file scan with row-group pruning (predicate pushdown)."""
    from repro.aformat.expressions import ALL, NONE

    meta = meta or read_footer(src)
    parts = []
    for rg in meta.row_groups:
        if predicate is not None:
            verdict = predicate.prune(rg.column_stats(meta.schema))
            if verdict == NONE:
                continue
            pred = None if verdict == ALL else predicate
        else:
            pred = None
        parts.append(scan_row_group(src, meta, rg, columns, pred,
                                    backend=backend))
    if not parts:
        names = list(columns) if columns is not None else meta.schema.names
        sch = meta.schema.select(names)
        return Table(sch, [Column(f, np.empty(0, object)
                                  if f.type == "string"
                                  else np.empty(0, f.numpy_dtype))
                           for f in sch])
    return Table.concat(parts)
