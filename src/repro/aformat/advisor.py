"""Measured encoding advisor: pick a chunk's encoding by encoding it.

``encodings.choose_encoding`` is a one-shot heuristic over a 4096-row
sample — cheap enough for the append hot path, but it guesses.  This
module is the measured alternative the compaction path uses
(``snapshot.MutableDataset.compact(advisor=True)`` →
``objclass.compact_op`` → ``parquet.encode_row_group(advise=True)``):
for each column chunk it *actually encodes* every applicable candidate,
compresses the buffers with the chunk's codec, and picks the cheapest by

    cost_s = stored_bytes / WIRE_RATE + stored_bytes / decode_rate

where ``decode_rate`` is the decode plane's per-backend rate prior for
the route that encoding would take (DICT/DICTP numeric chunks gather on
the Pallas kernel path; everything else decodes on the host) — so a
slightly larger encoding can still win when it unlocks the accelerated
decode route, exactly the stored-bytes-times-decode-rate trade the paper
prices.

Stored bytes stay primary: only candidates within ``BYTES_SLACK`` of
the smallest measured size compete on the rate-weighted cost.  Without
that gate the ~10x kernel prior would excuse multi-x byte inflation
(e.g. DICT over a unique-key column), defeating the point of measuring.

Candidate sets per type (all of ``encodings``' forms, including the
width-parameterized integer BITPACK and the bit-packed DICTP indices):

    string   PLAIN, DICT, DICTP
    bool     BITPACK, RLE, PLAIN
    int      PLAIN, DICT, DICTP, RLE, DELTA, BITPACK
    float    PLAIN, DICT, DICTP

A candidate that raises ``ValueError`` (DELTA overflow, BITPACK range
overflow) is simply dropped — PLAIN always applies, so the advisor
always returns a valid pick whose buffers the caller writes as-is.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.aformat import compression, encodings

#: Bytes/s the stored form moves at (network/flash read) — one shared
#: scale so the decode-rate term is commensurable; the *relative*
#: ranking is what matters, not the absolute seconds.
WIRE_RATE = 1e9

#: Candidates whose stored bytes exceed the minimum by more than this
#: factor are out, regardless of decode rate.
BYTES_SLACK = 1.10


def _rate_priors() -> tuple[float, float]:
    """(host, kernel) decode-rate priors from the decode plane."""
    from repro.aformat.decode import NumPyBackend, PallasBackend

    return NumPyBackend.decode_rate_prior, PallasBackend.decode_rate_prior


def candidate_encodings(field_type: str) -> list[str]:
    if field_type == "string":
        return [encodings.PLAIN, encodings.DICT, encodings.DICTP]
    if field_type == "bool":
        return [encodings.BITPACK, encodings.RLE, encodings.PLAIN]
    if field_type in ("int32", "int64"):
        return [encodings.PLAIN, encodings.DICT, encodings.DICTP,
                encodings.RLE, encodings.DELTA, encodings.BITPACK]
    return [encodings.PLAIN, encodings.DICT, encodings.DICTP]


def _decode_rate(field_type: str, encoding: str,
                 host_rate: float, kernel_rate: float) -> float:
    if (encoding in (encodings.DICT, encodings.DICTP)
            and field_type in ("int32", "int64", "float32")):
        return kernel_rate
    return host_rate


@dataclasses.dataclass
class Candidate:
    encoding: str
    stored_bytes: int   # compressed size, summed over data buffers
    cost_s: float       # wire + decode seconds under the rate priors


@dataclasses.dataclass
class Advice:
    """The advisor's pick for one column chunk.  ``buffers`` are the
    winner's *raw* (uncompressed) buffers — the caller compresses and
    writes them, so the measurement encode is not repeated."""

    encoding: str
    buffers: list[bytes]
    stored_bytes: int
    candidates: list[Candidate]


def advise_column(field_type: str, values: np.ndarray,
                  codec: str) -> Advice:
    host_rate, kernel_rate = _rate_priors()
    ranked: list[Candidate] = []
    raw: dict[str, list[bytes]] = {}
    for enc in candidate_encodings(field_type):
        try:
            bufs = encodings.encode(field_type, enc, values)
        except ValueError:
            continue
        stored = sum(len(compression.compress(codec, b)) for b in bufs)
        rate = _decode_rate(field_type, enc, host_rate, kernel_rate)
        ranked.append(
            Candidate(enc, stored, stored / WIRE_RATE + stored / rate))
        raw[enc] = bufs
    assert ranked  # PLAIN never raises
    min_stored = min(c.stored_bytes for c in ranked)
    eligible = [c for c in ranked
                if c.stored_bytes <= BYTES_SLACK * min_stored]
    winner = min(eligible, key=lambda c: c.cost_s)
    ranked.sort(key=lambda c: (c not in eligible, c.cost_s))
    return Advice(winner.encoding, raw[winner.encoding],
                  winner.stored_bytes, ranked)
