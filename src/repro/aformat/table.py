"""Columnar in-memory table (the Arrow analogue) + IPC wire format.

The IPC wire format is intentionally simple and *uncompressed* (header JSON
+ raw little-endian buffers) — mirroring Apache Arrow's design point that
the paper leans on: scan results travel in a larger-but-zero-decode format,
so pushdown trades network bytes for client CPU (their Fig. 5, 100%
selectivity case).
"""

from __future__ import annotations

import dataclasses
import json
import struct
from typing import Any, Iterable, Mapping, Sequence

import numpy as np

from repro.aformat.schema import Field, Schema, infer_type


def _to_string_buffers(arr) -> tuple[np.ndarray, bytes]:
    vals = [("" if v is None else str(v)).encode("utf-8") for v in arr]
    offsets = np.zeros(len(vals) + 1, np.int64)
    np.cumsum([len(v) for v in vals], out=offsets[1:])
    return offsets, b"".join(vals)


def strings_from_buffers(offsets: np.ndarray, payload: bytes,
                         n: int) -> np.ndarray:
    """Arrow-style (byte offsets, UTF-8 payload) -> (n,) object array of
    str.  The payload is decoded *once* and sliced by character offsets —
    equal to the byte offsets for pure-ASCII payloads (the common case),
    otherwise mapped through a vectorized count of UTF-8 continuation
    bytes — instead of one ``bytes.decode`` call per row."""
    out = np.empty(n, object)
    if n == 0:
        return out
    text = payload.decode("utf-8")
    if len(text) == len(payload):          # ASCII: offsets line up 1:1
        char_off = offsets
    else:
        lead = (np.frombuffer(payload, np.uint8) & 0xC0) != 0x80
        cum = np.zeros(len(payload) + 1, np.int64)
        np.cumsum(lead, out=cum[1:])
        char_off = cum[np.asarray(offsets[:n + 1], np.int64)]
    starts = char_off[:n].tolist()
    ends = char_off[1:n + 1].tolist()
    out[:] = [text[s:e] for s, e in zip(starts, ends)]
    return out


def _from_string_buffers(offsets: np.ndarray, payload: bytes) -> np.ndarray:
    return strings_from_buffers(offsets, payload, len(offsets) - 1)


@dataclasses.dataclass
class Column:
    field: Field
    values: np.ndarray                    # object array for strings
    validity: np.ndarray | None = None    # bool mask; None = all valid

    def __post_init__(self):
        if self.field.type == "string":
            if self.values.dtype.kind not in ("O", "U", "T"):
                raise TypeError("string column needs object/str array")
            if self.values.dtype.kind != "O":
                self.values = self.values.astype(object)
        else:
            self.values = np.ascontiguousarray(
                self.values, self.field.numpy_dtype)
        if self.validity is not None:
            self.validity = np.ascontiguousarray(self.validity, "?")
            if self.validity.all():
                self.validity = None

    def __len__(self):
        return len(self.values)

    def take(self, idx) -> "Column":
        v = None if self.validity is None else self.validity[idx]
        return Column(self.field, self.values[idx], v)

    def nbytes(self) -> int:
        if self.field.type == "string":
            return int(sum(len(str(v)) for v in self.values)) + 8 * (
                len(self.values) + 1)
        n = self.values.nbytes
        if self.validity is not None:
            n += self.validity.nbytes
        return n


class Table:
    def __init__(self, schema: Schema, columns: Sequence[Column]):
        if len(schema) != len(columns):
            raise ValueError("schema/column mismatch")
        lens = {len(c) for c in columns}
        if len(lens) > 1:
            raise ValueError(f"ragged columns: {lens}")
        self.schema = schema
        self.columns = list(columns)

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_pydict(data: Mapping[str, Any], schema: Schema | None = None
                    ) -> "Table":
        cols, fields = [], []
        for name, raw in data.items():
            arr = np.asarray(raw)
            if schema is not None:
                f = schema.field(name)
            else:
                f = Field(name, infer_type(arr))
            if f.type != "string":
                arr = arr.astype(f.numpy_dtype)
            cols.append(Column(f, arr))
            fields.append(f)
        sch = schema if schema is not None else Schema(tuple(fields))
        ordered = [cols[[f.name for f in fields].index(f2.name)]
                   for f2 in sch] if schema is not None else cols
        return Table(sch, ordered)

    def to_pydict(self):
        return {f.name: self.column(f.name).values
                for f in self.schema}

    # -- basic ops ------------------------------------------------------------
    def __len__(self):
        return len(self.columns[0]) if self.columns else 0

    @property
    def num_rows(self):
        return len(self)

    def column(self, name: str) -> Column:
        return self.columns[self.schema.index(name)]

    def select(self, names: Iterable[str]) -> "Table":
        names = list(names)
        return Table(self.schema.select(names),
                     [self.column(n) for n in names])

    def filter(self, mask: np.ndarray) -> "Table":
        idx = np.nonzero(np.asarray(mask, "?"))[0]
        return self.take(idx)

    def take(self, idx) -> "Table":
        return Table(self.schema, [c.take(idx) for c in self.columns])

    def slice(self, start: int, length: int) -> "Table":
        idx = slice(start, start + length)
        return Table(self.schema, [Column(c.field, c.values[idx],
                                          None if c.validity is None
                                          else c.validity[idx])
                                   for c in self.columns])

    def head(self, n: int) -> "Table":
        """The first min(n, len) rows — the LIMIT row-budget slice; a
        no-op (self) when the table is already within budget."""
        if len(self) <= n:
            return self
        return self.slice(0, n)

    def nbytes(self) -> int:
        return sum(c.nbytes() for c in self.columns)

    @staticmethod
    def concat(tables: Sequence["Table"]) -> "Table":
        if not tables:
            raise ValueError("concat of zero tables")
        sch = tables[0].schema
        cols = []
        for i, f in enumerate(sch):
            vals = np.concatenate([t.columns[i].values for t in tables])
            vs = [t.columns[i].validity for t in tables]
            if any(v is not None for v in vs):
                validity = np.concatenate(
                    [np.ones(len(t.columns[i]), "?") if v is None else v
                     for t, v in zip(tables, vs)])
            else:
                validity = None
            cols.append(Column(f, vals, validity))
        return Table(sch, cols)

    def equals(self, other: "Table") -> bool:
        if self.schema != other.schema or len(self) != len(other):
            return False
        for a, b in zip(self.columns, other.columns):
            va = np.ones(len(a), "?") if a.validity is None else a.validity
            vb = np.ones(len(b), "?") if b.validity is None else b.validity
            if not np.array_equal(va, vb):
                return False
            if a.field.type == "string":
                if not all((x == y) or not m for x, y, m in
                           zip(a.values, b.values, va)):
                    return False
            elif a.field.type in ("float32", "float64"):
                av, bv = a.values[va], b.values[vb]
                if not np.allclose(av, bv, equal_nan=True):
                    return False
            else:
                if not np.array_equal(a.values[va], b.values[vb]):
                    return False
        return True

    # -- IPC wire format -------------------------------------------------------
    def to_ipc(self) -> bytes:
        buffers: list[bytes] = []
        meta_cols = []
        for c in self.columns:
            entry: dict = {"name": c.field.name}
            if c.field.type == "string":
                offsets, payload = _to_string_buffers(c.values)
                entry["buffers"] = [len(buffers), len(buffers) + 1]
                buffers.append(offsets.tobytes())
                buffers.append(payload)
            else:
                entry["buffers"] = [len(buffers)]
                buffers.append(np.ascontiguousarray(c.values).tobytes())
            if c.validity is not None:
                entry["validity"] = len(buffers)
                buffers.append(np.packbits(c.validity).tobytes())
            meta_cols.append(entry)
        header = json.dumps({
            "schema": self.schema.to_json(),
            "num_rows": len(self),
            "columns": meta_cols,
            "buffer_lengths": [len(b) for b in buffers],
        }).encode()
        return (b"AIPC" + struct.pack("<I", len(header)) + header
                + b"".join(buffers))

    @staticmethod
    def from_ipc(data: bytes) -> "Table":
        if data[:4] != b"AIPC":
            raise ValueError("bad IPC magic")
        (hlen,) = struct.unpack_from("<I", data, 4)
        header = json.loads(data[8:8 + hlen])
        sch = Schema.from_json(header["schema"])
        n = header["num_rows"]
        lens = header["buffer_lengths"]
        offs = np.zeros(len(lens) + 1, np.int64)
        np.cumsum(lens, out=offs[1:])
        base = 8 + hlen

        def buf(i):
            return data[base + offs[i]:base + offs[i + 1]]

        cols = []
        for f, entry in zip(sch, header["columns"]):
            if f.type == "string":
                oi, pi = entry["buffers"]
                offsets = np.frombuffer(buf(oi), np.int64)
                values = _from_string_buffers(offsets, buf(pi))
            else:
                values = np.frombuffer(
                    buf(entry["buffers"][0]), f.numpy_dtype)[:n].copy()
            validity = None
            if "validity" in entry:
                validity = np.unpackbits(
                    np.frombuffer(buf(entry["validity"]), np.uint8))[:n]
                validity = validity.astype("?")
            cols.append(Column(f, values, validity))
        return Table(sch, cols)
