"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768. [arXiv:2401.04088; hf]
8 experts do not divide the 16-way model axis, so the baseline partitions
experts tensor-style (d_ff over "model"); see EXPERIMENTS.md for the EP
variant explored in the perf pass.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088; hf",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    num_experts=8,
    experts_per_token=2,
    moe_partition="tensor",
    scan_layers=True,
    opt_moment_dtype="int8",
)
