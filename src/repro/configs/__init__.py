"""Architecture registry: one module per assigned architecture."""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

from repro.configs.llama32_vision_90b import CONFIG as llama32_vision_90b
from repro.configs.mamba2_780m import CONFIG as mamba2_780m
from repro.configs.phi4_mini_3p8b import CONFIG as phi4_mini_3p8b
from repro.configs.gemma3_1b import CONFIG as gemma3_1b
from repro.configs.qwen2_72b import CONFIG as qwen2_72b
from repro.configs.starcoder2_7b import CONFIG as starcoder2_7b
from repro.configs.mixtral_8x22b import CONFIG as mixtral_8x22b
from repro.configs.llama4_maverick import CONFIG as llama4_maverick
from repro.configs.whisper_small import CONFIG as whisper_small
from repro.configs.zamba2_1p2b import CONFIG as zamba2_1p2b

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "ARCHS", "get_config", "smoke_config"]

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        llama32_vision_90b,
        mamba2_780m,
        phi4_mini_3p8b,
        gemma3_1b,
        qwen2_72b,
        starcoder2_7b,
        mixtral_8x22b,
        llama4_maverick,
        whisper_small,
        zamba2_1p2b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (shapes only)."""
    import dataclasses

    cfg = get_config(name)
    updates: dict = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2))
        if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        attn_chunk=64,
        scan_layers=cfg.scan_layers,
        opt_moment_dtype="float32",
    )
    if cfg.num_experts:
        updates.update(num_experts=4, experts_per_token=min(2, cfg.experts_per_token),
                       moe_layer_freq=cfg.moe_layer_freq)
    if cfg.ssm_state:
        updates.update(ssm_state=16, ssm_headdim=32, ssm_chunk=32)
    if cfg.encoder_layers:
        updates.update(encoder_layers=2, encoder_seq=64)
    if cfg.num_image_tokens:
        updates.update(num_image_tokens=32,
                       cross_attn_every=min(cfg.cross_attn_every, 2))
    if cfg.attn_every:
        updates.update(attn_every=2)
    if cfg.sliding_window:
        updates.update(sliding_window=32)
    if cfg.local_global_ratio:
        updates.update(local_global_ratio=cfg.local_global_ratio, sliding_window=32)
    return dataclasses.replace(cfg, name=f"{cfg.name}-smoke", **updates)
