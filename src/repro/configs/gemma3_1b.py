"""gemma3-1b [dense] — 5:1 local:global attention, 128k context.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]
Local layers: sliding window 1024, rope theta 10k; global layers: full
attention, rope theta 1M. Pattern encoded as a per-layer window array so a
per-layer window list covers the 5:1 schedule exactly; layers are
unrolled (26 small layers) so local ring caches and global full caches
coexist per layer at decode time.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt; unverified",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    act="gelu",
    qk_norm=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    sliding_window=1024,
    local_global_ratio=5,
    scan_layers=False,
)
