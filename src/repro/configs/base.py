"""Model / shape configuration for the assigned architecture pool."""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    source: str                      # provenance tag from the assignment

    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 3072
    vocab_size: int = 32000

    act: Literal["swiglu", "gelu"] = "swiglu"
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # !=0 -> separate theta for global layers
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    causal: bool = True

    # -- attention pattern ---------------------------------------------------
    sliding_window: int = 0          # 0 = full attention
    local_global_ratio: int = 0      # n>0 -> n local layers per 1 global
    attn_chunk: int = 1024           # flash-style kv-chunk size (seq>=8k)

    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_layer_freq: int = 1          # 2 -> every other layer is MoE
    capacity_factor: float = 1.25
    moe_partition: Literal["tensor", "expert"] = "tensor"

    # -- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # -- hybrid (zamba2-style shared attention) -------------------------------
    attn_every: int = 0              # >0: shared attn block after every k SSM layers

    # -- encoder-decoder (whisper) --------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0             # stubbed frame-embedding length

    # -- VLM (llama-3.2-vision) ------------------------------------------------
    cross_attn_every: int = 0        # >0: 1 cross-attn layer per k self layers
    num_image_tokens: int = 0        # stubbed patch-embedding length

    # -- execution ------------------------------------------------------------
    scan_layers: bool = True
    remat: bool = True
    prenorm_gather: bool = False     # §Perf q1: SP gather before the norm
    tuned_hints: bool = False        # §Perf: head-shard attention scores +
                                     # SSD decay tensors (anchors the big
                                     # softmax/segsum intermediates)
    boundary_barrier: bool = False   # §Perf: optimization_barrier after the
                                     # SP gather so XLA cannot fuse the f32
                                     # upcast into the all-gather
    train_chunked: bool = False      # §Perf: flash-chunked attention in the
                                     # train path (bounds score transients)
    rs_epilogue: bool = False        # §Perf: explicit bf16 psum_scatter
                                     # epilogue on TP out-projections
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_moment_dtype: str = "float32"   # "int8" for the >=70B configs

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived ---------------------------------------------------------------
    @property
    def d_inner(self) -> int:         # Mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_ngroups * self.ssm_state

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (needs non-quadratic full-context handling)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
            or self.local_global_ratio > 0
        )

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_size * d
        if not self.tie_embeddings:
            emb *= 2
        total = emb + d  # final norm
        if self.family == "ssm":
            total += self.num_layers * self._ssm_layer_params()
            return total
        if self.family == "hybrid":
            total += self.num_layers * self._ssm_layer_params()
            total += self._attn_params() + self._mlp_params(self.d_ff) + 2 * d
            return total
        attn = self._attn_params()
        per_layer = attn + 2 * d  # two norms
        n_moe = 0
        if self.num_experts:
            n_moe = self.num_layers // self.moe_layer_freq
        n_dense = self.num_layers - n_moe
        total += n_dense * (per_layer + self._mlp_params(self.d_ff))
        total += n_moe * (per_layer + d * self.num_experts
                          + self.num_experts * self._mlp_params(self.d_ff))
        if self.cross_attn_every:
            n_cross = self.num_layers // (self.cross_attn_every)
            total += n_cross * (attn + self._mlp_params(self.d_ff) + 2 * d)
        if self.encoder_layers:
            total += self.encoder_layers * (
                self._attn_params() + self._mlp_params(self.d_ff) + 2 * d)
            # decoder cross-attention
            total += self.num_layers * (self._attn_params() + d)
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        p = d * (self.num_heads + 2 * self.num_kv_heads) * hd
        p += self.num_heads * hd * d
        if self.qkv_bias:
            p += (self.num_heads + 2 * self.num_kv_heads) * hd
        return p

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.act == "swiglu" else 2
        return mult * self.d_model * d_ff

    def _ssm_layer_params(self) -> int:
        d = self.d_model
        di, cv = self.d_inner, self.conv_dim
        proj_in = d * (2 * di + 2 * self.ssm_ngroups * self.ssm_state
                       + self.ssm_nheads)
        conv = cv * self.ssm_conv_width + cv
        extra = 3 * self.ssm_nheads + di  # A_log, D, dt_bias, gated norm
        return proj_in + conv + extra + di * d + d  # + out proj + layer norm

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top-k of experts)."""
        if not self.num_experts:
            return self.param_count()
        n_moe = self.num_layers // self.moe_layer_freq
        inactive = (self.num_experts - self.experts_per_token)
        return self.param_count() - n_moe * inactive * self._mlp_params(self.d_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) dry-run cell runs, and why not if skipped."""
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return False, "enc-dec decoder is bounded-context by construction"
        if not cfg.sub_quadratic:
            return False, "pure full-attention arch; long_500k needs sub-quadratic"
    return True, ""
