"""starcoder2-7b [dense] — GQA, RoPE.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152. [arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    source="arXiv:2402.19173; hf",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    act="gelu",
    rope_theta=1_000_000.0,
    sliding_window=4096,
    scan_layers=True,
)
