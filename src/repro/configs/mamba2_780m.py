"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060; unverified",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    scan_layers=True,
)
