"""zamba2-1.2b [hybrid] — Mamba2 backbone + weight-shared attention block.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]
One shared attention+MLP block (single weight set) is invoked after every
6th Mamba2 layer; layers are unrolled (38 small layers) so the shared-block
schedule is exact.  LoRA per-invocation deltas omitted (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242; hf",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    attn_every=6,
    scan_layers=False,
)
