"""llama-3.2-vision-90b [vlm] — cross-attn image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
Pattern: 1 cross-attention layer per 5 layers (4 self + 1 cross per group).
Modality frontend is a STUB: input_specs() provides precomputed patch
embeddings already projected to d_model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_image_tokens=1600,
    scan_layers=True,
    opt_moment_dtype="int8",
)
