"""whisper-small [audio] — encoder-decoder; conv frontend STUBBED.

12L d_model=768 12H d_ff=3072 vocab=51865. [arXiv:2212.04356; unverified]
input_specs() provides precomputed frame embeddings (B, encoder_seq, d);
the mel-spectrogram conv frontend is a stub per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    source="arXiv:2212.04356; unverified",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    encoder_layers=12,
    encoder_seq=1500,
    causal=True,
    scan_layers=True,
)
