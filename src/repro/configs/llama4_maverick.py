"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
MoE on every other layer (moe_layer_freq=2) which reproduces the published
~400B total / ~17B active split with 128 routed experts; the chunked-
attention iRoPE detail is modeled as full attention (see DESIGN.md §6).
128 experts divide the 16-way model axis: expert partitioning (EP).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    num_experts=128,
    experts_per_token=1,
    moe_layer_freq=2,
    moe_partition="expert",
    scan_layers=True,
    opt_moment_dtype="int8",
)
