from repro.ingest.reader import (Prefetcher, ReaderConfig, ShardedReader,
                                 epoch_order, reshard_states)
from repro.ingest.state import STATE_VERSION, ReaderState

__all__ = ["Prefetcher", "ReaderConfig", "ReaderState", "STATE_VERSION",
           "ShardedReader", "epoch_order", "reshard_states"]
