"""Checkpointable reader state: resume an ingest stream byte-for-byte.

A :class:`ReaderState` is everything a sharded reader needs to reproduce
the *exact* batch stream from a cut point:

snapshot id
    Mutable datasets are pinned with ``as_of(snapshot_id)`` — commits
    that landed after the reader started (or after a crash) stay
    invisible, so restore re-plans the identical fragment task list.

epoch + seed (the RNG state)
    Per-epoch fragment order is *derived*, counter-RNG style, from
    ``default_rng((seed, epoch, dp_rank))`` instead of serializing a
    generator's internal state — the pair (seed, epoch) IS the RNG
    state, and any process can recompute the permutation.

cursor
    How many fragments of the current epoch order have been fully
    scanned into the packing buffer.

packing buffer
    Tokens already scanned but not yet emitted as a full
    ``(local_batch, seq_len+1)`` batch.  Variable length — which is why
    :meth:`restore_structs` uses the checkpoint layer's shape-free
    ``ANY_SHAPE`` placeholder.

override
    After an elastic re-shard (``repro.ingest.reshard_states``), the
    explicit remainder task order this rank must finish before resuming
    normal epoch sharding.  Encoded as indices into the canonical
    (plan-order) task list.

States serialize to a flat dict of numpy arrays (:meth:`to_arrays`) so
:class:`~repro.distrib.checkpoint.CheckpointManager` can save them as
ordinary pytree leaves alongside the model state.
"""

from __future__ import annotations

import dataclasses

import numpy as np

STATE_VERSION = 1

#: ``meta`` array layout (int64): a versioned fixed-width header so the
#: whole state round-trips through any pytree-of-arrays checkpointer.
_META_FIELDS = ("version", "seed", "dp_rank", "dp_size", "epoch",
                "cursor", "snapshot_id", "n_tasks", "has_override")


def _empty_buffer() -> np.ndarray:
    return np.empty(0, np.int32)


@dataclasses.dataclass
class ReaderState:
    """One rank's resumable ingest position (see module docstring)."""

    seed: int
    dp_rank: int
    dp_size: int
    epoch: int = 0
    cursor: int = 0
    #: Pinned snapshot of a MutableDataset source; -1 = immutable source.
    snapshot_id: int = -1
    #: Canonical task-list length, a guard that a restored state is
    #: replayed against the same plan it was cut from (-1 = unchecked).
    n_tasks: int = -1
    buffer: np.ndarray = dataclasses.field(default_factory=_empty_buffer)
    #: Elastic remainder order (indices into the canonical task list),
    #: or None when the rank follows its derived epoch order.
    override: np.ndarray | None = None

    def clone(self) -> "ReaderState":
        """Deep-enough copy: array fields are copied so a live reader
        mutating its working state never corrupts a taken checkpoint."""
        return dataclasses.replace(
            self,
            buffer=np.array(self.buffer, np.int32, copy=True),
            override=None if self.override is None
            else np.array(self.override, np.int64, copy=True),
        )

    # -- pytree-of-arrays serialization ------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        """Encode as a flat dict of numpy arrays — checkpointable as
        ordinary pytree leaves next to the model state."""
        meta = np.array(
            [STATE_VERSION, self.seed, self.dp_rank, self.dp_size,
             self.epoch, self.cursor, self.snapshot_id, self.n_tasks,
             0 if self.override is None else 1],
            np.int64,
        )
        override = (np.empty(0, np.int64) if self.override is None
                    else np.asarray(self.override, np.int64))
        return {"meta": meta,
                "buffer": np.asarray(self.buffer, np.int32),
                "override": override}

    @classmethod
    def from_arrays(cls, arrays: dict) -> "ReaderState":
        meta = np.asarray(arrays["meta"], np.int64)
        if len(meta) != len(_META_FIELDS):
            raise ValueError(
                f"ReaderState meta has {len(meta)} fields, expected "
                f"{len(_META_FIELDS)}")
        d = {k: int(v) for k, v in zip(_META_FIELDS, meta)}
        if d["version"] != STATE_VERSION:
            raise ValueError(
                f"ReaderState version {d['version']} is not "
                f"{STATE_VERSION}")
        override = None
        if d["has_override"]:
            override = np.array(arrays["override"], np.int64, copy=True)
        return cls(
            seed=d["seed"], dp_rank=d["dp_rank"], dp_size=d["dp_size"],
            epoch=d["epoch"], cursor=d["cursor"],
            snapshot_id=d["snapshot_id"], n_tasks=d["n_tasks"],
            buffer=np.array(arrays["buffer"], np.int32, copy=True),
            override=override,
        )

    @staticmethod
    def restore_structs() -> dict:
        """Restore target for CheckpointManager: the buffer and override
        arrays are variable-length, so every leaf is the shape-free
        ``ANY_SHAPE`` placeholder."""
        from repro.distrib.checkpoint import ANY_SHAPE

        return {"meta": ANY_SHAPE, "buffer": ANY_SHAPE,
                "override": ANY_SHAPE}
