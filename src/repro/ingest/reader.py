"""Sharded, checkpointable, elastic training readers over the query plan.

``ShardedReader`` replaces the old ``TokenPipeline`` private scan path:
instead of hand-rolled fragment pruning and ``scan_fragment`` calls, one
reader per data-parallel rank

1. pins its source — a ``MutableDataset`` is materialized via
   ``as_of()`` so commits landing mid-run stay invisible and a restore
   re-plans the identical fragment list;
2. lowers ``ds.query().filter(pred).select(column)`` through the full
   optimizer (stats pruning, projection pushdown) to a canonical
   :class:`~repro.dataset.plan.FragmentTask` list;
3. takes its shard of that list via
   :func:`~repro.dataset.plan.partition_tasks` — deterministic,
   row-balanced, empty shards legal — and streams it through the shared
   executor (:func:`~repro.dataset.plan.stream_tasks`) with bounded
   prefetch-ahead, under a registered ``bulk``-lane ingest
   :class:`~repro.dataset.qos.TaskContext` so interactive tenants are
   arbitrated against it by weighted-fair admission;
4. packs the token stream into fixed ``(local_batch, seq_len)``
   batches, tracking a :class:`~repro.ingest.state.ReaderState` that
   makes the whole stream resumable byte-for-byte.

Elasticity: on worker loss, feed every surviving (or checkpointed)
rank's ``ReaderState`` to :func:`reshard_states` with the new dp_size
from ``distrib.elastic.plan_downsize(...).axis_size("data")`` — the
not-yet-consumed remainder of the epoch is re-partitioned across the
survivors, each fragment exactly once, and orphaned packing buffers are
adopted rather than dropped.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator, Sequence

import numpy as np

from repro.aformat.expressions import Expr
from repro.dataset.format import FileFormat, resolve_format
from repro.dataset.plan import (PhysicalPlan, ScanMetrics, partition_tasks,
                                stream_tasks)
from repro.dataset.qos import TaskContext, TenantRegistry, ingest_context
from repro.ingest.state import ReaderState


@dataclasses.dataclass
class ReaderConfig:
    """What to read and how to batch it (rank/size travel separately —
    the same config is shared by every rank of a job)."""

    seq_len: int
    local_batch: int
    predicate: Expr | None = None          # e.g. quality > 0.8
    format: FileFormat | str = "pushdown"  # "pushdown"|"parquet"|"adaptive"
    num_threads: int = 4                   # scan prefetch-ahead (in flight)
    queue_depth: int = 4
    seed: int = 0
    prefetch: int = 2                      # batch double-buffer depth
    decode_backend: Any = None             # client decode engine (str name)
    tenant: TaskContext | str | None = None
    registry: TenantRegistry | None = None
    column: str = "token"


def epoch_order(state: ReaderState,
                shards: Sequence[Sequence[int]]) -> list[int]:
    """The exact task order ``state``'s rank walks this epoch: the
    elastic override verbatim if set, else the rank's shard permuted by
    the counter-based RNG ``default_rng((seed, epoch, dp_rank))`` — a
    pure function of the state, so any process reproduces it."""
    if state.override is not None:
        return [int(i) for i in state.override]
    shard = shards[state.dp_rank]
    if not shard:
        return []
    rng = np.random.default_rng((state.seed, state.epoch, state.dp_rank))
    return [shard[int(j)] for j in rng.permutation(len(shard))]


class Prefetcher:
    """Double-buffered background prefetch (compute/IO overlap).

    Unlike its predecessor in ``data/pipeline.py``, an abandoned
    Prefetcher no longer leaks its thread: ``close()`` (also via
    ``with`` or GC) wakes a producer parked on a full queue, joins it,
    and closes the source generator so scan resources unwind."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._err: BaseException | None = None
        self._stop = threading.Event()
        self._src = it
        self._thread = threading.Thread(target=self._run, args=(it,),
                                        daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Bounded put that aborts when the consumer closed us."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _run(self, it):
        try:
            for item in it:
                if not self._put(item):
                    return
        except BaseException as e:  # noqa: BLE001 — re-raised on main thread
            self._err = e
        finally:
            self._put(self._SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        """Unblock and join the producer thread; idempotent."""
        if self._stop.is_set():
            return
        self._stop.set()
        while True:  # drain so a parked producer's next put times out fast
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=10.0)
        closer = getattr(self._src, "close", None)
        if closer is not None:
            try:
                closer()
            except Exception:
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass


def _zero_totals() -> dict:
    return {"fragments_scanned": 0, "client_cpu_s": 0.0, "osd_cpu_s": 0.0,
            "wire_bytes": 0, "rows": 0}


class ShardedReader:
    """One DP rank's checkpointable ingest iterator (see module doc).

    Iterating yields ``{"tokens", "labels"}`` host batches of shape
    ``(local_batch, seq_len)``; ``checkpoint()`` returns the
    :class:`ReaderState` of the last batch *delivered* (not merely
    prefetched), so ``ShardedReader(source, cfg, state=that)`` resumes
    the stream with no gap and no repeat."""

    def __init__(self, source, cfg: ReaderConfig, *, dp_rank: int = 0,
                 dp_size: int = 1, state: ReaderState | None = None):
        if state is not None:
            # the state is authoritative: it pins rank, size and seed to
            # the stream it was cut from
            dp_rank, dp_size = state.dp_rank, state.dp_size
            seed = state.seed
        else:
            seed = cfg.seed
        if not (0 <= dp_rank < dp_size):
            raise ValueError(
                f"bad dp_rank/dp_size: {dp_rank}/{dp_size}")
        self.cfg = cfg
        self.dp_rank, self.dp_size = dp_rank, dp_size
        self.ds = self._pin_snapshot(source, state)
        self.snapshot_id = int(getattr(self.ds, "snapshot_id", -1))
        self.fmt = resolve_format(cfg.format,
                                  decode_backend=cfg.decode_backend)
        self.ctx = self._resolve_ctx(cfg)
        self._plan = self._lower()
        self.tasks = self._plan.tasks
        if state is not None and state.n_tasks >= 0 \
                and state.n_tasks != len(self.tasks):
            raise ValueError(
                f"ReaderState was cut from a {state.n_tasks}-task plan "
                f"but this source lowers to {len(self.tasks)} tasks — "
                "not the same data (snapshot drift or config change)")
        self.shards = partition_tasks(self.tasks, dp_size)
        self.shard = self.shards[dp_rank]
        if state is not None:
            self._state = state.clone()
        else:
            self._state = ReaderState(
                seed=seed, dp_rank=dp_rank, dp_size=dp_size,
                snapshot_id=self.snapshot_id, n_tasks=len(self.tasks))
        self._delivered = self._state.clone()
        self._prefetcher: Prefetcher | None = None
        self._totals = _zero_totals()
        self._live: ScanMetrics | None = None
        self._nbatches = 0

    # -- construction helpers ----------------------------------------------
    @staticmethod
    def _pin_snapshot(source, state: ReaderState | None):
        if not hasattr(source, "as_of"):
            return source  # already an immutable Dataset
        if state is not None and state.snapshot_id >= 0:
            return source.as_of(state.snapshot_id)
        return source.as_of()

    @staticmethod
    def _resolve_ctx(cfg: ReaderConfig) -> TaskContext:
        if isinstance(cfg.tenant, TaskContext):
            return cfg.tenant
        if isinstance(cfg.tenant, str):
            return ingest_context(cfg.registry, tenant=cfg.tenant)
        return ingest_context(cfg.registry)

    def _lower(self) -> PhysicalPlan:
        cfg = self.cfg
        if self.ds.schema is None:  # mutable dataset before first append
            return PhysicalPlan(kind="scan", dataset=self.ds, tasks=[],
                                decisions=[], passes=[])
        q = self.ds.query(format=self.fmt, num_threads=cfg.num_threads,
                          queue_depth=cfg.queue_depth, tenant=self.ctx)
        if cfg.predicate is not None:
            q = q.filter(cfg.predicate)
        return q.select(cfg.column).physical_plan()

    @classmethod
    def for_mesh(cls, source, cfg: ReaderConfig, mesh, *,
                 axis: str = "data", dp_rank: int | None = None,
                 state: ReaderState | None = None) -> "ShardedReader":
        """Shard over a mesh axis: ``dp_size`` is the axis size and
        ``dp_rank`` defaults to this process's position on it."""
        dp_size = int(mesh.shape[axis])
        if dp_rank is None:
            import jax

            dp_rank = jax.process_index() % dp_size
        return cls(source, cfg, dp_rank=dp_rank, dp_size=dp_size,
                   state=state)

    @property
    def shard_tasks(self):
        """This rank's FragmentTasks, plan order."""
        return [self.tasks[i] for i in self.shard]

    # -- the scan plane -----------------------------------------------------
    def _scan(self, order: Sequence[int]) -> Iterator:
        """Stream the tasks named by ``order`` (indices into the
        canonical list) through the shared executor, re-yielded in
        ``order`` — completion order would not be resumable.  A small
        reorder buffer (bounded by ``num_threads``) absorbs the
        difference; the executor still overlaps fragment fetches."""
        if not order:
            return
        tasks = [dataclasses.replace(self.tasks[g], index=i)
                 for i, g in enumerate(order)]
        plan = dataclasses.replace(self._plan, tasks=tasks)
        metrics = ScanMetrics(
            discovery_bytes=self.ds.discovery_bytes,
            fragments_total=len(order),
            tenant=self.ctx.tenant, lane=self.ctx.lane)
        self._live = metrics
        try:
            hold: dict[int, Any] = {}
            nxt = 0
            for task, out in stream_tasks(
                    plan, self.fmt, metrics,
                    max_inflight=self.cfg.num_threads,
                    queue_depth=self.cfg.queue_depth, ctx=self.ctx):
                hold[task.index] = out
                while nxt in hold:
                    yield hold.pop(nxt)
                    nxt += 1
            if metrics.shed is not None:
                raise RuntimeError(f"ingest scan shed: {metrics.shed}")
        finally:
            self._live = None
            self._fold(metrics)

    def _fold(self, metrics: ScanMetrics):
        t = self._totals
        t["fragments_scanned"] += len(metrics.tasks)
        t["client_cpu_s"] += metrics.client_cpu_s
        t["osd_cpu_s"] += metrics.osd_cpu_s
        t["wire_bytes"] += sum(r.wire_bytes for r in metrics.tasks)
        t["rows"] += sum(r.rows_out for r in metrics.tasks)

    # -- the batch plane ----------------------------------------------------
    def _emit(self, st: ReaderState, need: int):
        chunk = st.buffer[:need].reshape(self.cfg.local_batch,
                                         self.cfg.seq_len + 1)
        batch = {"tokens": np.ascontiguousarray(chunk[:, :-1]),
                 "labels": np.ascontiguousarray(chunk[:, 1:])}
        st.buffer = np.array(st.buffer[need:], np.int32, copy=True)
        self._nbatches += 1
        return batch, st.clone()

    def batches(self) -> Iterator[tuple[dict[str, np.ndarray], ReaderState]]:
        """The resumable stream: yields ``(batch, state)`` pairs where
        ``state`` is the exact cut point *after* that batch.  Wrapping
        it in a Prefetcher must not change what ``checkpoint()`` means,
        which is why the state rides alongside each batch instead of
        living on the reader."""
        need = self.cfg.local_batch * (self.cfg.seq_len + 1)
        st = self._state
        # a restored buffer may already hold full batches
        while len(st.buffer) >= need:
            yield self._emit(st, need)
        if not self.shard and st.override is None:
            return  # legal empty shard: rank idles, fleet stays up
        while True:
            order = epoch_order(st, self.shards)
            for tbl in self._scan(order[st.cursor:]):
                toks = np.ascontiguousarray(
                    tbl.column(self.cfg.column).values, np.int32)
                st.cursor += 1
                if len(toks):
                    st.buffer = (np.concatenate([st.buffer, toks])
                                 if len(st.buffer) else toks)
                while len(st.buffer) >= need:
                    yield self._emit(st, need)
            if st.override is not None:
                # elastic remainder drained; fall into normal epochs
                st.override = None
                st.cursor = 0
                if not self.shard:
                    return
            else:
                st.epoch += 1
                st.cursor = 0

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        if self._prefetcher is None:
            self._prefetcher = Prefetcher(self.batches(),
                                          self.cfg.prefetch)
        batch, st = next(self._prefetcher)
        # the checkpointable cut is the last batch the *consumer* saw,
        # not whatever the background thread ran ahead to
        self._delivered = st
        return batch

    # -- checkpoint / lifecycle --------------------------------------------
    def checkpoint(self) -> ReaderState:
        """State of the last delivered batch — save it (``to_arrays()``)
        with the model; restoring replays the stream from right here."""
        return self._delivered.clone()

    def close(self):
        if self._prefetcher is not None:
            self._prefetcher.close()
            self._prefetcher = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- accounting ---------------------------------------------------------
    def stats(self) -> dict:
        d = dict(self._totals)
        live = self._live
        if live is not None:
            recs = list(live.tasks)
            d["fragments_scanned"] += len(recs)
            d["client_cpu_s"] += sum(r.client_cpu_s for r in recs)
            d["osd_cpu_s"] += sum(r.cpu_s for r in recs
                                  if r.where == "osd")
            d["wire_bytes"] += sum(r.wire_bytes for r in recs)
            d["rows"] += sum(r.rows_out for r in recs)
        d["client_cpu_s"] = round(d["client_cpu_s"], 4)
        d["osd_cpu_s"] = round(d["osd_cpu_s"], 4)
        d["batches"] = self._nbatches
        d["epochs"] = self._state.epoch
        return d


def reshard_states(source, cfg: ReaderConfig,
                   states: Sequence[ReaderState],
                   new_dp_size: int) -> list[ReaderState]:
    """Elastic re-shard: given *every* rank's checkpointed state (the
    combined checkpoint always holds all of them) and the post-downsize
    dp_size (``DownsizePlan.axis_size("data")``), produce one state per
    surviving rank such that every not-yet-consumed task of the current
    epoch is covered exactly once across the survivors.

    The remainder is collected per rank with :func:`epoch_order` (so a
    rank mid-epoch contributes exactly its unconsumed tail), re-balanced
    with the same :func:`~repro.dataset.plan.partition_tasks` used for
    epoch sharding, and handed out as explicit ``override`` orders.
    Dead ranks' packing-buffer remainders are adopted by
    ``old_rank % new_dp_size`` instead of being dropped.  After the
    overrides drain, every survivor falls into epoch
    ``max(epochs) + 1`` under the normal new-dp_size sharding."""
    if not states:
        raise ValueError("reshard_states needs at least one ReaderState")
    if new_dp_size <= 0:
        raise ValueError(f"new_dp_size must be >= 1, got {new_dp_size}")
    states = sorted(states, key=lambda s: s.dp_rank)
    first = states[0]
    old_dp, seed, snap = first.dp_size, first.seed, first.snapshot_id
    for s in states:
        if (s.dp_size, s.seed, s.snapshot_id) != (old_dp, seed, snap):
            raise ValueError(
                "reshard_states: states disagree on dp_size/seed/"
                "snapshot — not one job's checkpoint")
    if sorted(s.dp_rank for s in states) != list(range(old_dp)):
        raise ValueError(
            f"reshard_states needs all {old_dp} ranks' states, got ranks "
            f"{sorted(s.dp_rank for s in states)}")

    # one probe reader pins the snapshot and lowers the canonical plan
    probe = ShardedReader(source, cfg, state=first)
    try:
        tasks, shards = probe.tasks, probe.shards
        n_tasks = len(tasks)
    finally:
        probe.close()

    pending: list[int] = []
    for s in states:
        pending.extend(epoch_order(s, shards)[s.cursor:])
    assignment = partition_tasks([tasks[i] for i in pending], new_dp_size)
    next_epoch = max(s.epoch for s in states) + 1

    adopted: list[list[np.ndarray]] = [[] for _ in range(new_dp_size)]
    for s in states:
        if len(s.buffer):
            adopted[s.dp_rank % new_dp_size].append(
                np.asarray(s.buffer, np.int32))

    out = []
    for r in range(new_dp_size):
        bufs = adopted[r]
        buffer = (np.concatenate(bufs).astype(np.int32) if bufs
                  else np.empty(0, np.int32))
        override = np.asarray([pending[i] for i in assignment[r]],
                              np.int64)
        out.append(ReaderState(
            seed=seed, dp_rank=r, dp_size=new_dp_size, epoch=next_epoch,
            cursor=0, snapshot_id=snap, n_tasks=n_tasks, buffer=buffer,
            override=override))
    return out
