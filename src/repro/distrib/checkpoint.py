"""Checkpointing *into the paper's object store*.

The training state is itself stored the way the paper stores Parquet data:
each pytree leaf becomes a CephFS file striped over RADOS objects (so big
leaves parallelize across OSDs and inherit 3-way replication/failover), and
a JSON manifest — the footer analogue — carries the tree keys, shapes,
dtypes and CRCs.  Restore reads leaves in parallel through
DirectObjectAccess-backed range reads and re-shards onto whatever mesh the
restoring job runs — which is what makes elastic downsize (lose a node,
shrink the data axis, reload) a checkpoint round-trip.
"""

from __future__ import annotations

import json
import re
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any

import jax
import numpy as np

from repro.storage.cephfs import CephFS

STRIPE = 4 * 1024 * 1024


class _AnyLeaf:
    """Restore-struct placeholder accepting any shape/dtype — for
    variable-length leaves (e.g. a reader's packing-buffer remainder)
    whose saved shape cannot be known before the manifest is read."""

    __slots__ = ()

    def __repr__(self):
        return "ANY_SHAPE"


#: Put this in a ``restore()`` structs pytree where an exact
#: shape/dtype template is impossible; the leaf restores to whatever
#: the checkpoint holds (CRC still verified).
ANY_SHAPE = _AnyLeaf()


def _leaf_name(path) -> str:
    key = jax.tree_util.keystr(path)
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", key).strip("_") or "root"


class CheckpointManager:
    def __init__(self, fs: CephFS, prefix: str = "/ckpt", *, keep: int = 3,
                 threads: int = 8):
        self.fs = fs
        self.prefix = prefix.rstrip("/")
        self.keep = keep
        self.threads = threads
        self._async: threading.Thread | None = None

    # -- naming -----------------------------------------------------------------
    def _dir(self, step: int) -> str:
        return f"{self.prefix}/step_{step:010d}"

    def _manifest_path(self, step: int) -> str:
        return f"{self._dir(step)}/MANIFEST.json"

    def steps(self) -> list[int]:
        out = []
        for p in self.fs.listdir(self.prefix):
            m = re.match(rf"{re.escape(self.prefix)}/step_(\d+)/MANIFEST"
                         r"\.json$", p)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------------
    def save(self, state: Any, step: int) -> dict:
        """Synchronous save; returns the manifest dict."""
        flat, _ = jax.tree_util.tree_flatten_with_path(state)
        d = self._dir(step)
        entries = []

        def write_one(item):
            path_keys, leaf = item
            arr = np.asarray(jax.device_get(leaf))
            data = arr.tobytes()
            fpath = f"{d}/{_leaf_name(path_keys)}.bin"
            self.fs.write_file(fpath, data, stripe_unit=STRIPE)
            return {"key": jax.tree_util.keystr(path_keys), "file": fpath,
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "crc": zlib.crc32(data), "bytes": len(data)}

        with ThreadPoolExecutor(max_workers=self.threads) as pool:
            entries = list(pool.map(write_one, flat))

        manifest = {"step": step, "leaves": entries,
                    "format": "repro-ckpt-v1"}
        # manifest written last = commit point
        self.fs.write_file(self._manifest_path(step),
                           json.dumps(manifest).encode())
        self._gc()
        return manifest

    def save_async(self, state: Any, step: int) -> threading.Thread:
        """Fire-and-forget save on a background thread (overlaps the next
        train steps).  Arrays are snapshotted to host before returning so
        donated buffers can be reused immediately."""
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self.wait()
        t = threading.Thread(target=self.save, args=(host_state, step),
                             daemon=True)
        t.start()
        self._async = t
        return t

    def wait(self):
        if self._async is not None:
            self._async.join()
            self._async = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            d = self._dir(s)
            for p in list(self.fs.listdir(d)):
                self.fs.unlink(p)

    # -- restore ----------------------------------------------------------------
    def restore(self, structs: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Parallel restore into the shape of ``structs``; if ``shardings``
        is given every leaf is device_put with it — restoring onto a
        *different* mesh than the one that saved is the elastic-resume
        path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints")
        manifest = json.loads(self.fs.read_file(self._manifest_path(step)))
        by_key = {e["key"]: e for e in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(structs)

        def read_one(item):
            path_keys, struct = item
            key = jax.tree_util.keystr(path_keys)
            if key not in by_key:
                raise KeyError(f"checkpoint missing leaf {key}")
            e = by_key[key]
            data = self.fs.read_file(e["file"])
            if zlib.crc32(data) != e["crc"]:
                raise IOError(f"CRC mismatch restoring {key}")
            arr = np.frombuffer(data, np.dtype(e["dtype"])).reshape(
                e["shape"])
            if isinstance(struct, _AnyLeaf):
                return arr
            if tuple(arr.shape) != tuple(struct.shape) or \
                    arr.dtype != struct.dtype:
                raise ValueError(
                    f"{key}: checkpoint {arr.shape}/{arr.dtype} vs "
                    f"expected {struct.shape}/{struct.dtype}")
            return arr

        with ThreadPoolExecutor(max_workers=self.threads) as pool:
            arrays = list(pool.map(read_one, flat))
        state = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state
