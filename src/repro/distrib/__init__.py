from repro.distrib.checkpoint import CheckpointManager
from repro.distrib.elastic import (DownsizePlan, HealthMonitor,
                                   InsufficientDevicesError, build_mesh,
                                   elastic_downsize, plan_downsize,
                                   remesh_state)

__all__ = ["CheckpointManager", "DownsizePlan", "HealthMonitor",
           "InsufficientDevicesError", "build_mesh", "elastic_downsize",
           "plan_downsize", "remesh_state"]
