from repro.distrib.checkpoint import ANY_SHAPE, CheckpointManager
from repro.distrib.elastic import (DownsizePlan, HealthMonitor,
                                   InsufficientDevicesError, build_mesh,
                                   elastic_downsize, plan_downsize,
                                   remesh_state)

__all__ = ["ANY_SHAPE", "CheckpointManager", "DownsizePlan", "HealthMonitor",
           "InsufficientDevicesError", "build_mesh", "elastic_downsize",
           "plan_downsize", "remesh_state"]
