"""Elastic scaling + health: survive node loss without losing the run.

``HealthMonitor`` is the heartbeat registry (hosts report in; silence past
the timeout marks a host dead).  ``plan_downsize`` picks the largest viable
mesh after losses — the data axis shrinks (it carries DP replicas; dropping
replicas is semantically free modulo batch size), the model axis is fixed
(it carries weight shards).  ``remesh_state`` re-shards a live state pytree
onto the new mesh; the equivalent cold path is a CheckpointManager.restore
with the new shardings.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding import AxisRules, tree_shardings


class InsufficientDevicesError(RuntimeError):
    pass


class HealthMonitor:
    """Heartbeat table for host liveness (coordinator side)."""

    def __init__(self, hosts: Sequence[int], timeout_s: float = 30.0):
        now = time.monotonic()
        self.timeout_s = timeout_s
        self._last: dict[int, float] = {h: now for h in hosts}
        self._marked_down: set[int] = set()

    def heartbeat(self, host: int, now: float | None = None):
        if host in self._marked_down:
            return  # must rejoin explicitly
        self._last[host] = time.monotonic() if now is None else now

    def mark_down(self, host: int):
        self._marked_down.add(host)

    def rejoin(self, host: int, now: float | None = None):
        self._marked_down.discard(host)
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        dead = {h for h, t in self._last.items()
                if now - t > self.timeout_s}
        return sorted(dead | self._marked_down)

    def healthy_hosts(self, now: float | None = None) -> list[int]:
        dead = set(self.dead_hosts(now))
        return sorted(h for h in self._last if h not in dead)


@dataclasses.dataclass(frozen=True)
class DownsizePlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    devices_kept: int

    @property
    def changed(self) -> bool:
        return self.old_shape != self.new_shape

    def axis_size(self, name: str) -> int:
        """Post-downsize size of one mesh axis — e.g. the new dp_size
        that ``repro.ingest.reshard_states`` re-partitions readers to."""
        return self.new_shape[self.axis_names.index(name)]


def plan_downsize(mesh: Mesh, healthy_devices: int, *,
                  shrink_axis: str = "data") -> DownsizePlan:
    """Largest mesh that fits the healthy device count by shrinking only
    ``shrink_axis`` (keep it a power of two so batch/FSDP divisibility
    survives)."""
    names = tuple(mesh.axis_names)
    shape = tuple(int(mesh.shape[n]) for n in names)
    idx = names.index(shrink_axis)
    others = int(np.prod([s for i, s in enumerate(shape) if i != idx]))
    max_shrink = healthy_devices // others
    if max_shrink < 1:
        raise InsufficientDevicesError(
            f"{healthy_devices} devices cannot host model axes {others}")
    new_size = 1 << (max_shrink.bit_length() - 1)   # floor pow2
    new_size = min(new_size, shape[idx])
    new_shape = tuple(new_size if i == idx else s
                      for i, s in enumerate(shape))
    return DownsizePlan(shape, new_shape, names,
                        int(np.prod(new_shape)))


def build_mesh(devices: Sequence, shape: tuple[int, ...],
               axis_names: tuple[str, ...]) -> Mesh:
    """Mesh over an explicit device subset (the survivors)."""
    need = int(np.prod(shape))
    if len(devices) < need:
        raise InsufficientDevicesError(f"need {need}, have {len(devices)}")
    arr = np.asarray(devices[:need], dtype=object).reshape(shape)
    return Mesh(arr, axis_names)


def remesh_state(state: Any, spec_tree: Any, new_mesh: Mesh,
                 rules: AxisRules) -> Any:
    """Live resharding of a state pytree onto a new mesh."""
    shardings = tree_shardings(new_mesh, rules, state, spec_tree)
    return jax.device_put(state, shardings)


def elastic_downsize(state: Any, spec_tree: Any, mesh: Mesh,
                     rules: AxisRules, healthy_devices: Sequence, *,
                     shrink_axis: str = "data"):
    """One-call node-loss recovery: plan, rebuild mesh, re-shard.

    Returns (new_mesh, new_state, plan).  The caller re-makes its jitted
    train step against the new mesh (shardings changed) and scales its
    per-rank batch so the global batch is preserved or documented.
    """
    plan = plan_downsize(mesh, len(healthy_devices), shrink_axis=shrink_axis)
    new_mesh = build_mesh(list(healthy_devices), plan.new_shape,
                          plan.axis_names)
    new_state = remesh_state(state, spec_tree, new_mesh, rules)
    return new_mesh, new_state, plan
