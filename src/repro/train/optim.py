"""AdamW with cosine schedule, global-norm clipping, and optional int8
moment quantization (block-wise absmax) for the >=70B configs.

Moment quantization is a distributed-optimization memory trick: m/v are
stored as int8 + a per-row fp32 scale (last dim kept fp32-accurate via the
row granularity), cutting optimizer HBM by ~3.5x. Dequant/requant happens
inside the (jit'd) update, so the fp32 values never persist.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # or "int8"


def schedule(opt: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = opt.peak_lr * step / max(1, opt.warmup_steps)
    frac = jnp.clip((step - opt.warmup_steps)
                    / max(1, opt.decay_steps - opt.warmup_steps), 0.0, 1.0)
    cos = opt.min_lr + 0.5 * (opt.peak_lr - opt.min_lr) * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < opt.warmup_steps, warm, cos)


# -- int8 moment codec -------------------------------------------------------


def _quant(x):
    """Per-row (leading-dims) absmax int8 quantization."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequant(qv):
    return qv["q"].astype(jnp.float32) * qv["scale"]


def _moment_zeros(p, quantized: bool):
    if quantized and p.ndim >= 1 and p.shape[-1] >= 4:
        return {"q": jnp.zeros(p.shape, jnp.int8),
                "scale": jnp.zeros((*p.shape[:-1], 1), jnp.float32)}
    return jnp.zeros(p.shape, jnp.float32)


def _moment_read(mv):
    if isinstance(mv, dict):
        return _dequant(mv)
    return mv


def _moment_write(mv, x):
    if isinstance(mv, dict):
        return _quant(x)
    return x.astype(jnp.float32)


def _is_moment(x):
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def init_opt_state(params, opt: OptConfig):
    quant = opt.moment_dtype == "int8"
    m = jax.tree.map(lambda p: _moment_zeros(p, quant), params)
    v = jax.tree.map(lambda p: _moment_zeros(p, quant), params)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def moment_specs(param_specs, moments):
    """Logical-spec tree matching a moment tree.  Quantized leaves become
    {"q": param_spec, "scale": param_spec minus the last (rowwise) dim}."""

    def one(spec, mv):
        if _is_moment(mv):
            spec = tuple(spec) if spec else ()
            lead = spec[:-1] if len(spec) else ()
            return {"q": spec, "scale": lead + (None,)}
        return spec

    return jax.tree.map(one, param_specs, moments,
                        is_leaf=lambda x: x is None or isinstance(x, tuple))


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(opt: OptConfig, params, grads, state):
    count = state["count"] + 1
    lr = schedule(opt, count)
    gnorm = global_norm(grads)
    scale_g = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-12))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])

    b1, b2 = opt.b1, opt.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * scale_g
        m_f = _moment_read(m) * b1 + (1 - b1) * g
        v_f = _moment_read(v) * b2 + (1 - b2) * jnp.square(g)
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + opt.eps)
        decay = opt.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) * (1 - lr * decay) - lr * upd
        new_p.append(p_new.astype(p.dtype))
        new_m.append(_moment_write(m, m_f))
        new_v.append(_moment_write(v, v_f))

    params = jax.tree.unflatten(treedef, new_p)
    state = {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "count": count}
    return params, state, {"lr": lr, "grad_norm": gnorm}
