"""Train/serve step factories with full sharding metadata.

``build_train_artifacts`` returns everything the launcher and the dry-run
need: abstract state, in/out shardings, and the jit'd step — without ever
materializing parameters (jax.eval_shape end to end).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import scanner
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api as model_api
from repro.models import lm
from repro.sharding import AxisRules, ShardingCtx, tree_shardings
from repro.train import optim


def batch_struct(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for one global batch of this (arch, shape)."""
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    return out


def batch_specs(cfg: ModelConfig, mesh, rules: AxisRules, structs):
    from repro.sharding import resolve_spec

    out = {}
    for k, v in structs.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, resolve_spec(mesh, rules, logical,
                                                  v.shape))
    return out


# ---------------------------------------------------------------------------


def abstract_state(cfg: ModelConfig, opt: optim.OptConfig):
    """(state_structs, state_spec_tree) without allocating anything."""
    param_shapes, specs = _static_specs(cfg)

    def build(params):
        return {"params": params, "opt": optim.init_opt_state(params, opt),
                "step": jnp.zeros((), jnp.int32)}

    structs = jax.eval_shape(build, param_shapes)
    state_specs = {
        "params": specs,
        "opt": {"m": optim.moment_specs(specs, structs["opt"]["m"]),
                "v": optim.moment_specs(specs, structs["opt"]["v"]),
                "count": None},
        "step": None,
    }
    return structs, state_specs


@functools.lru_cache(maxsize=32)
def _static_specs_cached(cfg: ModelConfig):
    # Specs are plain python data built during tracing; capture them via a
    # closure side-effect so eval_shape only sees the array pytree.
    box = {}

    def run(key):
        params, specs = lm.init_params(cfg, key)
        box["specs"] = specs
        return params

    shapes = jax.eval_shape(run, jax.random.key(0))
    return shapes, box["specs"]


def _static_specs(cfg: ModelConfig):
    return _static_specs_cached(cfg)


def init_state(cfg: ModelConfig, opt: optim.OptConfig, key, mesh=None,
               rules=None):
    """Concrete (small-config) state init, optionally sharded."""
    params, specs = lm.init_params(cfg, key)
    state = {"params": params, "opt": optim.init_opt_state(params, opt),
             "step": jnp.zeros((), jnp.int32)}
    return state, specs


def make_train_step(cfg: ModelConfig, mesh, rules: AxisRules,
                    opt: optim.OptConfig, num_microbatches: int = 1):
    ctx = ShardingCtx(mesh, rules)

    def train_step(state, batch):
        params = state["params"]

        def loss_fn(p, b):
            return model_api.train_loss(cfg, ctx, p, b)

        if num_microbatches > 1:
            def micro(p, b):
                bs = jax.tree.map(
                    lambda x: x.reshape(num_microbatches,
                                        x.shape[0] // num_microbatches,
                                        *x.shape[1:]), b)

                def acc_fn(carry, mb):
                    l, g = jax.value_and_grad(loss_fn)(p, mb)
                    return (carry[0] + l,
                            jax.tree.map(jnp.add, carry[1], g)), None

                zero = (jnp.zeros(()),
                        jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                     p))
                (l, g), _ = scanner.scan(acc_fn, zero, bs)
                n = float(num_microbatches)
                return l / n, jax.tree.map(lambda x: x / n, g)

            loss, grads = micro(params, batch)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        new_params, opt_state, mets = optim.adamw_update(
            opt, params, grads, state["opt"])
        new_state = {"params": new_params, "opt": opt_state,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **mets}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh, rules: AxisRules):
    ctx = ShardingCtx(mesh, rules)

    def prefill_step(params, batch):
        return model_api.prefill(cfg, ctx, params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh, rules: AxisRules):
    ctx = ShardingCtx(mesh, rules)

    def decode_step(params, cache, tokens, pos):
        return model_api.decode_step(cfg, ctx, params, cache, tokens, pos)

    return decode_step


# ---------------------------------------------------------------------------
# Sharding assembly for the launcher / dry-run
# ---------------------------------------------------------------------------


def state_shardings(cfg: ModelConfig, opt: optim.OptConfig, mesh,
                    rules: AxisRules):
    structs, spec_tree = abstract_state(cfg, opt)
    shardings = tree_shardings(mesh, rules, structs, spec_tree)
    return structs, shardings


def serve_param_structs(cfg: ModelConfig):
    """bf16 parameter structs for serving (params are cast for decode)."""
    shapes, specs = _static_specs(cfg)
    dt = jnp.dtype(cfg.compute_dtype)

    def cast(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, dt)
        return s

    return jax.tree.map(cast, shapes), specs
