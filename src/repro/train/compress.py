"""int8 error-feedback gradient compression for the cross-pod reduce.

Multi-pod data parallelism pays one gradient all-reduce per step across
the DCN (25 GB/s vs 50 GB/s/link ICI).  Quantizing the cross-pod leg to
int8 cuts its wire bytes 4x vs f32 (2x vs bf16); the quantization residual
is carried forward per leaf and re-added next step (error feedback), which
keeps SGD/Adam convergence — the residual is bounded, so the *averaged*
gradient bias vanishes (Karimireddy et al., 2019).

Mechanics (inside a shard_map over the pod axis):
    t   = grad + err                 # fp32 accumulate with carried error
    q   = clip(round(t / scale), ±127).astype(int8);  scale = absmax/127
    wire: all_gather(q) + all_gather(scale)   # int8 on the DCN
    out = mean_pods(dequant(q))      # exact given the quantized operands
    err'= t - dequant(q)             # next step's carry

``ef_allreduce_tree`` applies this leaf-wise; ``make_compressed_grad_fn``
wraps a loss into a pod-sharded gradient function with the error state
threaded through the optimizer state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import shard_map


def _quantize(t):
    absmax = jnp.max(jnp.abs(t))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_allreduce(g, err, axis: str):
    """One leaf: int8-compressed mean over ``axis`` + new error carry.
    Runs inside shard_map; wire traffic is the int8 all_gather."""
    t = g.astype(jnp.float32) + err
    q, scale = _quantize(t)
    qg = jax.lax.all_gather(q, axis)                   # (n, ...) int8 wire
    sg = jax.lax.all_gather(scale, axis)               # (n,) f32 (tiny)
    deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * g.ndim)
    mean = jnp.mean(deq, axis=0)
    new_err = t - q.astype(jnp.float32) * scale
    return mean.astype(g.dtype), new_err


def ef_allreduce_tree(grads, errs, axis: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = ef_allreduce(g, e, axis)
        out_g.append(m)
        out_e.append(ne)
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_e))


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_grad_fn(loss_fn, mesh, *, axis: str = "pod"):
    """(params, batch, err) -> (loss, grads, err') with the cross-``axis``
    gradient reduction int8-compressed.

    params replicated over ``axis``; batch sharded over it (pure DP across
    pods).  Within-pod sharding stays with pjit around this function.
    """

    def per_pod(params, batch, err):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, err = ef_allreduce_tree(grads, err, axis)
        loss = jax.lax.pmean(loss, axis)
        return loss, grads, err

    batch_spec = jax.tree.map(lambda _: P(axis), {"tokens": 0, "labels": 0})
    return shard_map(
        per_pod, mesh=mesh,
        in_specs=(P(), batch_spec, P()),
        out_specs=(P(), P(), P()),
        check_vma=False)
