"""Logical-axis sharding rules with divisibility fallback.

Parameters and activations are annotated with *logical* axis names
("embed", "heads", "mlp", ...).  A :class:`AxisRules` maps logical names to
mesh axis names.  ``shard_hint`` applies a ``with_sharding_constraint`` but
silently drops any mesh axis that does not divide the corresponding dim —
this single mechanism is what lets all 40 (arch x shape) dry-run cells lower
on the fixed production meshes without per-cell hand tuning (e.g. gemma3's
4 query heads simply fall back to replicated on a 16-way "model" axis while
its mlp dim still tensor-shards).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.4.38 exposes shard_map at the top level
    shard_map = jax.shard_map
except AttributeError:  # older jax: experimental namespace; the replica
    # check kwarg is spelled check_rep there instead of check_vma
    from jax.experimental.shard_map import shard_map as _shard_map_compat

    def shard_map(f, /, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map_compat(f, **kwargs)

# ---------------------------------------------------------------------------
# Logical axis vocabulary
# ---------------------------------------------------------------------------
# batch      global batch dim of activations
# seq        sequence dim of activations inside a block (replicated)
# sp_seq     sequence dim of the residual stream *between* blocks
#            (Megatron-style sequence parallelism: sharded over "model")
# kv_seq     sequence dim of KV caches / KV activations (context parallel)
# embed      model width d_model (FSDP axis for weights)
# heads      query heads;  kv_heads  KV heads;  head  head_dim
# mlp        FFN hidden;   vocab     vocabulary
# expert     MoE expert dim (EP);  expert_mlp  FFN hidden inside EP experts
# ssm_heads  Mamba2 heads; ssm_state  SSD state dim; conv  conv channels
# layers     stacked-scan layer dim (never sharded)

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "sp_seq": ("model",),
    "kv_seq": ("model",),
    "embed": ("data",),  # FSDP within a pod; pod axis only sees grad AR
    "heads": ("model",),
    "kv_heads": ("model",),
    "head": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "expert_mlp": (),
    "ssm_heads": ("model",),
    "ssm_state": (),
    "conv": ("model",),
    "layers": (),
    "stats": (),
}


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Maps logical axis names -> candidate mesh axes (in priority order)."""

    rules: Mapping[str, tuple[str, ...]]

    def mesh_axes(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        if logical not in self.rules:
            raise KeyError(f"unknown logical axis {logical!r}")
        return tuple(self.rules[logical])

    def replace(self, **updates: tuple[str, ...]) -> "AxisRules":
        merged = dict(self.rules)
        merged.update(updates)
        return AxisRules(merged)


def default_rules(**overrides: tuple[str, ...]) -> AxisRules:
    return AxisRules(dict(DEFAULT_RULES)).replace(**overrides)


# ---------------------------------------------------------------------------
# Spec resolution with divisibility fallback
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.shape else 1


def resolve_spec(
    mesh: Mesh,
    rules: AxisRules,
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
) -> P:
    """Logical axes -> PartitionSpec, dropping non-dividing mesh axes.

    Each mesh axis may be used at most once across the whole spec (a
    PartitionSpec invariant); earlier dims win.
    """
    if len(logical_axes) != len(shape):
        raise ValueError(
            f"logical axes {logical_axes} do not match shape {shape}")
    used: set[str] = set()
    parts: list[Any] = []
    for logical, dim in zip(logical_axes, shape):
        candidates = [a for a in rules.mesh_axes(logical)
                      if a not in used and a in mesh.shape]
        chosen: list[str] = []
        size = 1
        for axis in candidates:
            nxt = size * _axis_size(mesh, axis)
            if nxt == 0 or dim % nxt != 0:
                continue
            chosen.append(axis)
            size = nxt
        used.update(chosen)
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1 and len(candidates) == 1:
            # a product rule keeps tuple form even when one factor fits
            # (identical semantics; stable across PartitionSpec equality
            # behaviour of different jax versions)
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    return P(*parts)


def named_sharding(
    mesh: Mesh,
    rules: AxisRules,
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(mesh, rules, logical_axes, shape))


class ShardingCtx:
    """Carries (mesh, rules) through model code; used by shard_hint."""

    def __init__(self, mesh: Mesh, rules: AxisRules | None = None):
        self.mesh = mesh
        self.rules = rules or default_rules()

    def spec(self, logical_axes: Sequence[str | None], shape: Sequence[int]) -> P:
        return resolve_spec(self.mesh, self.rules, logical_axes, shape)

    def hint(self, x: jax.Array, *logical_axes: str | None) -> jax.Array:
        """with_sharding_constraint with divisibility fallback."""
        spec = self.spec(logical_axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
# Param-tree sharding: params carry a parallel tree of logical-axis tuples
# ---------------------------------------------------------------------------

def tree_shardings(mesh: Mesh, rules: AxisRules, params: Any, specs: Any):
    """Build a NamedSharding pytree for ``params`` from logical ``specs``.

    ``specs`` mirrors ``params`` but leaves are tuples of logical names (or
    None for replicated).  Works on ShapeDtypeStructs or concrete arrays.
    """

    def one(p, s):
        if s is None:
            return NamedSharding(mesh, P())
        return named_sharding(mesh, rules, s, p.shape)

    return jax.tree.map(one, params, specs,
                        is_leaf=lambda x: x is None or isinstance(x, tuple))


def tree_pspecs(mesh: Mesh, rules: AxisRules, params: Any, specs: Any):
    def one(p, s):
        if s is None:
            return P()
        return resolve_spec(mesh, rules, s, p.shape)

    return jax.tree.map(one, params, specs,
                        is_leaf=lambda x: x is None or isinstance(x, tuple))


def bytes_per_device(mesh: Mesh, rules: AxisRules, params: Any, specs: Any) -> int:
    """Estimate parameter bytes resident per device under the rules."""
    total = 0
    flat_p, treedef = jax.tree.flatten(params)
    flat_s = treedef.flatten_up_to(specs)
    for p, s in zip(flat_p, flat_s):
        shard = 1
        if s is not None:
            spec = resolve_spec(mesh, rules, s, p.shape)
            for entry in spec:
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                for a in axes:
                    shard *= _axis_size(mesh, a)
        total += int(np.prod(p.shape)) * p.dtype.itemsize // shard
    return total
