"""Shared model building blocks: init helpers, norms, RoPE, losses.

Parameters are plain pytrees (nested dicts of arrays).  Every init function
returns ``(params, specs)`` where ``specs`` mirrors ``params`` with leaves
that are tuples of *logical* axis names (see repro.sharding).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def trunc_normal(key, shape, std, dtype):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


class Initializer:
    """Accumulates (params, specs) pairs with a splitting PRNG key."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype

    def split(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, shape, axes, std=None):
        fan_in = shape[0] if len(shape) else 1
        if std is None:
            std = 1.0 / np.sqrt(max(1, fan_in))
        return trunc_normal(self.split(), shape, std, self.dtype), _ax(axes)

    def embed(self, shape, axes, std=0.02):
        return trunc_normal(self.split(), shape, std, self.dtype), _ax(axes)

    def zeros(self, shape, axes):
        return jnp.zeros(shape, self.dtype), _ax(axes)

    def ones(self, shape, axes):
        return jnp.ones(shape, self.dtype), _ax(axes)

    def const(self, value, axes):
        return jnp.asarray(value, self.dtype), _ax(axes)


def _ax(axes):
    return None if axes is None else tuple(axes)


def split_tree(tree):
    """Split a nested {name: (param, spec)} structure (dicts/lists) into
    parallel (params, specs) structures."""
    if isinstance(tree, dict):
        params, specs = {}, {}
        for name, value in tree.items():
            params[name], specs[name] = split_tree(value)
        return params, specs
    if isinstance(tree, list):
        pairs = [split_tree(v) for v in tree]
        return [p[0] for p in pairs], [p[1] for p in pairs]
    param, spec = tree
    return param, spec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_inv_freq(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def rope_table(seq_len: int, head_dim: int, theta: float):
    """(seq, head_dim/2) cos/sin tables, fp32."""
    inv = rope_inv_freq(head_dim, theta)
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.einsum("s,d->sd", t, inv.astype(jnp.float32))
    return jnp.cos(ang), jnp.sin(ang)


def rope_at(pos, head_dim: int, theta: float):
    """cos/sin at integer positions ``pos`` (any shape) -> (*pos, head_dim/2)."""
    inv = rope_inv_freq(head_dim, theta).astype(jnp.float32)
    ang = pos.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (seq, head_dim/2) or
    broadcastable (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, hd/2) -> broadcast over batch & heads
        cos = cos[:, None, :]
        sin = sin[:, None, :]
    else:  # (..., S, hd/2): add heads axis
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / losses
# ---------------------------------------------------------------------------

def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def cross_entropy(logits, labels, ignore_index: int = -1):
    """Mean CE over non-ignored positions.

    logits: (B, S, V) (possibly vocab-sharded); labels: (B, S) int32.
    Uses one-hot contraction (SPMD-friendly with a sharded vocab dim).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - picked
    mask = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
