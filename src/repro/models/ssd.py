"""Mamba2 / SSD (state-space duality) mixer — arXiv:2405.21060.

Chunked SSD algorithm: within a chunk the recurrence is computed as masked
attention-like einsums (dual form); across chunks a lax.scan carries the
(B, H, P, N) state.  State math in fp32.

Shapes: x (B, L, H, P); dt (B, L, H); A (H,) (negative); B_, C (B, L, G, N)
with G groups broadcast over heads.  Decode keeps (state, conv_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import scanner


def segsum(x):
    """Stable 'segment sum': cumulative sums over all (i<=j) segments.

    x: (..., Q) -> (..., Q, Q) with out[..., i, j] = sum_{k in (j, i]} x[k]
    for i >= j, -inf elsewhere.
    """
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, a, b, c, *, chunk: int, d_skip=None):
    """Chunked SSD forward over a full sequence.

    Returns (y, final_state).  x (B,L,H,P), dt (B,L,H) (softplus'd, >0),
    a (H,) negative reals, b/c (B,L,G,N).
    """
    bsz, seqlen, nheads, pdim = x.shape
    ngroups, nstate = b.shape[2], b.shape[3]
    if seqlen % chunk:
        raise ValueError(f"seq {seqlen} not divisible by chunk {chunk}")
    nc = seqlen // chunk
    rep = nheads // ngroups

    f32 = jnp.float32
    xd = (x.astype(f32) * dt.astype(f32)[..., None])          # dt-weighted input
    da = dt.astype(f32) * a.astype(f32)[None, None, :]        # (B,L,H) log-decay

    # reshape into chunks: (B, C, Q, ...)
    def chunked(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:])

    xc, dac = chunked(xd), chunked(da)
    bc = jnp.repeat(chunked(b.astype(f32)), rep, axis=3)      # (B,C,Q,H,N)
    cc = jnp.repeat(chunked(c.astype(f32)), rep, axis=3)

    # --- intra-chunk (dual / attention-like form) ---------------------------
    seg = segsum(jnp.moveaxis(dac, -1, 2))                    # (B,C,H,Q,Q)
    ell = jnp.exp(seg)
    scores = jnp.einsum("bcqhn,bcshn->bchqs", cc, bc) * jnp.moveaxis(ell, 2, 2)
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", scores, xc)

    # --- chunk states --------------------------------------------------------
    cum = jnp.cumsum(dac, axis=2)                             # (B,C,Q,H)
    total = cum[:, :, -1:, :]                                 # (B,C,1,H)
    decay_to_end = jnp.exp(total - cum)                       # (B,C,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", bc, decay_to_end, xc)

    # --- inter-chunk scan ----------------------------------------------------
    chunk_decay = jnp.exp(total[:, :, 0, :])                  # (B,C,H)

    def body(carry, xs):
        st_in = carry                                         # (B,H,P,N)
        s_c, dec = xs                                         # (B,H,P,N), (B,H)
        st_out = st_in * dec[:, :, None, None] + s_c
        return st_out, st_in

    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    init = jnp.zeros((bsz, nheads, pdim, nstate), f32)
    final_state, prev_states = scanner.scan(body, init, xs)
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (B,C,H,P,N)

    # --- inter-chunk contribution -------------------------------------------
    decay_from_start = jnp.exp(cum)                           # (B,C,Q,H)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         cc, decay_from_start, prev_states)

    y = (y_intra + y_inter).reshape(bsz, seqlen, nheads, pdim)
    if d_skip is not None:
        y = y + d_skip.astype(f32)[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), final_state


def ssd_decode(state, x_t, dt_t, a, b_t, c_t, *, d_skip=None):
    """Single-token SSD update.

    state (B,H,P,N) fp32; x_t (B,H,P); dt_t (B,H); b_t/c_t (B,G,N).
    Returns (y_t (B,H,P), new_state).
    """
    f32 = jnp.float32
    nheads = x_t.shape[1]
    rep = nheads // b_t.shape[1]
    b_t = jnp.repeat(b_t.astype(f32), rep, axis=1)            # (B,H,N)
    c_t = jnp.repeat(c_t.astype(f32), rep, axis=1)
    da = jnp.exp(dt_t.astype(f32) * a.astype(f32)[None, :])   # (B,H)
    xd = x_t.astype(f32) * dt_t.astype(f32)[..., None]        # (B,H,P)
    new_state = state * da[:, :, None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xd, b_t)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_t)
    if d_skip is not None:
        y = y + d_skip.astype(f32)[None, :, None] * x_t.astype(f32)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv (width w): y[t] = sum_i w[i] * x[t - (w-1) + i]
# ---------------------------------------------------------------------------

def causal_conv(x, weight, bias):
    """x (B, L, C); weight (W, C); bias (C,).  Shift-and-add form."""
    w = weight.shape[0]
    f32 = jnp.float32
    y = jnp.zeros_like(x, dtype=f32)
    for i in range(w):
        shift = w - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + xi.astype(f32) * weight[i].astype(f32)
    y = y + bias.astype(f32)
    return jax.nn.silu(y).astype(x.dtype)


def causal_conv_decode(conv_state, x_t, weight, bias):
    """conv_state (B, W-1, C) holds the previous W-1 inputs.

    Returns (y_t (B, C), new_conv_state).
    """
    f32 = jnp.float32
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", full.astype(f32), weight.astype(f32))
    y = jax.nn.silu(y + bias.astype(f32)).astype(x_t.dtype)
    return y, full[:, 1:, :]
