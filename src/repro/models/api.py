"""Top-level model API: train_loss / prefill / decode_step / init_cache.

All functions are pure and pjit-friendly; layer stacks run under lax.scan
with jax.checkpoint (remat) for the large archs, unrolled for the small or
heterogeneous ones (gemma3 local/global, zamba2 shared-attention hybrid).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import scanner

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.common import cross_entropy, rms_norm, rope_table
from repro.sharding import ShardingCtx


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _sinusoid(seq: int, d: int):
    # traced (not a baked HLO constant: at 32k x d this would bloat the IR)
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed(cfg, params, tokens, dtype):
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    return x


def _unembed(cfg, params, h):
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, w.astype(h.dtype))
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))


# ---------------------------------------------------------------------------
# Full-sequence backbone (train & prefill)
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, ctx: ShardingCtx, params, tokens, *,
            collect: bool, patches=None, frames=None, chunked=False):
    """Returns (hidden (B,S,d) post-final-norm, cache pytree or None)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    p = lm._cast(params, dtype)
    b, s = tokens.shape
    x = _embed(cfg, p, tokens, dtype)
    x = ctx.hint(x, "batch", "sp_seq", None)
    fam = cfg.family

    rope = rope_table(s, cfg.head_dim, cfg.rope_theta) if cfg.num_heads else None
    win = cfg.sliding_window

    def slice_window(kv, w):
        if w and s > w:
            k, v = kv
            return (k[..., s - w:, :], v[..., s - w:, :],
                    jnp.arange(s - w, s, dtype=jnp.int32))
        k, v = kv
        return (k, v, jnp.arange(s, dtype=jnp.int32))

    cache: Any = None

    if fam in ("dense", "moe") and cfg.scan_layers:
        plan = lm.layer_plan(cfg)
        kinds = sorted(set(plan))

        def block(kind, x, lp):
            x, kv = lm.attn_block(cfg, ctx, lp["attn"], x, rope=rope,
                                  window=win, chunked=chunked, return_kv=True)
            kv = (ctx.hint(kv[0], "batch", "kv_heads", "kv_seq", "head"),
                  ctx.hint(kv[1], "batch", "kv_heads", "kv_seq", "head"))
            if kind == "moe":
                x = lm.moe_block(cfg, ctx, lp["moe"], x)
            else:
                x = lm.mlp_block(cfg, ctx, lp["mlp"], x)
            return x, kv

        if len(kinds) == 1:
            body = _maybe_remat(cfg, functools.partial(block, plan[0]))

            def scan_body(carry, lp):
                y, kv = body(carry, lp)
                return y, (kv if collect else None)

            x, kvs = scanner.scan(scan_body, x, p["stack"])
            if collect:
                k, v, slot = slice_window(
                    (kvs[0], kvs[1]), win)
                cache = {"k": k, "v": v, "slot_pos": slot}
        else:  # llama4: (dense, moe) groups
            body_a = _maybe_remat(cfg, functools.partial(block, plan[0]))
            body_b = _maybe_remat(cfg, functools.partial(block, plan[1]))

            def scan_body(carry, lps):
                pa, pb = lps
                y, kv_a = body_a(carry, pa)
                y, kv_b = body_b(y, pb)
                return y, ((kv_a, kv_b) if collect else None)

            x, kvs = scanner.scan(scan_body, x, (p["stack_a"], p["stack_b"]))
            if collect:
                ka, va, slot = slice_window(kvs[0], win)
                kb, vb, _ = slice_window(kvs[1], win)
                cache = {"k_a": ka, "v_a": va, "k_b": kb, "v_b": vb,
                         "slot_pos": slot}

    elif fam == "dense" and not cfg.scan_layers:  # gemma3: unrolled 5:1
        tables = {}
        layer_caches = []
        for i, lp in enumerate(p["layers"]):
            w_i = lm.layer_window(cfg, i)
            th = lm.layer_theta(cfg, i)
            if th not in tables:
                tables[th] = rope_table(s, cfg.head_dim, th)

            def one(x, lp=lp, w_i=w_i, th=th):
                return lm.attn_block(cfg, ctx, lp["attn"], x, rope=tables[th],
                                     window=w_i, chunked=chunked,
                                     return_kv=True)

            x, kv = _maybe_remat(cfg, one)(x)
            x = _maybe_remat(cfg, lambda x, lp=lp: lm.mlp_block(
                cfg, ctx, lp["mlp"], x))(x)
            if collect:
                k, v, slot = slice_window(kv, w_i)
                layer_caches.append({"k": k, "v": v, "slot_pos": slot})
        if collect:
            cache = layer_caches

    elif fam == "ssm":
        body = _maybe_remat(
            cfg, lambda x, lp: lm.mamba_block(cfg, ctx, lp["mamba"], x,
                                              return_state=collect))

        def scan_body(carry, lp):
            out = body(carry, lp)
            if collect:
                return out[0], out[1]
            return out, None

        x, states = scanner.scan(scan_body, x, p["stack"])
        if collect:
            cache = states

    elif fam == "hybrid":
        shared = p["shared"]
        layer_caches = []
        attn_caches = []
        for i, lp in enumerate(p["layers"]):
            out = _maybe_remat(
                cfg, lambda x, lp=lp: lm.mamba_block(
                    cfg, ctx, lp["mamba"], x, return_state=collect))(x)
            if collect:
                x, st = out
                layer_caches.append(st)
            else:
                x = out
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0:
                x, kv = _maybe_remat(
                    cfg, lambda x: lm.attn_block(
                        cfg, ctx, shared["attn"], x, rope=rope, window=0,
                        chunked=chunked, return_kv=True))(x)
                x = _maybe_remat(cfg, lambda x: lm.mlp_block(
                    cfg, ctx, shared["mlp"], x))(x)
                if collect:
                    k, v, slot = slice_window(kv, 0)
                    attn_caches.append({"k": k, "v": v, "slot_pos": slot})
        if collect:
            cache = {"mamba": layer_caches, "attn": attn_caches}

    elif fam == "encdec":
        enc = p["encoder"]
        eseq = frames.shape[1]
        f = frames.astype(dtype) + _sinusoid(eseq, cfg.d_model).astype(dtype)
        f = ctx.hint(f, "batch", "sp_seq", None)

        def enc_body(carry, lp):
            y = lm.attn_block(cfg, ctx, lp["attn"], carry, rope=None,
                              window=0, causal=False, chunked=False)
            y = lm.mlp_block(cfg, ctx, lp["mlp"], y)
            return y, None

        f, _ = scanner.scan(_maybe_remat(cfg, enc_body), f, enc["stack"])
        enc_out = rms_norm(f, enc["norm"], cfg.norm_eps)

        x = x + _sinusoid(s, cfg.d_model).astype(dtype)

        def dec_body(carry, lp):
            y, kv = lm.attn_block(cfg, ctx, lp["attn"], carry, rope=None,
                                  window=0, chunked=chunked, return_kv=True)
            y, xkv = lm.attn_block(cfg, ctx, lp["xattn"], y, rope=None,
                                   kv_source=enc_out, return_kv=True)
            y = lm.mlp_block(cfg, ctx, lp["mlp"], y)
            out = ((kv, xkv) if collect else None)
            return y, out

        x, kvs = scanner.scan(_maybe_remat(cfg, dec_body), x, p["stack"])
        if collect:
            (k, v), (xk, xv) = kvs
            cache = {"k": k, "v": v,
                     "slot_pos": jnp.arange(s, dtype=jnp.int32),
                     "cross_k": xk, "cross_v": xv}

    elif fam == "vlm":
        img = patches.astype(dtype)
        img = ctx.hint(img, "batch", None, None)

        def self_body(carry, lp):
            y, kv = lm.attn_block(cfg, ctx, lp["attn"], carry, rope=rope,
                                  window=win, chunked=chunked, return_kv=True)
            y = lm.mlp_block(cfg, ctx, lp["mlp"], y)
            kv = (ctx.hint(kv[0], "batch", "kv_heads", "kv_seq", "head"),
                  ctx.hint(kv[1], "batch", "kv_heads", "kv_seq", "head"))
            return y, (kv if collect else None)

        def group_body(carry, lps):
            ps_self, ps_cross = lps
            y, kvs = scanner.scan(self_body, carry, ps_self)
            y, xkv = lm.attn_block(cfg, ctx, ps_cross["attn"], y, rope=None,
                                   kv_source=img, gated=True, return_kv=True)
            y = lm.mlp_block(cfg, ctx, ps_cross["mlp"], y)
            return y, ((kvs, xkv) if collect else None)

        x, ys = scanner.scan(_maybe_remat(cfg, group_body), x,
                             (p["stack_self"], p["stack_cross"]))
        if collect:
            (k, v), (xk, xv) = ys
            cache = {"k": k, "v": v,
                     "slot_pos": jnp.arange(s, dtype=jnp.int32),
                     "cross_k": xk, "cross_v": xv}
    else:
        raise ValueError(fam)

    h = rms_norm(x, p["final_norm"], cfg.norm_eps)
    return h, cache


# ---------------------------------------------------------------------------
# Train / prefill / decode entry points
# ---------------------------------------------------------------------------


def train_loss(cfg, ctx, params, batch):
    h, _ = forward(cfg, ctx, params, batch["tokens"], collect=False,
                   patches=batch.get("patches"), frames=batch.get("frames"),
                   chunked=cfg.train_chunked)
    logits = _unembed(cfg, params, h)
    logits = ctx.hint(logits, "batch", "seq", "vocab")
    return cross_entropy(logits, batch["labels"])


def prefill(cfg, ctx, params, batch):
    chunked = batch["tokens"].shape[1] >= 8192
    h, cache = forward(cfg, ctx, params, batch["tokens"], collect=True,
                       patches=batch.get("patches"),
                       frames=batch.get("frames"), chunked=chunked)
    last = h[:, -1:, :]
    logits = _unembed(cfg, params, last)[:, 0]
    logits = ctx.hint(logits, "batch", "vocab")
    return logits, cache


def decode_step(cfg, ctx, params, cache, tokens, pos):
    """tokens (B, 1) int32; pos scalar int32 (uniform batch position)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    p = lm._cast(params, dtype)
    x = _embed(cfg, p, tokens[:, 0], dtype)[:, None, :]
    fam = cfg.family
    win = cfg.sliding_window

    if fam in ("dense", "moe") and cfg.scan_layers:
        plan = lm.layer_plan(cfg)
        kinds = sorted(set(plan))

        def block(kind, x, lp, c):
            x, nc = lm.attn_block_decode(cfg, ctx, lp["attn"], x, c, pos,
                                         window=win)
            if kind == "moe":
                x = lm.moe_block_decode(cfg, ctx, lp["moe"], x)
            else:
                x = lm.mlp_block_decode(cfg, ctx, lp["mlp"], x)
            return x, nc

        if len(kinds) == 1:
            def scan_body(carry, xs):
                lp, ck, cv = xs
                c = {"k": ck, "v": cv, "slot_pos": cache["slot_pos"]}
                y, nc = block(plan[0], carry, lp, c)
                return y, (nc["k"], nc["v"])

            x, (ks, vs) = scanner.scan(
                scan_body, x, (p["stack"], cache["k"], cache["v"]))
            size = cache["k"].shape[3]
            slot = jnp.where(jnp.asarray(win, jnp.int32) > 0, pos % size,
                             jnp.minimum(pos, size - 1))
            new_slot = jnp.where(jnp.arange(size) == slot, pos,
                                 cache["slot_pos"])
            cache = {"k": ks, "v": vs, "slot_pos": new_slot}
        else:
            def scan_body(carry, xs):
                pa, pb, ka, va, kb, vb = xs
                y, nca = block(plan[0], carry,
                               pa, {"k": ka, "v": va,
                                    "slot_pos": cache["slot_pos"]})
                y, ncb = block(plan[1], y,
                               pb, {"k": kb, "v": vb,
                                    "slot_pos": cache["slot_pos"]})
                return y, (nca["k"], nca["v"], ncb["k"], ncb["v"])

            x, (ka, va, kb, vb) = scanner.scan(
                scan_body, x, (p["stack_a"], p["stack_b"],
                               cache["k_a"], cache["v_a"],
                               cache["k_b"], cache["v_b"]))
            size = cache["k_a"].shape[3]
            slot = jnp.where(jnp.asarray(win, jnp.int32) > 0, pos % size,
                             jnp.minimum(pos, size - 1))
            new_slot = jnp.where(jnp.arange(size) == slot, pos,
                                 cache["slot_pos"])
            cache = {"k_a": ka, "v_a": va, "k_b": kb, "v_b": vb,
                     "slot_pos": new_slot}

    elif fam == "dense" and not cfg.scan_layers:
        new_caches = []
        for i, (lp, c) in enumerate(zip(p["layers"], cache)):
            w_i = lm.layer_window(cfg, i)
            th = lm.layer_theta(cfg, i)
            x, nc = lm.attn_block_decode(cfg, ctx, lp["attn"], x, c, pos,
                                         window=w_i, theta=th)
            x = lm.mlp_block_decode(cfg, ctx, lp["mlp"], x)
            new_caches.append(nc)
        cache = new_caches

    elif fam == "ssm":
        def scan_body(carry, xs):
            lp, c = xs
            y, nc = lm.mamba_block_decode(cfg, ctx, lp["mamba"], carry, c)
            return y, nc

        x, cache = scanner.scan(scan_body, x, (p["stack"], cache))

    elif fam == "hybrid":
        shared = p["shared"]
        new_m, new_a = [], []
        ai = 0
        for i, (lp, c) in enumerate(zip(p["layers"], cache["mamba"])):
            x, nc = lm.mamba_block_decode(cfg, ctx, lp["mamba"], x, c)
            new_m.append(nc)
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0 \
                    and ai < len(cache["attn"]):
                x, nac = lm.attn_block_decode(cfg, ctx, shared["attn"], x,
                                              cache["attn"][ai], pos, window=0)
                x = lm.mlp_block_decode(cfg, ctx, shared["mlp"], x)
                new_a.append(nac)
                ai += 1
        cache = {"mamba": new_m, "attn": new_a}

    elif fam in ("encdec", "vlm"):
        if fam == "encdec":
            # sinusoidal absolute positional encoding at `pos` (no RoPE)
            x = x + _pos_at(pos, cfg.d_model).astype(dtype)

            def scan_body(carry, xs):
                lp, ck, cv, xk, xv = xs
                c = {"k": ck, "v": cv, "slot_pos": cache["slot_pos"]}
                y, nc = lm.attn_block_decode(cfg, ctx, lp["attn"], carry, c,
                                             pos, window=0, use_rope=False)
                y, _ = lm.attn_block_decode(cfg, ctx, lp["xattn"], y, None,
                                            pos, cross_cache={"k": xk,
                                                              "v": xv})
                y = lm.mlp_block_decode(cfg, ctx, lp["mlp"], y)
                return y, (nc["k"], nc["v"])

            x, (ks, vs) = scanner.scan(
                scan_body, x, (p["stack"], cache["k"], cache["v"],
                               cache["cross_k"], cache["cross_v"]))
        else:  # vlm: groups of 4 self + 1 cross
            def self_body(carry, xs):
                lp, ck, cv = xs
                c = {"k": ck, "v": cv, "slot_pos": cache["slot_pos"]}
                y, nc = lm.attn_block_decode(cfg, ctx, lp["attn"], carry, c,
                                             pos, window=win)
                y = lm.mlp_block_decode(cfg, ctx, lp["mlp"], y)
                return y, (nc["k"], nc["v"])

            def group_body(carry, xs):
                ps_self, ps_cross, ck, cv, xk, xv = xs
                y, kv = scanner.scan(self_body, carry, (ps_self, ck, cv))
                y, _ = lm.attn_block_decode(cfg, ctx, ps_cross["attn"], y,
                                            None, pos,
                                            cross_cache={"k": xk, "v": xv},
                                            gated=True)
                y = lm.mlp_block_decode(cfg, ctx, ps_cross["mlp"], y)
                return y, kv

            x, (ks, vs) = scanner.scan(
                group_body, x, (p["stack_self"], p["stack_cross"],
                                cache["k"], cache["v"],
                                cache["cross_k"], cache["cross_v"]))
        size = cache["k"].shape[-2]
        new_slot = jnp.where(jnp.arange(size) == jnp.minimum(pos, size - 1),
                             pos, cache["slot_pos"])
        cache = dict(cache, k=ks, v=vs, slot_pos=new_slot)
    else:
        raise ValueError(fam)

    h = rms_norm(x, p["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, p, h)[:, 0]
    logits = ctx.hint(logits, "batch", "vocab")
    return logits, cache


def _pos_at(pos, d):
    i = jnp.arange(d // 2)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def pad_cache(cache, headroom: int):
    """Add decode headroom to the KV caches collected by ``prefill``.

    Prefill emits exactly prompt-length caches; decoding N new tokens needs
    N free slots (``decode_attention`` masks them via slot_pos = -1 until
    written).  Applies to every {k*, v*, slot_pos} group in the cache
    pytree; cross-attention caches (fixed source length) and SSM states
    (no slots) are untouched.  Ring (sliding-window) caches must NOT be
    padded — their slot arithmetic is pos % size with size == window; the
    serving engine only pads full-attention caches.
    """
    if headroom <= 0:
        return cache
    if isinstance(cache, list):
        return [pad_cache(c, headroom) for c in cache]
    if not isinstance(cache, dict):
        return cache
    if "slot_pos" not in cache:
        return {k: pad_cache(v, headroom) for k, v in cache.items()}
    out = {}
    for k, v in cache.items():
        if k == "slot_pos":
            out[k] = jnp.pad(v, (0, headroom), constant_values=-1)
        elif (k.startswith("k") or k.startswith("v")) \
                and not k.startswith(("cross", "k_cross", "v_cross")):
            pad = [(0, 0)] * v.ndim
            pad[-2] = (0, headroom)
            out[k] = jnp.pad(v, pad)
        else:
            out[k] = v
    return out


# ---------------------------------------------------------------------------
# Cache construction (decode dry-run + real decode)
# ---------------------------------------------------------------------------


def _kv_struct(cfg, lead, batch, size, concrete):
    dtype = jnp.dtype(cfg.compute_dtype)
    shape_k = (*lead, batch, cfg.num_kv_heads, size, cfg.head_dim)
    spec = ("layers",) * len(lead) + ("batch", "kv_heads", "kv_seq", "head")
    if concrete:
        return jnp.zeros(shape_k, dtype), spec
    return jax.ShapeDtypeStruct(shape_k, dtype), spec


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               concrete: bool = False):
    """Build the decode cache pytree and its logical-spec pytree."""
    dtype = jnp.dtype(cfg.compute_dtype)
    fam = cfg.family
    win = cfg.sliding_window

    def arr(shape, spec, dt=dtype, fill=0):
        if concrete:
            return (jnp.full(shape, fill, dt), spec)
        return (jax.ShapeDtypeStruct(shape, dt), spec)

    def slot(size):
        if concrete:
            init = jnp.where(jnp.arange(size) < seq_len - 1,
                             jnp.arange(size), -1).astype(jnp.int32)
            return (init, ("kv_seq",))
        return (jax.ShapeDtypeStruct((size,), jnp.int32), ("kv_seq",))

    def kv_size(w):
        return min(w, seq_len) if w > 0 else seq_len

    if fam in ("dense", "moe") and cfg.scan_layers:
        plan = lm.layer_plan(cfg)
        kinds = sorted(set(plan))
        size = kv_size(win)
        if len(kinds) == 1:
            tree = {
                "k": _kv_struct(cfg, (cfg.num_layers,), batch, size, concrete),
                "v": _kv_struct(cfg, (cfg.num_layers,), batch, size, concrete),
                "slot_pos": slot(size),
            }
        else:
            n = cfg.num_layers // 2
            tree = {
                "k_a": _kv_struct(cfg, (n,), batch, size, concrete),
                "v_a": _kv_struct(cfg, (n,), batch, size, concrete),
                "k_b": _kv_struct(cfg, (n,), batch, size, concrete),
                "v_b": _kv_struct(cfg, (n,), batch, size, concrete),
                "slot_pos": slot(size),
            }
    elif fam == "dense":
        tree = []
        for i in range(cfg.num_layers):
            size = kv_size(lm.layer_window(cfg, i))
            tree.append({
                "k": _kv_struct(cfg, (), batch, size, concrete),
                "v": _kv_struct(cfg, (), batch, size, concrete),
                "slot_pos": slot(size),
            })
    elif fam == "ssm":
        tree = _ssm_cache(cfg, cfg.num_layers, batch, concrete)
    elif fam == "hybrid":
        per = _ssm_cache(cfg, None, batch, concrete)
        n_attn = cfg.num_layers // cfg.attn_every
        tree = {
            "mamba": [dict(per) for _ in range(cfg.num_layers)],
            "attn": [{
                "k": _kv_struct(cfg, (), batch, kv_size(0), concrete),
                "v": _kv_struct(cfg, (), batch, kv_size(0), concrete),
                "slot_pos": slot(kv_size(0)),
            } for _ in range(n_attn)],
        }
    elif fam == "encdec":
        l = cfg.num_layers
        tree = {
            "k": _kv_struct(cfg, (l,), batch, seq_len, concrete),
            "v": _kv_struct(cfg, (l,), batch, seq_len, concrete),
            "slot_pos": slot(seq_len),
            "cross_k": _kv_struct(cfg, (l,), batch, cfg.encoder_seq, concrete),
            "cross_v": _kv_struct(cfg, (l,), batch, cfg.encoder_seq, concrete),
        }
    elif fam == "vlm":
        ng = cfg.num_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        tree = {
            "k": _kv_struct(cfg, (ng, per), batch, seq_len, concrete),
            "v": _kv_struct(cfg, (ng, per), batch, seq_len, concrete),
            "slot_pos": slot(seq_len),
            "cross_k": _kv_struct(cfg, (ng,), batch, cfg.num_image_tokens,
                                  concrete),
            "cross_v": _kv_struct(cfg, (ng,), batch, cfg.num_image_tokens,
                                  concrete),
        }
    else:
        raise ValueError(fam)

    return _split(tree)


def _ssm_cache(cfg, layers, batch, concrete):
    f32 = jnp.float32
    dtype = jnp.dtype(cfg.compute_dtype)
    lead = (layers,) if layers else ()
    lspec = ("layers",) if layers else ()
    w = cfg.ssm_conv_width
    gn = cfg.ssm_ngroups * cfg.ssm_state

    def arr(shape, spec, dt):
        if concrete:
            return (jnp.zeros(shape, dt), spec)
        return (jax.ShapeDtypeStruct(shape, dt), spec)

    return {
        "state": arr((*lead, batch, cfg.ssm_nheads, cfg.ssm_headdim,
                      cfg.ssm_state),
                     lspec + ("batch", "ssm_heads", None, None), f32),
        "conv_x": arr((*lead, batch, w - 1, cfg.d_inner),
                      lspec + ("batch", None, "mlp"), dtype),
        "conv_B": arr((*lead, batch, w - 1, gn),
                      lspec + ("batch", None, None), dtype),
        "conv_C": arr((*lead, batch, w - 1, gn),
                      lspec + ("batch", None, None), dtype),
    }


def _split(tree):
    """Split nested {name: (leaf, spec)} (with lists) into two trees."""
    if isinstance(tree, dict):
        a, b = {}, {}
        for k, v in tree.items():
            a[k], b[k] = _split(v)
        return a, b
    if isinstance(tree, list):
        pairs = [_split(v) for v in tree]
        return [p[0] for p in pairs], [p[1] for p in pairs]
    leaf, spec = tree
    return leaf, spec
