"""Attention primitives: full masked, flash-chunked (prefill), and decode.

Conventions:
  q          (B, Sq, KV, G, hd)   G = query heads per KV head (GQA groups)
  k, v       (B, Skv, KV, hd)
  caches     (B, KV, S, hd)       seq-dim laid out for context sharding
  positions  int32; window <= 0 means full attention

All softmax math is fp32.  Under pjit, attention over a context-sharded
cache turns into flash-decode automatically: the max/sum reductions over the
sharded seq dim lower to all-reduces (verified in the dry-run HLO).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import scanner

NEG_INF = -1e30


def _mask_bias(q_pos, kv_pos, window, causal: bool):
    """(Sq, Skv) additive bias from positions; window is a traced scalar."""
    q = q_pos[:, None].astype(jnp.int32)
    k = kv_pos[None, :].astype(jnp.int32)
    valid = jnp.ones(q.shape[:1] + k.shape[1:], dtype=bool)
    if causal:
        valid = k <= q
    w = jnp.asarray(window, jnp.int32)
    in_window = jnp.where(w > 0, (q - k) < w, True)
    valid = valid & in_window
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def split_gqa(q, num_kv):
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def merge_gqa(o):
    b, s, kv, g, d = o.shape
    return o.reshape(b, s, kv * g, d)


def full_attention(q, k, v, q_pos, kv_pos, *, window=0, causal=True, scale,
                   score_hint=None):
    """Masked softmax attention, materialized scores.  Used when Sq is small
    enough (training at 4k; smoke tests).

    score_hint: optional callback hinting the (B, KV*G, Sq, Skv) score
    layout — with GQA the KV dim alone often cannot shard a 16-way model
    axis (e.g. 8 kv heads), leaving the score tensor replicated; merging
    (KV, G) lets the full head product shard (§Perf tuned_hints)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k, precision=jax.lax.Precision.DEFAULT)
    s = s.astype(jnp.float32) * scale
    s = s + _mask_bias(q_pos, kv_pos, window, causal)[None, None, None]
    if score_hint is not None:
        b_, kvh, g, sq_, skv_ = s.shape
        s = score_hint(s.reshape(b_, kvh * g, sq_, skv_)).reshape(s.shape)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return o


def chunked_attention(q, k, v, q_pos, kv_pos, *, window=0, causal=True,
                      scale, chunk=1024, score_hint=None):
    """Flash-style online-softmax attention, scanning KV chunks.

    Bounds the transient score tensor to (B,KV,G,Sq,chunk); used for the
    32k-prefill shapes.  Inference-only path (scan carries would bloat AD).
    score_hint: see full_attention — applied per KV chunk.
    """
    b, skv, kv_h, hd = k.shape
    sq = q.shape[1]
    if skv % chunk:
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-(10 ** 9))
        skv += pad
    n = skv // chunk
    g = q.shape[3]

    kc = jnp.moveaxis(k.reshape(b, n, chunk, kv_h, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n, chunk, kv_h, hd), 1, 0)
    pc = kv_pos.reshape(n, chunk)

    m0 = jnp.full((b, kv_h, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv_h, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv_h, g, sq, hd), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, k_i).astype(jnp.float32) * scale
        s = s + _mask_bias(q_pos, p_i, window, causal)[None, None, None]
        if score_hint is not None:
            bb, kvh, gg, sq_, ck = s.shape
            s = score_hint(s.reshape(bb, kvh * gg, sq_, ck)).reshape(s.shape)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v_i.dtype), v_i).astype(jnp.float32)
        return (m_new, l, acc), None

    (m, l, acc), _ = scanner.scan(body, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(o, 3, 1).astype(q.dtype)  # (B,Sq,KV,G,hd)


def decode_attention(q, k_cache, v_cache, slot_pos, pos, *, window=0, scale):
    """One-token attention over a cache.

    q: (B, KV, G, hd); caches (B, KV, S, hd); slot_pos (S,) int32 giving the
    absolute position stored in each slot (-1 = empty; ring buffers reuse
    slots).  pos: scalar int32 current position (the query's position).
    """
    s = jnp.einsum("bkgd,bksd->bkgs", q, k_cache).astype(jnp.float32) * scale
    w = jnp.asarray(window, jnp.int32)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    valid = valid & jnp.where(w > 0, (pos - slot_pos) < w, True)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o


def cache_write(k_cache, v_cache, k_new, v_new, slot):
    """Masked one-hot write of one token into slot ``slot`` (traced scalar).

    SPMD-friendly on a seq-sharded cache: a pure elementwise select, no
    dynamic-update-slice (which would force resharding of the cache).
    k_new/v_new: (B, KV, hd).
    """
    s = k_cache.shape[2]
    hit = (jax.lax.iota(jnp.int32, s) == slot)[None, None, :, None]
    k_cache = jnp.where(hit, k_new[:, :, None, :].astype(k_cache.dtype), k_cache)
    v_cache = jnp.where(hit, v_new[:, :, None, :].astype(v_cache.dtype), v_cache)
    return k_cache, v_cache
