"""Unified LM covering all 10 assigned architectures.

Families:
  dense   — GQA transformer (phi4, gemma3, qwen2, starcoder2)
  moe     — mixtral (every-layer MoE, TP experts), llama4 (alt-layer MoE, EP)
  ssm     — mamba2 (SSD)
  hybrid  — zamba2 (mamba2 backbone + weight-shared attention block)
  encdec  — whisper (stub frame embeddings)
  vlm     — llama-3.2-vision (1 gated cross-attn layer per 5)

Parameters are (params, specs) pytrees; specs leaves are logical-axis tuples
consumed by repro.sharding.  Layer stacks use lax.scan with jax.checkpoint
(large archs) or are unrolled (small/heterogeneous: gemma3, zamba2).

Modes:
  train_loss(cfg, ctx, params, batch) -> scalar loss
  prefill(cfg, ctx, params, batch)    -> (last_logits, cache)
  decode_step(cfg, ctx, params, cache, tokens, pos) -> (logits, cache)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssd
from repro.models.common import (Initializer, apply_rope, gelu,
                                 rms_norm, rope_at, split_tree, swiglu)
from repro.sharding import shard_map

# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def _attn_params(ini: Initializer, cfg: ModelConfig, *, cross=False):
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    t = {
        "norm": ini.zeros((d,), ("embed",)),
        "wq": ini.dense((d, h, hd), ("embed", "heads", "head")),
        "wk": ini.dense((d, kv, hd), ("embed", "kv_heads", "head")),
        "wv": ini.dense((d, kv, hd), ("embed", "kv_heads", "head")),
        "wo": ini.dense((h, hd, d), ("heads", "head", "embed"),
                        std=1.0 / np.sqrt(h * hd * 2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        t["bq"] = ini.zeros((h, hd), ("heads", "head"))
        t["bk"] = ini.zeros((kv, hd), ("kv_heads", "head"))
        t["bv"] = ini.zeros((kv, hd), ("kv_heads", "head"))
    if cfg.qk_norm:
        t["q_norm"] = ini.zeros((hd,), ("head",))
        t["k_norm"] = ini.zeros((hd,), ("head",))
    if cross:
        t["gate"] = ini.zeros((), None)  # tanh-gated cross-attn (llama3.2)
        t["kv_norm"] = ini.zeros((d,), ("embed",))
    return t


def _mlp_params(ini: Initializer, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    t = {
        "norm": ini.zeros((d,), ("embed",)),
        "wi": ini.dense((d, f), ("embed", "mlp")),
        "wo": ini.dense((f, d), ("mlp", "embed"),
                        std=1.0 / np.sqrt(f * 2 * cfg.num_layers)),
    }
    if cfg.act == "swiglu":
        t["wg"] = ini.dense((d, f), ("embed", "mlp"))
    return t


def _moe_params(ini: Initializer, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ep = cfg.moe_partition == "expert"
    w_axes = ("expert", "embed", None) if ep else (None, "embed", "mlp")
    o_axes = ("expert", "mlp", "embed") if ep else (None, "mlp", "embed")
    # EP keeps d_ff unsharded; TP shards d_ff over "model".
    if ep:
        o_axes = ("expert", None, "embed")
    t = {
        "norm": ini.zeros((d,), ("embed",)),
        "router": ini.dense((d, e), ("embed", "expert"), std=0.02),
        "wi": ini.dense((e, d, f), w_axes, std=1.0 / np.sqrt(d)),
        "wg": ini.dense((e, d, f), w_axes, std=1.0 / np.sqrt(d)),
        "wo": ini.dense((e, f, d), o_axes,
                        std=1.0 / np.sqrt(f * 2 * cfg.num_layers)),
    }
    return t


def _mamba_params(ini: Initializer, cfg: ModelConfig):
    d = cfg.d_model
    di, gn, h = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state, cfg.ssm_nheads
    w = cfg.ssm_conv_width
    t = {
        "norm": ini.zeros((d,), ("embed",)),
        "wz": ini.dense((d, di), ("embed", "mlp")),
        "wx": ini.dense((d, di), ("embed", "mlp")),
        "wB": ini.dense((d, gn), ("embed", None)),
        "wC": ini.dense((d, gn), ("embed", None)),
        "wdt": ini.dense((d, h), ("embed", "ssm_heads")),
        "conv_x_w": ini.dense((w, di), (None, "mlp"), std=0.3),
        "conv_x_b": ini.zeros((di,), ("mlp",)),
        "conv_B_w": ini.dense((w, gn), (None, None), std=0.3),
        "conv_B_b": ini.zeros((gn,), (None,)),
        "conv_C_w": ini.dense((w, gn), (None, None), std=0.3),
        "conv_C_b": ini.zeros((gn,), (None,)),
        "A_log": ini.const(np.log(np.linspace(1.0, 16.0, h)), ("ssm_heads",)),
        "D": ini.ones((h,), ("ssm_heads",)),
        "dt_bias": ini.const(np.log(np.expm1(np.linspace(1e-3, 0.1, h))),
                             ("ssm_heads",)),
        "gnorm": ini.zeros((di,), ("mlp",)),
        "wout": ini.dense((di, d), ("mlp", "embed"),
                          std=1.0 / np.sqrt(di * 2 * cfg.num_layers)),
    }
    return t


def _block_params(ini, cfg, kind: str):
    if kind == "dense":
        return {"attn": _attn_params(ini, cfg), "mlp": _mlp_params(ini, cfg)}
    if kind == "moe":
        return {"attn": _attn_params(ini, cfg), "moe": _moe_params(ini, cfg)}
    if kind == "mamba":
        return {"mamba": _mamba_params(ini, cfg)}
    if kind == "cross":
        return {"attn": _attn_params(ini, cfg, cross=True),
                "mlp": _mlp_params(ini, cfg)}
    if kind == "encoder":
        return {"attn": _attn_params(ini, cfg), "mlp": _mlp_params(ini, cfg)}
    if kind == "decoder":  # whisper decoder layer: self + cross + mlp
        return {"attn": _attn_params(ini, cfg),
                "xattn": _attn_params(ini, cfg, cross=True),
                "mlp": _mlp_params(ini, cfg)}
    raise ValueError(kind)


def _stack(trees):
    """Stack a list of (param,spec) trees along a new leading 'layers' dim."""

    def is_leaf(x):
        return isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], dict)

    out = {}
    first = trees[0]
    for name in first:
        if isinstance(first[name], dict):
            out[name] = _stack([t[name] for t in trees])
        else:
            arrs = jnp.stack([t[name][0] for t in trees])
            spec = ("layers",) + tuple(first[name][1] or ())
            out[name] = (arrs, spec)
    return out


def init_params(cfg: ModelConfig, key: jax.Array):
    """Returns (params, specs).  Use jax.eval_shape for the full configs."""
    dtype = jnp.dtype(cfg.param_dtype)
    ini = Initializer(key, dtype)
    d = cfg.d_model
    tree: dict[str, Any] = {
        "embed": ini.embed((cfg.vocab_size, d), ("vocab", "embed")),
        "final_norm": ini.zeros((d,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ini.dense((d, cfg.vocab_size), ("embed", "vocab"))

    fam = cfg.family
    if fam in ("dense", "moe"):
        plan = layer_plan(cfg)
        if cfg.scan_layers:
            kinds = sorted(set(plan))
            if len(kinds) == 1:
                tree["stack"] = _stack(
                    [_block_params(ini, cfg, plan[0]) for _ in plan])
            else:  # llama4 [dense, moe] alternation: one stack per kind
                n = len(plan) // len(kinds)
                tree["stack_a"] = _stack(
                    [_block_params(ini, cfg, plan[0]) for _ in range(n)])
                tree["stack_b"] = _stack(
                    [_block_params(ini, cfg, plan[1]) for _ in range(n)])
        else:
            tree["layers"] = [
                _block_params(ini, cfg, k) for k in plan]
    elif fam == "ssm":
        tree["stack"] = _stack(
            [_block_params(ini, cfg, "mamba") for _ in range(cfg.num_layers)])
    elif fam == "hybrid":
        tree["layers"] = [
            _block_params(ini, cfg, "mamba") for _ in range(cfg.num_layers)]
        tree["shared"] = {"attn": _attn_params(ini, cfg),
                          "mlp": _mlp_params(ini, cfg)}
    elif fam == "encdec":
        tree["encoder"] = {
            "stack": _stack([_block_params(ini, cfg, "encoder")
                             for _ in range(cfg.encoder_layers)]),
            "norm": ini.zeros((d,), ("embed",)),
        }
        tree["stack"] = _stack([_block_params(ini, cfg, "decoder")
                                for _ in range(cfg.num_layers)])
    elif fam == "vlm":
        n_group = cfg.num_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        self_layers = [_block_params(ini, cfg, "dense")
                       for _ in range(n_group * per)]
        stacked = _stack(self_layers)
        stacked = _tree_reshape(stacked, (n_group, per))
        tree["stack_self"] = stacked
        tree["stack_cross"] = _stack(
            [_block_params(ini, cfg, "cross") for _ in range(n_group)])
    else:
        raise ValueError(fam)
    return split_tree(tree)


def _tree_reshape(tree, lead):
    out = {}
    for k, v in tree.items():
        if isinstance(v, dict):
            out[k] = _tree_reshape(v, lead)
        else:
            arr, spec = v
            out[k] = (arr.reshape(lead + arr.shape[1:]),
                      ("layers",) + tuple(spec))
    return out


def layer_plan(cfg: ModelConfig) -> list[str]:
    if cfg.family == "moe" and cfg.moe_layer_freq > 1:
        plan = []
        for i in range(cfg.num_layers):
            plan.append("moe" if i % cfg.moe_layer_freq == cfg.moe_layer_freq - 1
                        else "dense")
        return plan
    if cfg.family == "moe":
        return ["moe"] * cfg.num_layers
    return ["dense"] * cfg.num_layers


def layer_window(cfg: ModelConfig, i: int) -> int:
    """Sliding window for layer i (0 = full attention)."""
    if cfg.local_global_ratio > 0:
        # pattern: n local then 1 global, repeating (gemma3: 5:1)
        return 0 if (i % (cfg.local_global_ratio + 1)
                     == cfg.local_global_ratio) else cfg.sliding_window
    return cfg.sliding_window


def layer_theta(cfg: ModelConfig, i: int) -> float:
    if cfg.rope_theta_global and layer_window(cfg, i) == 0:
        return cfg.rope_theta_global
    return cfg.rope_theta


# ---------------------------------------------------------------------------
# Block applies (full sequence: train & prefill)
# ---------------------------------------------------------------------------


def _cast(p, dtype):
    return jax.tree.map(lambda x: x.astype(dtype)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, p)


def _project_qkv(cfg, p, h, h_kv):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h_kv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h_kv, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rs_ok(ctx, batch, seq, contracted, d_out) -> bool:
    """rs_epilogue applicability: shard_map specs are strict, so every
    mapped dim must divide exactly (pjit hints would just fall back)."""
    shape = ctx.mesh.shape
    nm = shape.get("model", 1)
    nd = shape.get("data", 1)
    nb = nd * shape.get("pod", 1)
    return (nm > 1 and seq % nm == 0 and contracted % nm == 0
            and batch % nb == 0 and d_out % nd == 0)


def _bd(ctx):
    return tuple(a for a in ("pod", "data") if a in ctx.mesh.shape)


def _mlp_out_rs(ctx, act, w):
    """Down-projection with an explicit bf16 reduce-scatter epilogue.

    pjit's partitioner turns the TP partial-sum into a full all-reduce of
    the f32 dot accumulator (observed in the qwen2 baseline HLO: 2 GiB f32
    per layer per direction).  Writing the epilogue as a shard_map psum_
    scatter keeps the boundary in bf16 and scatters instead of reducing:
    4x less wire (§Perf q3).  act: (B,S,F) F-sharded; w: (F,D) (model,
    data)-sharded; returns (B,S,D) seq-sharded over "model".
    """
    def body(a, w_):
        w_full = jax.lax.all_gather(w_, "data", axis=1, tiled=True)
        y = jnp.einsum("bsf,fd->bsd", a, w_full)     # partial over F shard
        return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                    tiled=True)

    bd = _bd(ctx)
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(bd, None, "model"), P("model", "data")),
        out_specs=P(bd, "model", None), check_vma=False)(act, w)


def _attn_out_rs(ctx, o, w):
    """Attention out-projection, same epilogue as _mlp_out_rs.

    o: (B,S,H,hd) H-sharded; w: (H,hd,D) (model, -, data)-sharded."""
    def body(o_, w_):
        w_full = jax.lax.all_gather(w_, "data", axis=2, tiled=True)
        y = jnp.einsum("bshk,hkd->bsd", o_, w_full)  # partial over H shard
        return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                    tiled=True)

    bd = _bd(ctx)
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(bd, None, "model", None), P("model", None, "data")),
        out_specs=P(bd, "model", None), check_vma=False)(o, w)


def _enter_block(cfg, ctx, x):
    """Cross the SP boundary into a block: gather the sequence-sharded
    residual, then normalize.

    Hint placement matters (§Perf iteration q1): gathering *before* the
    norm moves the all-gather onto the bf16 residual; hinting after lets
    XLA fuse the gather with rms_norm's f32 upcast and ship 2x the bytes.
    Gated on cfg.prenorm_gather so the recorded baselines stay
    reproducible.
    """
    if cfg.prenorm_gather:
        return ctx.hint(x, "batch", "seq", None)
    return x


def attn_block(cfg, ctx, p, x, *, rope, window=0, causal=True,
               chunked=False, return_kv=False, kv_source=None, gated=False):
    """Self- or cross-attention block with residual.  x: (B, S, d)."""
    h = rms_norm(_enter_block(cfg, ctx, x), p["norm"], cfg.norm_eps)
    h = ctx.hint(h, "batch", "seq", None)
    if cfg.boundary_barrier:
        h = jax.lax.optimization_barrier(h)
    if kv_source is None:
        h_kv = h
    else:
        h_kv = rms_norm(kv_source, p["kv_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, p, h, h_kv)
    if rope is not None and kv_source is None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = ctx.hint(q, "batch", "seq", "heads", "head")
    k = ctx.hint(k, "batch", "seq", "kv_heads", "head")
    v = ctx.hint(v, "batch", "seq", "kv_heads", "head")
    qg = attn.split_gqa(q, cfg.num_kv_heads)
    scale = cfg.head_dim ** -0.5
    sq, skv = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq)
    kv_pos = jnp.arange(skv)
    if kv_source is not None:
        causal = False
    score_hint = None
    if cfg.tuned_hints:
        # Anchor the (B, KV*G, Sq, Skv) score layout: prefer sharding the
        # merged head product over "model" (KV alone cannot shard a 16-way
        # axis under GQA); when the head count itself does not divide
        # (starcoder2's 36, gemma3's 4), fall back to sharding the *query*
        # dim — softmax reduces over Skv, so a q-shard needs no comm.
        score_hint = lambda t: ctx.hint(  # noqa: E731
            t, "batch", "heads", "sp_seq", None)
    if chunked and skv > 4 * cfg.attn_chunk:
        o = attn.chunked_attention(qg, k, v, q_pos, kv_pos, window=window,
                                   causal=causal, scale=scale,
                                   chunk=cfg.attn_chunk,
                                   score_hint=score_hint)
    else:
        o = attn.full_attention(qg, k, v, q_pos, kv_pos, window=window,
                                causal=causal, scale=scale,
                                score_hint=score_hint)
    o = attn.merge_gqa(o.astype(x.dtype))
    # Pin the pre-projection layout: with_sharding_constraint also fixes the
    # cotangent sharding, which keeps the attention backward head-sharded
    # (without this, SPMD re-shards the (b,kv,g,q,s) score tensor seq-wise in
    # the transpose pass -> involuntary full rematerialization).
    o = ctx.hint(o, "batch", "seq", "heads", "head")
    if cfg.rs_epilogue and not gated and _rs_ok(
            ctx, o.shape[0], o.shape[1], o.shape[2], p["wo"].shape[2]):
        out = _attn_out_rs(ctx, o, p["wo"])
    else:
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if gated:
            out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) \
                * out
        out = ctx.hint(out, "batch", "sp_seq", None)
    res = x + out
    if return_kv:  # (B, KV, S, hd) cache layout
        return res, (jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2))
    return res


def mlp_block(cfg, ctx, p, x):
    h = rms_norm(_enter_block(cfg, ctx, x), p["norm"], cfg.norm_eps)
    h = ctx.hint(h, "batch", "seq", None)
    if cfg.boundary_barrier:
        h = jax.lax.optimization_barrier(h)
    up = jnp.einsum("bsd,df->bsf", h, p["wi"])
    up = ctx.hint(up, "batch", "seq", "mlp")
    if cfg.act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", h, p["wg"])
        act = swiglu(gate, up)
    else:
        act = gelu(up)
    if cfg.rs_epilogue and _rs_ok(ctx, act.shape[0], act.shape[1],
                                  act.shape[2], p["wo"].shape[1]):
        return x + _mlp_out_rs(ctx, act, p["wo"])
    out = jnp.einsum("bsf,fd->bsd", act, p["wo"])
    out = ctx.hint(out, "batch", "sp_seq", None)
    return x + out


# ---------------------------------------------------------------------------
# MoE block — shard_map interior for deterministic collectives
# ---------------------------------------------------------------------------


def _moe_local(cfg, p, x_flat):
    """Local (per-shard) top-k dispatch via sort + capacity scatter.

    x_flat: (T, d) local tokens.  Returns (T, d) combined expert output and
    the number of locally dropped assignments (diagnostic).
    """
    t, d = x_flat.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = int(np.ceil(cfg.capacity_factor * k * t / e))
    cap = max(4, min(cap, t * k))

    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = eid.reshape(-1)                             # (T*k,)
    flat_g = gate.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=0)
    start = jnp.cumsum(counts) - counts                  # exclusive cumsum
    pos_in_e = jnp.arange(t * k) - start[se]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)  # overflow row

    buf = jnp.zeros((e * cap + 1, d), x_flat.dtype)
    buf = buf.at[slot].add(x_flat[st] * keep[:, None].astype(x_flat.dtype))
    xe = buf[:-1].reshape(e, cap, d)
    return xe, (slot, st, sg, keep), int(cap)


def _moe_combine(t, d, k, outs_rows, slot, st, sg, keep, dtype):
    """Scatter expert rows back to tokens and weight by gates."""
    picked = outs_rows[slot] * keep[:, None].astype(outs_rows.dtype)  # (T*k,d)
    y = jnp.zeros((t, d), dtype)
    y = y.at[st].add(picked.astype(dtype) * sg[:, None].astype(dtype))
    return y


def moe_block(cfg, ctx, p, x):
    """Mixture block.  TP mode: experts replicated, d_ff sharded over
    "model", psum-scatter epilogue (Megatron-SP style).  EP mode: experts
    sharded over "model", explicit all_to_all dispatch/return."""
    mesh = ctx.mesh
    bd = tuple(a for a in ("pod", "data") if a in mesh.shape)
    # the shard_map bodies below hard-code Megatron-TP weight layouts; use
    # them only when the rules actually put d_ff (or experts) on "model".
    # Under data-parallel-only rules (§Perf fsdp preset) fall through to a
    # plain pjit path and let the partitioner place the expert einsums.
    mlp_on_model = "model" in ctx.rules.mesh_axes("mlp")
    exp_on_model = "model" in ctx.rules.mesh_axes("expert")
    has_model = "model" in mesh.shape and (mlp_on_model or exp_on_model)
    ep = cfg.moe_partition == "expert" and has_model and exp_on_model and (
        cfg.num_experts % mesh.shape["model"] == 0)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token

    h_in = rms_norm(_enter_block(cfg, ctx, x), p["norm"], cfg.norm_eps)

    if not has_model:
        t = b * s
        hf = h_in.reshape(t, d)
        xe, meta, cap = _moe_local(cfg, {"router": p["router"]}, hf)
        up = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
        gt = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
        act = swiglu(gt, up)
        out = jnp.einsum("ecf,efd->ecd", act, p["wo"])
        rows = jnp.concatenate(
            [out.reshape(e * cap, d), jnp.zeros((1, d), out.dtype)])
        y = _moe_combine(t, d, k, rows, *meta, h_in.dtype).reshape(b, s, d)
        y = ctx.hint(y, "batch", "sp_seq", None)
        return x + y

    def tp_body(h, router, wi, wg, wo):
        # tokens replicated over "model", d_ff sharded: each rank computes a
        # partial over its f-shard; the single reduction is fused with the
        # sequence-parallel re-shard (reduce-scatter epilogue, Megatron-SP).
        t = h.shape[0] * h.shape[1]
        hf = h.reshape(t, d)
        xe, meta, cap = _moe_local(cfg, {"router": router}, hf)
        up = jnp.einsum("ecd,edf->ecf", xe, wi)
        gt = jnp.einsum("ecd,edf->ecf", xe, wg)
        act = swiglu(gt, up)
        out = jnp.einsum("ecf,efd->ecd", act, wo)        # partial over f
        rows = jnp.concatenate(
            [out.reshape(e * cap, d), jnp.zeros((1, d), out.dtype)])
        y = _moe_combine(t, d, k, rows, *meta, h.dtype)  # linear: stays partial
        y = y.reshape(h.shape)
        if has_model:
            if s % mesh.shape["model"] == 0:
                y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                         tiled=True)
            else:
                y = jax.lax.psum(y, "model")
        return y

    def ep_body(h, router, wi, wg, wo):
        t = h.shape[0] * h.shape[1]
        hf = h.reshape(t, d)
        xe, meta, cap = _moe_local(cfg, {"router": router}, hf)  # (E,cap,d)
        # all_to_all: split experts across model ranks, concat token chunks
        xr = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=1,
                                tiled=True)              # (E/nm, cap*nm, d)
        up = jnp.einsum("ecd,edf->ecf", xr, wi)
        gt = jnp.einsum("ecd,edf->ecf", xr, wg)
        act = swiglu(gt, up)
        out = jnp.einsum("ecf,efd->ecd", act, wo)        # (E/nm, cap*nm, d)
        back = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                  tiled=True)            # (E, cap, d)
        rows = jnp.concatenate(
            [back.reshape(e * cap, d), jnp.zeros((1, d), back.dtype)])
        y = _moe_combine(t, d, k, rows, *meta, h.dtype)
        return y.reshape(h.shape)

    if ep:
        # tokens sharded over every mesh axis (batch over dp axes, seq over
        # model); experts sharded over model.
        in_specs = (P(bd, "model" if s % mesh.shape.get("model", 1) == 0
                      else None, None),
                    P(None, None), P("model", None, None),
                    P("model", None, None), P("model", None, None))
        out_spec = in_specs[0]
        body = ep_body
    else:
        seq_ok = has_model and s % mesh.shape["model"] == 0
        in_specs = (P(bd, None, None),
                    P(None, None),
                    P(None, None, "model") if has_model else P(None, None, None),
                    P(None, None, "model") if has_model else P(None, None, None),
                    P(None, "model", None) if has_model else P(None, None, None))
        out_spec = P(bd, "model", None) if seq_ok else P(bd, None, None)
        body = tp_body

    y = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
        check_vma=False,
    )(h_in, p["router"], p["wi"], p["wg"], p["wo"])
    y = ctx.hint(y, "batch", "sp_seq", None)
    return x + y


def moe_block_decode(cfg, ctx, p, x):
    """Gather-based MoE for decode: fetch top-k expert weights per token.

    Keeps FLOPs at k/E of dense and reads only the needed expert weights
    (the true memory cost of MoE decode).  d_ff stays sharded over "model"
    in TP mode; in EP mode weights are E-sharded so we fall back to a dense
    one-hot contraction over the *local* experts then psum (tokens tiny).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    hf = h.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", hf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                   # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    wi = jnp.take(p["wi"], eid, axis=0)                   # (T,k,d,f)
    wg = jnp.take(p["wg"], eid, axis=0)
    wo = jnp.take(p["wo"], eid, axis=0)                   # (T,k,f,d)
    up = jnp.einsum("td,tkdf->tkf", hf, wi)
    gt = jnp.einsum("td,tkdf->tkf", hf, wg)
    act = swiglu(gt, up)
    out = jnp.einsum("tkf,tkfd->tkd", act, wo)
    y = jnp.einsum("tkd,tk->td", out, gate.astype(out.dtype))
    return x + y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba_block(cfg, ctx, p, x, *, return_state=False, chunk=None):
    """Full-sequence Mamba2 block.  x: (B, S, d)."""
    b, s, d = x.shape
    h = rms_norm(_enter_block(cfg, ctx, x), p["norm"], cfg.norm_eps)
    h = ctx.hint(h, "batch", "seq", None)
    z = jnp.einsum("bsd,di->bsi", h, p["wz"])
    xi = jnp.einsum("bsd,di->bsi", h, p["wx"])
    bb = jnp.einsum("bsd,dg->bsg", h, p["wB"])
    cc = jnp.einsum("bsd,dg->bsg", h, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", h, p["wdt"])
    xi = ssd.causal_conv(xi, p["conv_x_w"], p["conv_x_b"])
    bb = ssd.causal_conv(bb, p["conv_B_w"], p["conv_B_b"])
    cc = ssd.causal_conv(cc, p["conv_C_w"], p["conv_C_b"])
    nh, pd, ns = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    xh = xi.reshape(b, s, nh, pd)
    xh = ctx.hint(xh, "batch", "seq", "ssm_heads", None)
    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    if cfg.tuned_hints:
        # anchor the decay tensor on heads so the (B,C,H,Q,Q) segsum/score
        # intermediates in ssd_scan shard over "model" instead of
        # replicating (§Perf z-iterations)
        dtp = ctx.hint(dtp, "batch", "seq", "ssm_heads")
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    bg = bb.reshape(b, s, cfg.ssm_ngroups, ns)
    cg = cc.reshape(b, s, cfg.ssm_ngroups, ns)
    y, state = ssd.ssd_scan(xh, dtp, a, bg, cg,
                            chunk=chunk or cfg.ssm_chunk, d_skip=p["D"])
    y = y.reshape(b, s, nh * pd)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gnorm"], cfg.norm_eps)
    if cfg.rs_epilogue and _rs_ok(ctx, b, s, y.shape[2],
                                  p["wout"].shape[1]):
        out = _mlp_out_rs(ctx, y, p["wout"])
    else:
        out = jnp.einsum("bsi,id->bsd", y, p["wout"])
        out = ctx.hint(out, "batch", "sp_seq", None)
    res = x + out
    if return_state:
        # conv states: last (W-1) *pre-conv* channel inputs, re-projected
        # from the normed-residual tail (cheap: (B, W-1, d) slice).
        w = cfg.ssm_conv_width
        tail = h[:, s - (w - 1):, :]
        pre_x = jnp.einsum("bsd,di->bsi", tail, p["wx"])
        pre_b = jnp.einsum("bsd,dg->bsg", tail, p["wB"])
        pre_c = jnp.einsum("bsd,dg->bsg", tail, p["wC"])
        return res, {"state": state, "conv_x": pre_x, "conv_B": pre_b,
                     "conv_C": pre_c}
    return res


def mamba_block_decode(cfg, ctx, p, x, cache):
    """One-token Mamba2 update.  x: (B, 1, d); cache holds state+conv."""
    b = x.shape[0]
    h = rms_norm(x[:, 0, :], p["norm"], cfg.norm_eps)
    z = jnp.einsum("bd,di->bi", h, p["wz"])
    xi = jnp.einsum("bd,di->bi", h, p["wx"])
    bb = jnp.einsum("bd,dg->bg", h, p["wB"])
    cc = jnp.einsum("bd,dg->bg", h, p["wC"])
    dt = jnp.einsum("bd,dh->bh", h, p["wdt"])
    xi, cx = ssd.causal_conv_decode(cache["conv_x"], xi,
                                    p["conv_x_w"], p["conv_x_b"])
    bb, cb = ssd.causal_conv_decode(cache["conv_B"], bb,
                                    p["conv_B_w"], p["conv_B_b"])
    cc, ccs = ssd.causal_conv_decode(cache["conv_C"], cc,
                                     p["conv_C_w"], p["conv_C_b"])
    nh, pd, ns = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    xh = xi.reshape(b, nh, pd)
    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd.ssd_decode(cache["state"], xh, dtp, a,
                              bb.reshape(b, cfg.ssm_ngroups, ns),
                              cc.reshape(b, cfg.ssm_ngroups, ns),
                              d_skip=p["D"])
    y = y.reshape(b, nh * pd)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["wout"])
    new_cache = {"state": state, "conv_x": cx, "conv_B": cb, "conv_C": ccs}
    return x + out[:, None, :], new_cache


# ---------------------------------------------------------------------------
# Decode-mode attention block
# ---------------------------------------------------------------------------


def attn_block_decode(cfg, ctx, p, x, cache, pos, *, window=0, theta=None,
                      cross_cache=None, gated=False, use_rope=True):
    """x: (B, 1, d).  cache: {k, v, slot_pos}; cross_cache: {k, v} fixed."""
    b = x.shape[0]
    h = rms_norm(x[:, 0, :], p["norm"], cfg.norm_eps)
    hs = h[:, None, :]
    if cross_cache is not None:
        q = jnp.einsum("bd,dhk->bhk", h, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        qg = q.reshape(b, cfg.num_kv_heads,
                       cfg.num_heads // cfg.num_kv_heads, cfg.head_dim)
        skv = cross_cache["k"].shape[2]
        slot = jnp.arange(skv)
        o = attn.decode_attention(qg, cross_cache["k"], cross_cache["v"],
                                  slot, jnp.asarray(skv, jnp.int32),
                                  window=0, scale=cfg.head_dim ** -0.5)
        o = o.reshape(b, cfg.num_heads, cfg.head_dim)
        out = jnp.einsum("bhk,hkd->bd", o.astype(x.dtype), p["wo"])
        if gated:
            out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out
        return x + out[:, None, :], cache
    q, k, v = _project_qkv(cfg, p, hs, hs)
    if use_rope:
        theta = theta if theta is not None else cfg.rope_theta
        cos, sin = rope_at(pos[None], cfg.head_dim, theta)  # (1, hd/2)
        q = apply_rope(q, cos[None], sin[None])
        k = apply_rope(k, cos[None], sin[None])
    q1 = q[:, 0]                                           # (B,H,hd)
    k1, v1 = k[:, 0], v[:, 0]                              # (B,KV,hd)
    size = cache["k"].shape[2]
    slot = jnp.where(jnp.asarray(window, jnp.int32) > 0, pos % size,
                     jnp.minimum(pos, size - 1))
    ck, cv = attn.cache_write(cache["k"], cache["v"], k1, v1, slot)
    slot_pos = cache["slot_pos"]
    slot_pos = jnp.where(jnp.arange(size) == slot, pos, slot_pos)
    qg = q1.reshape(b, cfg.num_kv_heads,
                    cfg.num_heads // cfg.num_kv_heads, cfg.head_dim)
    o = attn.decode_attention(qg, ck, cv, slot_pos, pos, window=window,
                              scale=cfg.head_dim ** -0.5)
    o = o.reshape(b, cfg.num_heads, cfg.head_dim)
    out = jnp.einsum("bhk,hkd->bd", o.astype(x.dtype), p["wo"])
    new_cache = {"k": ck, "v": cv, "slot_pos": slot_pos}
    return x + out[:, None, :], new_cache


def mlp_block_decode(cfg, ctx, p, x):
    return mlp_block(cfg, ctx, p, x)
