"""lax.scan wrapper with a process-wide unroll switch (analysis only).

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
its trip count (verified in tests/test_perfmodel.py::test_cost_analysis_
counts_loops_once), so roofline terms derived from scan-based HLO
under-count in-loop flops/bytes/collectives by the trip count.  The
dry-run's cost pass therefore lowers with ``set_unroll(True)``: every scan
in the model/train code fully unrolls and XLA's own numbers become exact.
Execution paths (tests, examples, real training) keep scans rolled.
"""

from __future__ import annotations

import jax

_UNROLL = False


def set_unroll(value: bool) -> None:
    global _UNROLL
    _UNROLL = bool(value)


def get_unroll() -> bool:
    return _UNROLL


def scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if _UNROLL else 1)
