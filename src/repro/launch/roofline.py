"""Roofline-term derivation from compiled dry-run artifacts.

compute_s    = per-device HLO flops / peak bf16 flops
memory_s     = per-device HLO bytes accessed / HBM bandwidth
collective_s = sum over collective ops of wire_bytes(op) / link bandwidth

``cost_analysis()`` on an SPMD executable reports per-device numbers
(verified in EXPERIMENTS.md §Dry-run).  Collective bytes are not in
cost_analysis, so we parse the partitioned HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
result shape, with ring wire factors and ICI-vs-DCN classification by
whether the replica group crosses the pod boundary (device id >= 256).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

from repro.launch import mesh as mesh_mod

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")

# wire factor: fraction of the RESULT (gather) or OPERAND (others) bytes
# each device puts on the wire under ring algorithms, as f(group size n)
WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _parse_groups(line: str):
    """Returns (group_size, crosses_pod) for the collective on this line."""
    m = _GROUPS_LIST_RE.search(line)
    if m:
        groups = [[int(x) for x in g.split(",") if x]
                  for g in re.findall(r"\{([^}]*)\}", m.group(1))]
        size = max((len(g) for g in groups), default=1)
        crosses = any((max(g) // 256) != (min(g) // 256)
                      for g in groups if g)
        return size, crosses
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        n = int(np.prod(dims))
        ids = np.arange(n).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(ngroups, gsize)
        crosses = bool(((ids // 256).max(axis=1)
                        != (ids // 256).min(axis=1)).any())
        return gsize, crosses
    return 1, False


@dataclasses.dataclass
class Collective:
    op: str
    result_bytes: int
    group_size: int
    crosses_pod: bool
    wire_bytes: float


# -- computation structure: multiply collectives inside while bodies --------

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{$")
_WHILE_RE = re.compile(
    r"while\(.*?(?:condition=%?([\w.\-]+), body=%?([\w.\-]+)"
    r"|body=%?([\w.\-]+), condition=%?([\w.\-]+))")
_CONST_RE = re.compile(r"[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _split_computations(hlo_text: str):
    """-> (comps: {name: [lines]}, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur: list[str] | None = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and cur is None:
            name = m.group(2)
            comps[name] = cur = []
            if m.group(1):
                entry = name
            continue
        if cur is not None:
            if line.strip().startswith("}"):
                cur = None
            else:
                cur.append(line)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    """Loop bound heuristic: the largest integer constant in the loop
    condition (induction starts at 0, compares LT bound)."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def computation_multipliers(hlo_text: str):
    """{computation: execution multiplier} from nested while trip counts."""
    comps, entry = _split_computations(hlo_text)
    mult: dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in comps or mult.get(name, 0) >= m:
            return
        mult[name] = m
        for line in comps[name]:
            w = _WHILE_RE.search(line)
            if not w:
                continue
            cond = w.group(1) or w.group(4)
            body = w.group(2) or w.group(3)
            t = _TRIP_RE.search(line)           # XLA's own trip count
            trips = int(t.group(1)) if t else _trip_count(comps.get(cond,
                                                                    []))
            visit(body, m * trips)
            visit(cond, m * trips)

    if entry:
        visit(entry, 1)
    return comps, mult


def while_trip_counts(hlo_text: str) -> dict[str, int]:
    """Residual loops and their trip counts (diagnostic: should be empty
    or all-1 in an unrolled cost pass)."""
    comps, mult = computation_multipliers(hlo_text)
    return {name: m for name, m in mult.items()
            if m > 1 and any(_LINE_RE.search(l) for l in comps[name])}


def parse_collectives(hlo_text: str) -> list[Collective]:
    """Collectives weighted by how many times their computation executes
    (while bodies run trip-count times; cost text lists them once)."""
    comps, mult = computation_multipliers(hlo_text)
    if not comps:
        comps = {"": hlo_text.splitlines()}
        mult = {"": 1}
    out = []
    for name, lines in comps.items():
        m_exec = mult.get(name, 1)
        for line in lines:
            m = _LINE_RE.search(line)
            if not m:
                continue
            shape_str, op = m.group(1), m.group(2)
            nbytes = _shape_bytes(shape_str)
            gsize, crosses = _parse_groups(line)
            if gsize <= 1:
                continue
            wire = nbytes * WIRE_FACTOR[op](gsize)
            for _ in range(m_exec):
                out.append(Collective(op, nbytes, gsize, crosses, wire))
    return out


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: older
    releases return a one-element list of per-program dicts, newer ones a
    bare dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


# ---------------------------------------------------------------------------
# Text-based flop/byte model with loop multipliers
#
# cost_analysis() counts a while body once; full unroll is too slow to
# compile for the 70B+ cells on this host.  So we re-derive flops and
# bytes-accessed from the HLO text itself and weight every computation by
# its execution count (XLA's known_trip_count).  Validated against
# cost_analysis() on loop-free graphs (tests/test_perfmodel.py).
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\S+)\s+([\w\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_FUSION_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_SHAPE_ONLY_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota", "copy-start", "copy-done",
               "while", "conditional", "call"}


def _type_bytes_dims(type_str: str):
    """(total bytes, dims-of-first-shape) for an HLO type string."""
    total = 0
    first = None
    for m in _SHAPE_ONLY_RE.finditer(type_str):
        if m.group(1) not in DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        n = DTYPE_BYTES[m.group(1)]
        for d in dims:
            n *= d
        total += n
        if first is None:
            first = dims
    return total, (first if first is not None else [])


def _operand_names(rhs: str) -> list[str]:
    """%names inside the operand parens (excludes calls=/condition= refs)."""
    start = rhs.find("(")
    if start < 0:
        return []
    depth = 0
    end = start
    for i in range(start, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", rhs[start:end])


def text_costs(hlo_text: str) -> dict[str, float]:
    """Loop-aware per-device {flops, bytes} from the partitioned HLO.

    flops: dot contractions (2*M*N*K incl. batch dims), weighted by loop
    trip counts.  bytes: per-instruction output+operand buffer sizes
    (fusion internals excluded — the fusion call carries the traffic),
    weighted likewise.  Elementwise flops are ignored (dots dominate);
    validated against cost_analysis() on loop-free graphs.
    """
    comps, mult = computation_multipliers(hlo_text)
    if not comps:
        comps, mult = {"": hlo_text.splitlines()}, {"": 1}

    # symbol table: instruction name -> (bytes, first-shape dims)
    defs: dict[str, tuple[int, list[int]]] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                defs[m.group(1)] = _type_bytes_dims(m.group(2))

    direct_flops: dict[str, float] = {}
    direct_bytes: dict[str, float] = {}
    calls: dict[str, list[str]] = {}
    for name, lines in comps.items():
        f = b = 0.0
        cl = []
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            out_bytes, out_dims = _type_bytes_dims(m.group(2))
            opcode = m.group(3)
            rhs = line.split("=", 1)[1]
            if opcode == "dot":
                ops = _operand_names(rhs)
                cm = _CONTRACT_RE.search(line)
                if ops and cm and ops[0] in defs:
                    lhs_dims = defs[ops[0]][1]
                    k = 1
                    for idx in cm.group(1).split(","):
                        if idx:
                            k *= lhs_dims[int(idx)]
                    out_elems = 1
                    for d in out_dims:
                        out_elems *= d
                    f += 2.0 * out_elems * k
            if opcode == "fusion" or "to_apply=" in line:
                fm = _FUSION_CALL_RE.search(line)
                if fm:
                    cl.append(fm.group(1))
            if opcode not in _NO_TRAFFIC:
                b += out_bytes
                for op_name in _operand_names(rhs):
                    b += defs.get(op_name, (0, []))[0]
        direct_flops[name], direct_bytes[name] = f, b
        calls[name] = cl

    import functools

    @functools.lru_cache(maxsize=None)
    def flops_closure(name: str) -> float:
        return direct_flops.get(name, 0.0) + sum(
            flops_closure(c) for c in calls.get(name, []))

    total_f = total_b = 0.0
    for name, m_exec in mult.items():
        total_f += m_exec * (direct_flops.get(name, 0.0) + sum(
            flops_closure(c) for c in calls.get(name, [])))
        total_b += m_exec * direct_bytes.get(name, 0.0)
    return {"flops": total_f, "bytes": total_b}


def collective_summary(colls: list[Collective]) -> dict[str, Any]:
    by_op: dict[str, dict[str, float]] = {}
    for c in colls:
        d = by_op.setdefault(c.op, {"count": 0, "bytes": 0.0, "wire": 0.0})
        d["count"] += 1
        d["bytes"] += c.result_bytes
        d["wire"] += c.wire_bytes
    return by_op


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   colls: list[Collective]) -> dict[str, float]:
    compute_s = flops_per_dev / mesh_mod.PEAK_FLOPS_BF16
    memory_s = bytes_per_dev / mesh_mod.HBM_BW
    ici = sum(c.wire_bytes for c in colls if not c.crosses_pod)
    dcn = sum(c.wire_bytes for c in colls if c.crosses_pod)
    collective_s = (ici / (mesh_mod.ICI_BW_PER_LINK *
                           mesh_mod.ICI_LINKS_PER_AXIS)
                    + dcn / mesh_mod.DCN_BW)
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "ici_wire_bytes": ici,
        "dcn_wire_bytes": dcn,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom
    terms["roofline_fraction"] = compute_s / max(
        compute_s, memory_s, collective_s)
    terms["step_time_lower_bound_s"] = max(compute_s, memory_s, collective_s)
    return terms


def model_flops(cfg, shape) -> float:
    """Useful-work FLOPs for the (arch, shape) cell (see DESIGN.md §7)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
