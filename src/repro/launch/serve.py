"""Production serving launcher: prompts out of the object store -> waves.

Mirrors launch/train.py for the inference path: builds the cluster,
stores a batch of prompt token streams columnar, fetches each prompt via
a pushdown scan (projection + prompt-id predicate), and drives the
wave-batching engine.  --smoke runs the identical code path on one CPU
device with a reduced config:

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b \
        --smoke --requests 8 --max-new 8
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.configs import get_config, smoke_config
from repro.core import dataset, make_cluster, write_flat
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.serve import Request, ServeEngine, init_serve_params
from repro.sharding import default_rules


def store_prompts(fs, n: int, vocab: int, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    pid, pos, tok = [], [], []
    for i in range(n):
        m = int(rng.integers(4, 24))
        pid += [i] * m
        pos += list(range(m))
        tok += rng.integers(1, vocab, m).tolist()
    tbl = Table.from_pydict({
        "prompt_id": np.asarray(pid, np.int64),
        "pos": np.asarray(pos, np.int32),
        "token": np.asarray(tok, np.int32),
    })
    write_flat(fs, "/prompts/wave0.arw", tbl, row_group_rows=8192)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--osds", type=int, default=4)
    args = ap.parse_args()

    if args.smoke:
        cfg = smoke_config(args.arch)
        cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers, 2),
                                  remat=False, vocab_size=1024)
        mesh = make_local_mesh(1, 1)
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules = default_rules()

    fs = make_cluster(args.osds)
    store_prompts(fs, args.requests, cfg.vocab_size)
    ds = dataset(fs, "/prompts")

    params, _ = init_serve_params(cfg)
    engine = ServeEngine(cfg, mesh, rules, params,
                         max_batch=args.max_batch)
    t0 = time.perf_counter()
    wire = 0
    for i in range(args.requests):
        sc = ds.scanner(format="pushdown", columns=["token"],
                        predicate=field("prompt_id") == i)
        prompt = sc.to_table().column("token").values.astype(np.int32)
        wire += sc.metrics.wire_bytes
        engine.submit(Request(i, prompt, max_new_tokens=args.max_new))
    comps = engine.run()
    dt = time.perf_counter() - t0
    total = sum(len(c.tokens) for c in comps)
    print(f"arch={cfg.name} served {len(comps)} requests "
          f"({total} tokens) in {dt:.2f}s; prompt wire {wire / 1e3:.1f} KB "
          f"via pushdown")
    return 0 if len(comps) == args.requests else 1


if __name__ == "__main__":
    raise SystemExit(main())
