"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state.  The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing
jax so 512 placeholder CPU devices exist; smoke tests and benchmarks see
the default single device.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.4.38 exposes explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto-typed
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices this host actually has."""
    return _make_mesh((data, model), ("data", "model"))


# Hardware model used for the roofline terms (TPU v5e-like; see DESIGN.md §7)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link (one direction)
ICI_LINKS_PER_AXIS = 1          # torus: 1 link per mesh-axis direction
DCN_BW = 25e9                   # bytes/s per chip, pod axis (multi-pod)
