"""Production training launcher.

Wires every layer together: mesh construction, per-arch config, the
object-store-backed pushdown data pipeline, the jitted train step, periodic
async checkpoints into the same object store, and failure recovery.

Full-scale use (on a real pod) takes --arch/--shape directly from the
registry; --smoke shrinks the model and mesh so the identical code path
runs end-to-end on one CPU device:

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-7b \
        --smoke --steps 50
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.aformat.expressions import field
from repro.configs import SHAPES, get_config, smoke_config
from repro.core import dataset, make_cluster
from repro.data import device_put_batch, synth_corpus, write_corpus
from repro.dataset.qos import TenantRegistry, ingest_context
from repro.distrib import CheckpointManager
from repro.ingest import ReaderConfig, ReaderState, ShardedReader
from repro.launch import knobs as knobs_mod
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.sharding import default_rules
from repro.train import optim, step as step_mod


def build_training(cfg, mesh, rules, opt, *, num_microbatches=1):
    state, spec_tree = step_mod.init_state(cfg, opt, jax.random.key(0))
    from repro.sharding import tree_shardings

    state_specs = {
        "params": spec_tree,
        "opt": {"m": optim.moment_specs(spec_tree, state["opt"]["m"]),
                "v": optim.moment_specs(spec_tree, state["opt"]["v"]),
                "count": None},
        "step": None,
    }
    shardings = tree_shardings(mesh, rules, state, state_specs)
    state = jax.device_put(state, shardings)
    fn = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt,
                                          num_microbatches=num_microbatches),
                 donate_argnums=(0,))
    return state, state_specs, fn


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + local mesh (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--knobs", default="baseline")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--quality", type=float, default=0.5,
                    help="pushdown quality-filter threshold")
    ap.add_argument("--osds", type=int, default=8)
    ap.add_argument("--format", default="pushdown",
                    choices=["pushdown", "parquet", "adaptive"])
    ap.add_argument("--resume", action="store_true",
                    help="restore model + reader from the latest "
                         "checkpoint and continue the exact batch stream")
    args = ap.parse_args()

    # -- model + mesh ---------------------------------------------------------
    if args.smoke:
        cfg = smoke_config(args.arch)
        cfg = dataclasses.replace(cfg, remat=False,
                                  vocab_size=4096,
                                  num_layers=min(cfg.num_layers, 2))
        mesh = make_local_mesh(1, 1)
        seq, batch = args.seq, args.batch
    else:
        cfg = get_config(args.arch)
        kn = knobs_mod.get(args.knobs, args.arch, args.shape)
        cfg = kn.apply(cfg)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = SHAPES[args.shape]
        seq, batch = shape.seq_len, shape.global_batch
    rules = default_rules()
    opt = optim.OptConfig(peak_lr=1e-3, warmup_steps=10,
                          decay_steps=max(100, args.steps),
                          moment_dtype=cfg.opt_moment_dtype)

    # -- storage + ingest -------------------------------------------------------
    fs = make_cluster(args.osds)
    corpus = synth_corpus(1200, mean_doc_len=400,
                          vocab_size=cfg.vocab_size, seed=0)
    write_corpus(fs, "/corpus", corpus, num_shards=args.osds,
                 row_group_rows=16384)
    ds = dataset(fs, "/corpus")
    # the training reader is a registered bulk-lane tenant: interactive
    # queries against the same cluster are arbitrated against it by the
    # shared weighted-fair admission controller, not starved by it
    registry = TenantRegistry()
    rcfg = ReaderConfig(seq_len=seq, local_batch=batch,
                        predicate=field("quality") > args.quality,
                        format=args.format, num_threads=2,
                        tenant=ingest_context(registry), registry=registry)
    cm = CheckpointManager(fs, "/ckpt", keep=3)

    # -- train state (+ optional resume) -------------------------------------
    state, state_specs, fn = build_training(cfg, mesh, rules, opt)
    start_step = 0
    rstate: ReaderState | None = None
    if args.resume:
        last = cm.latest_step()
        if last is None:
            print("--resume: no checkpoint found, starting fresh")
        else:
            from repro.sharding import tree_shardings

            shardings = tree_shardings(mesh, rules, state, state_specs)
            state = cm.restore({"model": state}, last,
                               shardings={"model": shardings})["model"]
            rstate = ReaderState.from_arrays(
                cm.restore({"reader": ReaderState.restore_structs()},
                           last)["reader"])
            start_step = last
            print(f"--resume: step {last}, reader at epoch "
                  f"{rstate.epoch} cursor {rstate.cursor}")
    reader = ShardedReader.for_mesh(ds, rcfg, mesh, state=rstate)

    # -- train loop ----------------------------------------------------------------
    print(f"arch={cfg.name} params~{cfg.param_count() / 1e6:.1f}M "
          f"mesh={dict(mesh.shape)} ingest={args.format} "
          f"shard {reader.dp_rank}/{reader.dp_size} "
          f"({len(reader.shard)} of {len(reader.tasks)} tasks)")
    t0 = time.perf_counter()
    for step in range(start_step + 1, start_step + args.steps + 1):
        host_batch = next(reader)
        gbatch = device_put_batch(host_batch, mesh, rules)
        state, mets = fn(state, gbatch)
        if step % 10 == 0 or step == start_step + 1:
            loss = float(mets["loss"])
            toks = (step - start_step) * seq * batch
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss {loss:7.4f} "
                  f"tok/s {toks / dt:9.0f} lr {float(mets['lr']):.2e}",
                  flush=True)
        if step % args.ckpt_every == 0:
            # reader state rides in the same checkpoint as the model:
            # one commit point restores both to the same cut
            cm.save_async({"model": state,
                           "reader": reader.checkpoint().to_arrays()},
                          step)
    cm.wait()
    reader.close()
    ing = reader.stats()
    print(f"done: ingest host_cpu={ing['client_cpu_s']}s "
          f"storage_cpu={ing['osd_cpu_s']}s "
          f"wire={ing['wire_bytes'] / 1e6:.1f}MB "
          f"batches={ing['batches']} checkpoints={cm.steps()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
