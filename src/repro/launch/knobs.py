"""Per-(arch, shape) performance knobs for the dry-run / perf pass.

``BASELINE`` is the paper-faithful starting point (sensible defaults, no
cell-specific tuning).  ``TUNED`` holds the hillclimbed settings from
EXPERIMENTS.md §Perf — each entry there corresponds to a recorded
hypothesis -> change -> measure iteration.  Select with ``--knobs``.
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class Knobs:
    num_microbatches: int = 1
    remat: bool = True
    scan_layers: bool | None = None        # None = config default
    moe_partition: str | None = None       # None = config default
    rules: dict[str, tuple[str, ...]] | None = None  # AxisRules overrides
    attn_chunk: int | None = None
    prenorm_gather: bool = False           # SP gather before the norm (§Perf)
    ssm_chunk: int | None = None           # SSD chunk length override
    tuned_hints: bool = False              # head-shard scores / SSD decay
    boundary_barrier: bool = False         # pin bf16 at the SP gather
    rs_epilogue: bool = False              # bf16 psum_scatter TP epilogues
    train_chunked: bool = False            # flash-chunked attention in train

    def apply(self, cfg):
        import dataclasses as dc

        updates: dict[str, Any] = {}
        if not self.remat:
            updates["remat"] = False
        if self.scan_layers is not None:
            updates["scan_layers"] = self.scan_layers
        if self.moe_partition is not None:
            updates["moe_partition"] = self.moe_partition
        if self.attn_chunk is not None:
            updates["attn_chunk"] = self.attn_chunk
        if self.prenorm_gather:
            updates["prenorm_gather"] = True
        if self.ssm_chunk is not None:
            updates["ssm_chunk"] = self.ssm_chunk
        if self.tuned_hints:
            updates["tuned_hints"] = True
        if self.boundary_barrier:
            updates["boundary_barrier"] = True
        if self.rs_epilogue:
            updates["rs_epilogue"] = True
        if self.train_chunked:
            updates["train_chunked"] = True
        return dc.replace(cfg, **updates) if updates else cfg


BASELINE: dict[tuple[str, str], Knobs] = {}

# ZeRO-3-style rules: pure DP over every mesh axis, params sharded over
# (data, model).  Wins when activation-per-device >> params-per-layer
# (qwen2 q7); catastrophic for MoE at small per-device token counts (m3).
FSDP_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data", "model"),
    "embed": ("data", "model"),
    "sp_seq": (), "kv_seq": (), "heads": (), "kv_heads": (),
    "mlp": (), "vocab": (), "expert": (), "expert_mlp": (),
    "ssm_heads": (), "conv": (),
}

# Tuned knobs from EXPERIMENTS.md §Perf (one entry per hillclimbed cell;
# the iteration log references the tags).
TUNED: dict[tuple[str, str], Knobs] = {
    # q7: FSDP rules — collective 60.7 -> 22.7 s, frac 0.238 -> 0.360.
    # (q5 = rs_epilogue + micro4 is the fits-16GiB alternative, frac 0.290)
    ("qwen2-72b", "train_4k"): Knobs(rules=FSDP_RULES),
    # multi-pod: 512 chips > global batch 256, so pure FSDP cannot shard
    # the batch (8x redundant compute, useful ratio 0.048) — the q5
    # TP+SP config is the right 512-chip posture at this batch size.
    ("qwen2-72b", "train_4k", "multi"): Knobs(rs_epilogue=True,
                                              num_microbatches=4),
    # m5: bf16 RS epilogues + 2 microbatches — frac 0.252, peak 43.6->30.1
    ("mixtral-8x22b", "train_4k"): Knobs(rs_epilogue=True,
                                         num_microbatches=2),
    # z6: remat OFF (1.2B params: recompute cost >> checkpoint savings) +
    # 8 microbatches + RS epilogues — memory 13.9 -> 6.9 s, peak 168 -> 19
    ("zamba2-1.2b", "train_4k"): Knobs(remat=False, num_microbatches=8,
                                       rs_epilogue=True),
    # -- extended sweep: the generalized mechanisms applied table-wide ----
    # g2: score seq-shard (4 heads cannot shard 16 ways) + remat off
    # (unrolled 1B stack) — memory 22.1 -> 10.9 s, peak 62 -> 17
    ("gemma3-1b", "train_4k"): Knobs(tuned_hints=True, remat=False,
                                     num_microbatches=8, rs_epilogue=True),
    # s2: score seq-shard (36 heads) — memory 68.4 -> 11.1 s (6.2x),
    # peak 153 -> 10 GiB (fits v5e)
    ("starcoder2-7b", "train_4k"): Knobs(tuned_hints=True, rs_epilogue=True,
                                         num_microbatches=2),
    # p1: same — memory 46.8 -> 8.6 s, peak 103 -> 14 GiB (fits v5e)
    ("phi4-mini-3.8b", "train_4k"): Knobs(tuned_hints=True, rs_epilogue=True,
                                          num_microbatches=2),
    # l4_3: memory 119.5 -> 26.1 s, peak 312 -> 49 GiB; micro>2 re-plays
    # the EP all-to-all dispatch too often (l4_1/l4_2)
    ("llama4-maverick-400b-a17b", "train_4k"): Knobs(
        tuned_hints=True, rs_epilogue=True, num_microbatches=2),
    # v2: collective 82.4 -> 61.7 s, peak 90 -> 33 GiB
    ("llama-3.2-vision-90b", "train_4k"): Knobs(
        tuned_hints=True, rs_epilogue=True, num_microbatches=4),
    # w1: memory 12.7 -> 3.2 s (75%), peak 51 -> 46 GiB
    ("whisper-small", "train_4k"): Knobs(tuned_hints=True, rs_epilogue=True,
                                         num_microbatches=2),
    # mb2: marginal (+10% on collective); remat-off REFUTED for mamba2 —
    # scan-stacked residuals explode without remat (unlike zamba2's
    # unrolled stack, where remat-off halved traffic)
    ("mamba2-780m", "train_4k"): Knobs(rs_epilogue=True,
                                       num_microbatches=2),
    # -- prefill: the chunked-attention score seq-shard (pf iterations).
    # Archs whose head count divides 16 were already sharded (qwen2,
    # mixtral, vision: no-op); the rest were replicating the per-chunk
    # score tensor across the model axis:
    ("starcoder2-7b", "prefill_32k"): Knobs(tuned_hints=True),   # 132->10s
    ("phi4-mini-3.8b", "prefill_32k"): Knobs(tuned_hints=True),  # 89->7.3s
    ("gemma3-1b", "prefill_32k"): Knobs(tuned_hints=True),       # 13.5->1.8s
    ("whisper-small", "prefill_32k"): Knobs(tuned_hints=True),   # 17->1.4s
    ("llama4-maverick-400b-a17b", "prefill_32k"):
        Knobs(tuned_hints=True),                                 # 221->18s
}


def get(table: str, arch: str, shape: str, mesh: str = "single") -> Knobs:
    tab = BASELINE if table == "baseline" else {**BASELINE, **TUNED}
    # mesh-specific entry wins (e.g. multi-pod needs a different
    # parallelism posture when chips > global batch)
    if (arch, shape, mesh) in tab:
        return tab[(arch, shape, mesh)]
    return tab.get((arch, shape), Knobs())
