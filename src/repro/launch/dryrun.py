import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
# production meshes using 512 placeholder host devices.  The two lines above
# MUST run before any jax import (jax locks the device count on first init).

import argparse
import gc
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import knobs as knobs_mod
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import api as model_api
from repro.sharding import default_rules, tree_shardings
from repro.train import optim, step as step_mod


def _mesh_for(name: str):
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    return make_production_mesh(multi_pod=False)


def _batch_shardings(cfg, shape, mesh, rules):
    structs = step_mod.batch_struct(cfg, shape)
    shardings = step_mod.batch_specs(cfg, mesh, rules, structs)
    return structs, shardings


def lower_cell(arch: str, shape_name: str, mesh_name: str,
               knob_table: str = "baseline", *, unroll: bool = False):
    """Lower + compile one dry-run cell; returns the record dict.

    unroll=True unrolls every scan so XLA's cost_analysis counts each
    layer/microbatch/chunk iteration (a while body is otherwise counted
    once — see repro.models.scanner).  Memory analysis should be read from
    the rolled (unroll=False) pass: unrolling forgoes loop buffer reuse.
    """
    from repro.models import scanner
    scanner.set_unroll(unroll)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kn = knobs_mod.get(knob_table, arch, shape_name, mesh_name)
    cfg = kn.apply(cfg)
    rules = default_rules(**(kn.rules or {}))
    mesh = _mesh_for(mesh_name)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "knobs": knob_table, "kind": shape.kind,
        "devices": int(len(mesh.devices.flatten())),
    }
    t0 = time.time()

    if shape.kind == "train":
        opt = optim.OptConfig(moment_dtype=cfg.opt_moment_dtype)
        state_structs, state_shardings = step_mod.state_shardings(
            cfg, opt, mesh, rules)
        batch_structs, batch_shardings = _batch_shardings(
            cfg, shape, mesh, rules)
        fn = step_mod.make_train_step(cfg, mesh, rules, opt,
                                      num_microbatches=kn.num_microbatches)
        jitted = jax.jit(fn,
                         in_shardings=(state_shardings, batch_shardings),
                         out_shardings=(state_shardings, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_structs, batch_structs)
    elif shape.kind == "prefill":
        params, pspecs = step_mod.serve_param_structs(cfg)
        pshard = tree_shardings(mesh, rules, params, pspecs)
        batch_structs, batch_shardings = _batch_shardings(
            cfg, shape, mesh, rules)
        batch_structs.pop("labels")
        batch_shardings.pop("labels")
        fn = step_mod.make_prefill_step(cfg, mesh, rules)
        cache_structs, cache_specs = model_api.init_cache(
            cfg, shape.global_batch, shape.seq_len)
        cache_shard = tree_shardings(mesh, rules, cache_structs, cache_specs)
        jitted = jax.jit(fn, in_shardings=(pshard, batch_shardings),
                         out_shardings=(None, cache_shard))
        lowered = jitted.lower(params, batch_structs)
    else:  # decode
        params, pspecs = step_mod.serve_param_structs(cfg)
        pshard = tree_shardings(mesh, rules, params, pspecs)
        cache_structs, cache_specs = model_api.init_cache(
            cfg, shape.global_batch, shape.seq_len)
        cache_shard = tree_shardings(mesh, rules, cache_structs, cache_specs)
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = step_mod.make_decode_step(cfg, mesh, rules)
        jitted = jax.jit(fn,
                         in_shardings=(pshard, cache_shard, None, None),
                         out_shardings=(None, cache_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(params, cache_structs, tok, pos)

    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_est": int(ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
    }
    ca = roofline.cost_analysis_dict(compiled)
    # cost_analysis counts while bodies ONCE (verified in tests); the
    # loop-aware text model is authoritative for the roofline terms.
    rec["cost_hlo_body_once"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }
    text = compiled.as_text()
    tc = roofline.text_costs(text)
    flops = float(tc["flops"])
    nbytes = float(tc["bytes"])
    rec["cost"] = {"flops_per_device": flops, "bytes_per_device": nbytes,
                   "source": "text_costs(loop-aware)"}

    colls = roofline.parse_collectives(text)
    rec["collectives"] = roofline.collective_summary(colls)
    rec["roofline"] = roofline.roofline_terms(flops, nbytes, colls)
    rec["unrolled_costs"] = unroll
    rec["while_trips"] = roofline.while_trip_counts(text)
    scanner.set_unroll(False)
    mf = roofline.model_flops(get_config(arch), SHAPES[shape_name])
    rec["model_flops_total"] = mf
    dev = rec["devices"]
    rec["useful_flops_ratio"] = (mf / dev) / flops if flops else 0.0
    # top-10 largest collectives, for the perf log
    top = sorted(colls, key=lambda c: -c.wire_bytes)[:10]
    rec["top_collectives"] = [
        {"op": c.op, "bytes": c.result_bytes, "group": c.group_size,
         "pod": c.crosses_pod, "wire": int(c.wire_bytes)} for c in top]
    del compiled, lowered, text
    gc.collect()
    return rec


def iter_cells(mesh_names):
    for arch, cfg in ARCHS.items():
        for shape_name, shape in SHAPES.items():
            run, why = shape_applicable(cfg, shape)
            for mesh_name in mesh_names:
                yield arch, shape_name, mesh_name, run, why


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--knobs", default="baseline",
                    choices=["baseline", "tuned"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose result JSON already exists")
    args = ap.parse_args()

    mesh_names = {"both": ["single", "multi"], "single": ["single"],
                  "multi": ["multi"]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    cells = [(a, s, m, run, why) for a, s, m, run, why in iter_cells(mesh_names)
             if (args.arch in (None, a)) and (args.shape in (None, s))]
    if args.list:
        for c in cells:
            print(*c)
        return 0

    failures = 0
    for arch, shape_name, mesh_name, run, why in cells:
        tag = f"{arch}__{shape_name}__{mesh_name}__{args.knobs}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"HAVE {tag}", flush=True)
            continue
        if not run:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "knobs": args.knobs, "skipped": True, "reason": why}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"SKIP {tag}: {why}", flush=True)
            continue
        try:
            rec = lower_cell(arch, shape_name, mesh_name, args.knobs)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            r = rec["roofline"]
            print(f"OK   {tag} compile={rec['compile_s']}s "
                  f"flops/dev={rec['cost']['flops_per_device']:.3e} "
                  f"bottleneck={r['bottleneck']} "
                  f"frac={r['roofline_fraction']:.3f}", flush=True)
        except Exception as e:  # noqa: BLE001 - record and continue
            failures += 1
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())
            print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
