"""Dictionary decode — Pallas TPU kernel.

DICT-encoded column chunks store (codes: intK, dictionary: D values);
decoding is ``values[i] = dictionary[codes[i]]``.  On CPU this is a
pointer-chasing gather; on TPU we keep the dictionary resident in VMEM
across the whole grid (its BlockSpec index_map is constant, so Pallas
streams it in once) and decode a (TILE,) code block per step.

Two in-kernel strategies, chosen statically by dictionary size:

  one-hot matmul (D <= ONEHOT_MAX)   codes -> one-hot (TILE, D) -> MXU
       dot with the dictionary.  Systolic-array friendly; exact for f32
       payloads and for ints < 2**24 (all our dictionaries qualify).
  vector gather  (D  > ONEHOT_MAX)   jnp.take on the VMEM-resident
       dictionary (VPU dynamic-gather path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024
ONEHOT_MAX = 2048     # one-hot matmul cutover: (TILE x D) f32 must fit VMEM


def _kernel(codes_ref, dict_ref, out_ref, *, use_onehot: bool):
    codes = codes_ref[...]                      # (TILE,) int32
    d = dict_ref[...]                           # (D_pad,) f32
    if use_onehot:
        onehot = (codes[:, None] == jnp.arange(d.shape[0], dtype=jnp.int32)
                  [None, :]).astype(jnp.float32)          # (TILE, D)
        out_ref[...] = onehot @ d                          # MXU
    else:
        out_ref[...] = jnp.take(d, codes, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dict_decode(codes: jax.Array, dictionary: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """codes (N,) int32, dictionary (D,) f32 -> (N,) f32 decoded values.

    N must be a multiple of TILE and D a multiple of 128 (ops.py pads)."""
    n, = codes.shape
    d, = dictionary.shape
    if n % TILE or d % 128:
        raise ValueError(f"unpadded shapes N={n} D={d}; use ops.py")
    use_onehot = d <= ONEHOT_MAX
    return pl.pallas_call(
        functools.partial(_kernel, use_onehot=use_onehot),
        grid=(n // TILE,),
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,)),
                  pl.BlockSpec((d,), lambda i: (0,))],   # resident
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), dictionary.dtype),
        interpret=interpret,
    )(codes, dictionary)
