"""Public entry for dictionary decode: padding + dtype management."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dict_decode.dict_decode import TILE, dict_decode

_INTERPRET = jax.default_backend() == "cpu"


def decode_dictionary(codes, dictionary):
    """codes (N,) int, dictionary (D,) numeric -> (N,) decoded values.

    Integer dictionaries must fit the f32-exact domain (< 2**24); all
    corpus dictionaries (token ids, domain ids) do.  64-bit requests come
    back as numpy arrays of the original dtype (jax canonicalizes to 32
    bits on-device; the exactness domain makes the widening lossless).
    """
    out_dtype = np.dtype(getattr(dictionary, "dtype", np.float32))
    codes = jnp.asarray(codes, jnp.int32)
    dictionary = jnp.asarray(dictionary)
    if jnp.issubdtype(out_dtype, jnp.integer):
        if np.abs(np.asarray(dictionary)).max(initial=0) >= 2 ** 24:
            raise ValueError("int dictionary exceeds f32-exact domain")
        dic = dictionary.astype(jnp.float32)
    else:
        dic = dictionary.astype(jnp.float32)
    n = codes.shape[0]
    pad_n = (-n) % TILE
    pad_d = (-dic.shape[0]) % 128
    if pad_n:
        codes = jnp.pad(codes, (0, pad_n))
    if pad_d:
        dic = jnp.pad(dic, (0, pad_d))
    out = dict_decode(codes, dic, interpret=_INTERPRET)[:n]
    if out_dtype.itemsize == 8:                     # non-canonical in jax
        out = np.asarray(out)
        return (np.round(out) if out_dtype.kind in "iu" else out
                ).astype(out_dtype)
    if jnp.issubdtype(out_dtype, jnp.integer):
        return jnp.round(out).astype(out_dtype)
    return out.astype(out_dtype)
