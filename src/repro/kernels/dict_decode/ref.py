"""Pure-jnp oracle for dictionary decode."""

from __future__ import annotations

import jax.numpy as jnp


def dict_decode_ref(codes, dictionary):
    return jnp.take(dictionary, codes, axis=0)
