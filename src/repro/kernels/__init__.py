"""Pallas TPU kernels for the scan hot path (DESIGN.md §2).

The paper's scan runs on storage-node Xeons; on a TPU fleet the "free"
compute near the data is the accelerator, so the residual decode work
(dictionary decode, predicate evaluation, selection) gets MXU/VPU kernels:

  predicate_fused   multi-column compare + logic -> byte mask (one pass)
  dict_decode       dictionary gather (one-hot MXU matmul or VPU gather)
  token_pack        masked stream compaction to (fixed buffer, count)

These are load-bearing for the storage half of the repo: the client-side
decode engine (``repro.aformat.decode.PallasBackend``, reached through
``decode_backend="pallas"`` on any Dataset scan) batches DICT column
chunks through ``decode_dictionary``, lowers flat AND/OR comparison
predicates to ``build_program``/``fused_predicate`` so mask evaluation
fuses across columns, and compacts selections with ``pack_tokens``; the
adaptive scheduler prices client placement with the backend's decode
rate.  Off-accelerator the ops run ``interpret=True``, so results stay
byte-identical to the host path (pinned by ``tests/test_decode.py``).

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper with padding), ref.py (pure-jnp oracle for the allclose tests).
RLE/bit-packed *byte-stream* decode is inherently sequential and stays on
the host path (DESIGN.md §2, non-transferable).
"""

from repro.kernels.dict_decode.ops import decode_dictionary
from repro.kernels.predicate_fused.ops import build_program, fused_predicate
from repro.kernels.token_pack.ops import pack_tokens

__all__ = ["decode_dictionary", "build_program", "fused_predicate",
           "pack_tokens"]
