"""Pallas TPU kernels for the scan hot path (DESIGN.md §2).

The paper's scan runs on storage-node Xeons; on a TPU fleet the "free"
compute near the data is the accelerator, so the residual decode work
(dictionary decode, predicate evaluation, selection) gets MXU/VPU kernels:

  predicate_fused   multi-column compare + logic -> byte mask (one pass)
  dict_decode       dictionary gather (one-hot MXU matmul or VPU gather)
  token_pack        masked stream compaction to (fixed buffer, count)

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper with padding), ref.py (pure-jnp oracle for the allclose tests).
RLE/bit-packed *byte-stream* decode is inherently sequential and stays on
the host path (DESIGN.md §2, non-transferable).
"""

from repro.kernels.dict_decode.ops import decode_dictionary
from repro.kernels.predicate_fused.ops import build_program, fused_predicate
from repro.kernels.token_pack.ops import pack_tokens

__all__ = ["decode_dictionary", "build_program", "fused_predicate",
           "pack_tokens"]
