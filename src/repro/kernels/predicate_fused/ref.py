"""Pure-jnp oracle for the fused predicate kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.predicate_fused.predicate_fused import Program, Term


def _term(cols, t: Term):
    x = cols[t.col]
    v = jnp.float32(t.value)
    return {"lt": x < v, "le": x <= v, "gt": x > v, "ge": x >= v,
            "eq": x == v, "ne": x != v}[t.op]


def predicate_mask_ref(cols, prog: Program):
    acc = _term(cols, prog.terms[0])
    for t in prog.terms[1:]:
        m = _term(cols, t)
        acc = acc & m if prog.combine == "and" else acc | m
    if prog.negate:
        acc = ~acc
    return acc.astype(jnp.uint8)
