"""Public entry for the fused predicate kernel: padding + program build.

``build_program`` translates a (restricted) repro.aformat expression — a
flat AND/OR of column-vs-constant comparisons — into the kernel's static
Program against a given column ordering.  Columns are cast to f32; the
f32-exactness domain (|int| < 2**24) covers every corpus column we emit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.predicate_fused.predicate_fused import (TILE, Program,
                                                           Term,
                                                           predicate_mask)

_INTERPRET = jax.default_backend() == "cpu"


def build_program(terms: list[tuple[int, str, float]], combine: str = "and",
                  negate: bool = False) -> Program:
    return Program(tuple(Term(c, op, float(v)) for c, op, v in terms),
                   combine, negate)


@functools.partial(jax.jit, static_argnames=("prog",))
def _stack(cols, prog):
    return jnp.stack([c.astype(jnp.float32) for c in cols])


def fused_predicate(cols: list[jax.Array | np.ndarray], prog: Program
                    ) -> jax.Array:
    """cols: list of (N,) arrays -> (N,) bool mask."""
    n = int(np.shape(cols[0])[0])
    stacked = _stack([jnp.asarray(c) for c in cols], prog)
    pad = (-n) % TILE
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    mask = predicate_mask(stacked, prog, interpret=_INTERPRET)
    return mask[:n].astype(bool)
