"""Fused multi-column predicate evaluation — Pallas TPU kernel.

The scan hot loop evaluates ``(col_a OP c_a) COMBINE (col_b OP c_b) ...``
over millions of rows.  On CPU Arrow does this one compare kernel at a
time, materializing an intermediate mask per term; on TPU we fuse every
term into one VMEM pass: the C predicate columns arrive as a (C, N) stack,
each grid step streams a (C, TILE) block into VMEM, evaluates all compares
on the VPU and combines them in registers, emitting one (TILE,) byte mask.
Arithmetic intensity is (C compares + C-1 logicals) per C·4 bytes — memory
bound, which is exactly why fusing (one pass, no intermediate masks)
matters.

The predicate program is *static* (baked at trace time): real systems
compile predicates once per query; specializing the kernel per query shape
is the TPU analogue.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048   # lanes per grid step; multiple of 128

# comparison opcodes
OPS = ("lt", "le", "gt", "ge", "eq", "ne")


@dataclasses.dataclass(frozen=True)
class Term:
    col: int          # row index into the (C, N) column stack
    op: str           # one of OPS
    value: float      # compare constant (f32-exact domain: ints < 2**24)


@dataclasses.dataclass(frozen=True)
class Program:
    terms: tuple[Term, ...]
    combine: str = "and"          # "and" | "or"
    negate: bool = False

    def __post_init__(self):
        if self.combine not in ("and", "or"):
            raise ValueError(self.combine)
        for t in self.terms:
            if t.op not in OPS:
                raise ValueError(t.op)


def _apply_term(cols, t: Term):
    x = cols[t.col]
    v = jnp.float32(t.value)
    return {
        "lt": lambda: x < v, "le": lambda: x <= v,
        "gt": lambda: x > v, "ge": lambda: x >= v,
        "eq": lambda: x == v, "ne": lambda: x != v,
    }[t.op]()


def _kernel(cols_ref, out_ref, *, prog: Program):
    cols = cols_ref[...]                       # (C, TILE) f32 in VMEM
    acc = _apply_term(cols, prog.terms[0])
    for t in prog.terms[1:]:
        m = _apply_term(cols, t)
        acc = jnp.logical_and(acc, m) if prog.combine == "and" \
            else jnp.logical_or(acc, m)
    if prog.negate:
        acc = jnp.logical_not(acc)
    out_ref[...] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("prog", "interpret"))
def predicate_mask(cols: jax.Array, prog: Program, *,
                   interpret: bool = False) -> jax.Array:
    """cols: (C, N) float32 (N a multiple of TILE) -> (N,) uint8 mask."""
    c, n = cols.shape
    if n % TILE:
        raise ValueError(f"N={n} not a multiple of {TILE}; pad in ops.py")
    grid = (n // TILE,)
    return pl.pallas_call(
        functools.partial(_kernel, prog=prog),
        grid=grid,
        in_specs=[pl.BlockSpec((c, TILE), lambda i: (0, i))],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint8),
        interpret=interpret,
    )(cols)
