"""Public entry for token packing: pad, tile-pack, gather-merge.

``pack_tokens`` is the full TPU Filter analogue: (values, mask, capacity)
-> (packed[capacity], count).  The expensive data-dependent compaction
runs in the Pallas kernel per tile; the inter-tile merge is one gather
computed from the tile-count prefix sum (plain XLA, bandwidth-bound).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.token_pack.token_pack import TILE, tile_pack

_INTERPRET = jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def _pack(values, mask, capacity: int, interpret: bool):
    n = values.shape[0]
    pad = (-n) % TILE
    v = jnp.pad(values.astype(jnp.float32), (0, pad))
    m = jnp.pad(mask.astype(jnp.uint8), (0, pad))
    packed_tiles, counts = tile_pack(v, m, interpret=interpret)

    offsets = jnp.cumsum(counts) - counts            # tile -> global base
    total = jnp.minimum(jnp.sum(counts), capacity)
    # output slot j comes from tile t(j) = searchsorted(cum, j, right),
    # local slot j - offsets[t]
    j = jnp.arange(capacity, dtype=jnp.int32)
    cum = jnp.cumsum(counts)
    t = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
    t = jnp.minimum(t, counts.shape[0] - 1)
    local = j - offsets[t]
    flat = packed_tiles.reshape(-1)
    out = jnp.where(j < total, flat[t * TILE + local], 0.0)
    return out, total


def pack_tokens(values, mask, capacity: int):
    """values (N,), mask (N,) -> (packed (capacity,), count scalar).

    Integer inputs must be f32-exact (< 2**24): true for token ids."""
    values = jnp.asarray(values)
    out_dtype = values.dtype
    out, total = _pack(values, jnp.asarray(mask), capacity, _INTERPRET)
    if jnp.issubdtype(out_dtype, jnp.integer):
        out = jnp.round(out).astype(out_dtype)
    return out, total
