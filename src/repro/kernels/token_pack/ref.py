"""Pure-jnp oracle for token packing."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pack_ref(values, mask, capacity: int, fill=0):
    """Variable-length filter, then pad to capacity (numpy semantics)."""
    v = np.asarray(values)
    m = np.asarray(mask).astype(bool)
    kept = v[m][:capacity]
    out = np.full(capacity, fill, v.dtype)
    out[: len(kept)] = kept
    return out, min(int(m.sum()), capacity)


def tile_pack_ref(values, mask, tile: int):
    """Oracle for the in-kernel per-tile stage."""
    v = np.asarray(values).reshape(-1, tile)
    m = np.asarray(mask).astype(bool).reshape(-1, tile)
    tiles = v.shape[0]
    packed = np.zeros((tiles, tile), np.float32)
    counts = np.zeros(tiles, np.int32)
    for t in range(tiles):
        kept = v[t][m[t]]
        packed[t, : len(kept)] = kept
        counts[t] = len(kept)
    return packed, counts
