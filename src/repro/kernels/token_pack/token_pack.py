"""Masked stream compaction (token packing) — Pallas TPU kernel.

Arrow's CPU ``Filter`` kernel emits a variable-length output — impossible
on TPU, where every shape is static.  The TPU-idiomatic equivalent returns
(fixed-capacity packed buffer, valid count).  Strategy:

  per tile (in-kernel, this file):
    pos     = exclusive-cumsum(mask)            # VPU scan
    onehot  = (pos[i] == j) & mask[i]           # (TILE, TILE) selection mx
    packed  = onehot^T @ values                 # MXU matmul compaction
    count   = sum(mask)

  across tiles (ops.py epilogue, plain XLA):
    per-tile packed buffers are gathered to their global offsets
    (cumsum of counts) with one take — cheap, bandwidth-bound.

The matmul trick turns data-dependent scatter (which the MXU cannot do)
into a dense systolic op; values must be f32-exact (floats, or ints
< 2**24 — token ids always are).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 512    # (TILE x TILE) f32 one-hot = 1 MiB VMEM


def _kernel(vals_ref, mask_ref, packed_ref, count_ref):
    v = vals_ref[...]                                   # (TILE,) f32
    m = mask_ref[...].astype(jnp.int32)                 # (TILE,)
    pos = jnp.cumsum(m) - m                             # exclusive scan
    idx = jnp.arange(TILE, dtype=jnp.int32)
    onehot = ((pos[:, None] == idx[None, :]) &
              (m[:, None] == 1)).astype(jnp.float32)    # (TILE, TILE)
    packed_ref[...] = (onehot.T @ v)[None, :]           # (1, TILE)
    count_ref[...] = jnp.sum(m, keepdims=True)          # (1,)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tile_pack(values: jax.Array, mask: jax.Array, *,
              interpret: bool = False):
    """values (N,) f32, mask (N,) uint8 -> (N//TILE, TILE) per-tile packed
    buffers + (N//TILE,) counts.  N must be a multiple of TILE."""
    n, = values.shape
    if n % TILE:
        raise ValueError(f"N={n} not a multiple of {TILE}; pad in ops.py")
    tiles = n // TILE
    return pl.pallas_call(
        _kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,)),
                  pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((1, TILE), lambda i: (i, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((tiles, TILE), jnp.float32),
                   jax.ShapeDtypeStruct((tiles,), jnp.int32)],
        interpret=interpret,
    )(values, mask)
