"""Training ingest (deprecated shim): ``TokenPipeline`` over the new
sharded reader.

The real ingest plane now lives in :mod:`repro.ingest` —
``ShardedReader`` runs every scan through the query plan (stats pruning,
projection pushdown, the shared streaming executor, QoS admission) and
is checkpointable and elastic.  ``TokenPipeline`` remains for one
release as a thin wrapper that preserves the historic constructor and
iterator surface, with one behavior fix: a rank with no fragments is a
legal empty shard (it yields nothing) instead of a crash, so a fleet
with more ranks than fragments stays up.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Iterator

import numpy as np

from repro.aformat.expressions import Expr
from repro.dataset.dataset import Dataset
from repro.dataset.format import (FileFormat, ParquetFormat,
                                  PushdownParquetFormat)
from repro.ingest.reader import Prefetcher, ReaderConfig, ShardedReader


@dataclasses.dataclass
class PipelineConfig:
    seq_len: int
    local_batch: int
    predicate: Expr | None = None          # e.g. quality > 0.8
    format: str = "pushdown"                # "pushdown" | "parquet"
    num_threads: int = 4
    queue_depth: int = 4
    seed: int = 0
    prefetch: int = 2                       # double-buffer depth
    hedge_threshold_s: float | None = None


def _make_format(cfg: PipelineConfig) -> FileFormat:
    if cfg.format == "pushdown":
        return PushdownParquetFormat(hedge_threshold_s=cfg.hedge_threshold_s)
    return ParquetFormat()


class TokenPipeline:
    """Deprecated: use :class:`repro.ingest.ShardedReader`.

    Iterator of {"tokens","labels"} host batches for one DP rank,
    now backed by the sharded reader (same packing, same shapes; shard
    assignment is row-balanced rather than round-robin)."""

    def __init__(self, ds: Dataset, cfg: PipelineConfig, *,
                 dp_rank: int = 0, dp_size: int = 1):
        warnings.warn(
            "TokenPipeline is deprecated; use repro.ingest.ShardedReader "
            "(sharded, checkpointable, elastic, QoS-aware)",
            DeprecationWarning, stacklevel=2)
        if not (0 <= dp_rank < dp_size):
            raise ValueError("bad dp_rank/dp_size")
        self.ds = ds
        self.cfg = cfg
        rcfg = ReaderConfig(
            seq_len=cfg.seq_len, local_batch=cfg.local_batch,
            predicate=cfg.predicate, format=_make_format(cfg),
            num_threads=cfg.num_threads, queue_depth=cfg.queue_depth,
            seed=cfg.seed, prefetch=cfg.prefetch)
        self.reader = ShardedReader(ds, rcfg, dp_rank=dp_rank,
                                    dp_size=dp_size)
        self.fmt = self.reader.fmt
        self.fragments = [t.fragment for t in self.reader.shard_tasks]

    def batches(self) -> Iterator[dict[str, np.ndarray]]:
        """Pack the filtered token stream into (B, S) token/label pairs."""
        for batch, _state in self.reader.batches():
            yield batch

    def __iter__(self):
        return Prefetcher(self.batches(), self.cfg.prefetch)

    def close(self):
        self.reader.close()

    # -- accounting ----------------------------------------------------------------
    def stats(self) -> dict:
        d = self.reader.stats()
        return {k: d[k] for k in ("fragments_scanned", "client_cpu_s",
                                  "osd_cpu_s", "wire_bytes", "rows")}


def device_put_batch(batch: dict[str, np.ndarray], mesh, rules):
    """Host batch -> sharded global jax.Arrays (batch over (pod, data))."""
    import jax
    from jax.sharding import NamedSharding

    from repro.sharding import resolve_spec

    out = {}
    for k, v in batch.items():
        logical = ("batch",) + (None,) * (v.ndim - 1)
        sh = NamedSharding(mesh, resolve_spec(mesh, rules, logical, v.shape))
        out[k] = jax.device_put(v, sh)
    return out
