"""Training ingest: per-DP-rank pushdown scans -> host packing -> device.

Each data-parallel rank owns a disjoint subset of the corpus fragments
(round-robin over the sorted fragment list — the multi-host analogue of the
paper's single client).  Fragments are scanned through the Dataset API with
whichever FileFormat placement the run selects, filtered tokens are packed
into fixed (local_batch, seq_len+1) arrays, and a double-buffered
background prefetcher overlaps the next batch's scan with the current
step's compute — the compute/IO-overlap trick at the heart of keeping a
197-TFLOP/s chip fed by a storage-limited input path.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.aformat.expressions import ALL, NONE, Expr
from repro.dataset.dataset import Dataset
from repro.dataset.format import (FileFormat, ParquetFormat,
                                  PushdownParquetFormat, TaskRecord)


@dataclasses.dataclass
class PipelineConfig:
    seq_len: int
    local_batch: int
    predicate: Expr | None = None          # e.g. quality > 0.8
    format: str = "pushdown"                # "pushdown" | "parquet"
    num_threads: int = 4
    queue_depth: int = 4
    seed: int = 0
    prefetch: int = 2                       # double-buffer depth
    hedge_threshold_s: float | None = None


def _make_format(cfg: PipelineConfig) -> FileFormat:
    if cfg.format == "pushdown":
        return PushdownParquetFormat(hedge_threshold_s=cfg.hedge_threshold_s)
    return ParquetFormat()


class TokenPipeline:
    """Iterator of {"tokens","labels"} host batches for one DP rank."""

    def __init__(self, ds: Dataset, cfg: PipelineConfig, *,
                 dp_rank: int = 0, dp_size: int = 1):
        if not (0 <= dp_rank < dp_size):
            raise ValueError("bad dp_rank/dp_size")
        self.ds = ds
        self.cfg = cfg
        self.fmt = _make_format(cfg)
        frags = sorted(ds.fragments(), key=lambda f: (f.path, f.obj_idx,
                                                      f.rg_in_object))
        self.fragments = frags[dp_rank::dp_size]
        if not self.fragments:
            raise ValueError(f"rank {dp_rank}: no fragments")
        self.records: list[TaskRecord] = []
        self._lock = threading.Lock()

    # -- fragment-level scan ----------------------------------------------------
    def _scan(self, frag) -> np.ndarray:
        pred = self.cfg.predicate
        if pred is not None and frag.stats:
            verdict = pred.prune(frag.stats)
            if verdict == NONE:
                return np.empty(0, np.int32)
            if verdict == ALL:
                pred = None
        tbl, rec = self.fmt.scan_fragment(self.ds.fs, frag, ["token"], pred)
        with self._lock:
            self.records.append(rec)
        return np.ascontiguousarray(tbl.column("token").values, np.int32)

    # -- epoch stream -------------------------------------------------------------
    def _token_stream(self) -> Iterator[np.ndarray]:
        rng = np.random.default_rng(self.cfg.seed)
        epoch = 0
        while True:
            order = rng.permutation(len(self.fragments))
            for i in order:
                toks = self._scan(self.fragments[i])
                if len(toks):
                    yield toks
            epoch += 1

    def batches(self) -> Iterator[dict[str, np.ndarray]]:
        """Pack the filtered token stream into (B, S) token/label pairs."""
        need = self.cfg.local_batch * (self.cfg.seq_len + 1)
        buf = np.empty(0, np.int32)
        for toks in self._token_stream():
            buf = np.concatenate([buf, toks]) if len(buf) else toks
            while len(buf) >= need:
                chunk = buf[:need].reshape(self.cfg.local_batch,
                                           self.cfg.seq_len + 1)
                buf = buf[need:]
                yield {"tokens": np.ascontiguousarray(chunk[:, :-1]),
                       "labels": np.ascontiguousarray(chunk[:, 1:])}

    def __iter__(self):
        return Prefetcher(self.batches(), self.cfg.prefetch)

    # -- accounting ----------------------------------------------------------------
    def stats(self) -> dict:
        recs = self.records
        return {
            "fragments_scanned": len(recs),
            "client_cpu_s": round(sum(r.client_cpu_s for r in recs), 4),
            "osd_cpu_s": round(sum(r.cpu_s for r in recs
                                   if r.where == "osd"), 4),
            "wire_bytes": sum(r.wire_bytes for r in recs),
            "rows": sum(r.rows_out for r in recs),
        }


class Prefetcher:
    """Double-buffered background prefetch (compute/IO overlap)."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._err: BaseException | None = None
        self._thread = threading.Thread(target=self._run, args=(it,),
                                        daemon=True)
        self._thread.start()

    def _run(self, it):
        try:
            for item in it:
                self._q.put(item)
        except BaseException as e:  # noqa: BLE001 — re-raised on main thread
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def device_put_batch(batch: dict[str, np.ndarray], mesh, rules):
    """Host batch -> sharded global jax.Arrays (batch over (pod, data))."""
    import jax
    from jax.sharding import NamedSharding

    from repro.sharding import resolve_spec

    out = {}
    for k, v in batch.items():
        logical = ("batch",) + (None,) * (v.ndim - 1)
        sh = NamedSharding(mesh, resolve_spec(mesh, rules, logical, v.shape))
        out[k] = jax.device_put(v, sh)
    return out
