from repro.data.corpus import CORPUS_SCHEMA, synth_corpus, write_corpus
from repro.data.pipeline import (PipelineConfig, Prefetcher, TokenPipeline,
                                 device_put_batch)

__all__ = ["CORPUS_SCHEMA", "synth_corpus", "write_corpus",
           "PipelineConfig", "Prefetcher", "TokenPipeline",
           "device_put_batch"]
