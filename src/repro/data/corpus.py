"""Columnar token corpus: the training-data analogue of the NYC-taxi table.

One row per token, with document-level quality / domain / language columns
replicated onto every token row.  This is what makes the paper's technique
bite for *training ingest*: quality- and domain-filtering are data-reducing
predicates, so pushing them into the storage layer returns only the tokens
a step actually trains on — the client (TPU host) stops burning CPU on
decode+filter of data it was going to drop.
"""

from __future__ import annotations

import numpy as np

from repro.aformat.schema import Schema, schema
from repro.aformat.table import Table
from repro.storage import layouts
from repro.storage.cephfs import CephFS

CORPUS_SCHEMA: Schema = schema(
    ("doc_id", "int64"),
    ("pos", "int32"),
    ("token", "int32"),
    ("quality", "float32"),
    ("domain", "int32"),
)

WRITERS = {"flat": layouts.write_flat, "striped": layouts.write_striped,
           "split": layouts.write_split}


def synth_corpus(num_docs: int, *, mean_doc_len: int = 512,
                 vocab_size: int = 32000, num_domains: int = 8,
                 seed: int = 0, distribution: str = "uniform") -> Table:
    """Synthesize a corpus with per-document quality scores and domains.

    distribution="zipf" draws tokens from a Zipf(1.3) unigram law — a
    learnable distribution (entropy << log V) for end-to-end training
    demos; "uniform" keeps the irreducible-entropy stream used by tests.
    """
    rng = np.random.default_rng(seed)
    lens = np.maximum(16, rng.poisson(mean_doc_len, num_docs))
    total = int(lens.sum())
    doc_id = np.repeat(np.arange(num_docs, dtype=np.int64), lens)
    pos = np.concatenate([np.arange(n, dtype=np.int32) for n in lens])
    if distribution == "zipf":
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** -1.3
        p /= p.sum()
        token = rng.choice(vocab_size, total, p=p).astype(np.int32)
    else:
        token = rng.integers(0, vocab_size, total).astype(np.int32)
    quality = np.repeat(rng.beta(2.0, 2.0, num_docs).astype(np.float32),
                        lens)
    domain = np.repeat(rng.integers(0, num_domains, num_docs).astype(
        np.int32), lens)
    return Table.from_pydict(
        {"doc_id": doc_id, "pos": pos, "token": token,
         "quality": quality, "domain": domain}, CORPUS_SCHEMA)


def write_corpus(fs: CephFS, prefix: str, table: Table, *,
                 num_shards: int = 8, row_group_rows: int = 16384,
                 layout: str = "flat") -> None:
    """Shard a corpus table into ``num_shards`` files under ``prefix``.

    Shards split on document boundaries so a document never straddles a
    shard (row groups inside a shard may still split documents; the
    pipeline's packer is sequence-oriented and does not care).
    """
    writer = WRITERS[layout]
    doc = table.column("doc_id").values
    bounds = np.searchsorted(
        doc, np.linspace(doc[0], doc[-1] + 1, num_shards + 1))
    for i in range(num_shards):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        if hi <= lo:
            continue
        part = table.slice(lo, hi - lo)
        writer(fs, f"{prefix}/shard{i:05d}.arw", part,
               row_group_rows=row_group_rows)
