"""RADOS-analogue programmable object store.

Real data structures and byte-level semantics; the transport is in-process.
Objects are placed on OSDs via PG hashing + a deterministic CRUSH-like
replica permutation, written with 3-way replication, and read from the
primary with automatic failover to replicas.  Every OSD tracks busy-time
and byte counters — the inputs to the paper's Fig.-6 CPU-utilization
reproduction — and supports failure + straggler injection.

Two pieces feed the adaptive scan scheduler
(``repro.dataset.scheduler``):

* **Load accounting** — each OSD tracks in-flight object-class calls
  (queued + executing) and caps concurrent execution at its thread count;
  ``ObjectStore.load_of`` snapshots (busy_s, inflight, straggle_factor)
  into an :class:`OSDLoad` whose ``pressure`` is the scheduler's
  saturation signal.
* **Object versions** — every ``put``/``delete`` bumps a per-object
  version counter; ``ObjectStore.version_of`` exposes it so decoded
  result caches are invalidated by overwrites.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import threading
import time
import zlib
from typing import Any, Callable

DEFAULT_PG_NUM = 128


class OSDDownError(RuntimeError):
    pass


class ObjectNotFound(KeyError):
    pass


@dataclasses.dataclass
class OSDStats:
    bytes_stored: int = 0
    objects: int = 0
    reads: int = 0
    writes: int = 0
    cls_calls: int = 0
    busy_s: float = 0.0
    bytes_read: int = 0
    bytes_written: int = 0
    bytes_returned: int = 0


@dataclasses.dataclass(frozen=True)
class OSDLoad:
    """Point-in-time load snapshot of one OSD (``ObjectStore.load_of``).

    ``inflight`` counts object-class calls queued *or* executing on the
    node; ``pressure`` is the service-time inflation the scheduler should
    expect relative to an idle node: the straggle factor scaled by how
    oversubscribed the node's thread pool is.
    """

    osd_id: int
    busy_s: float
    inflight: int
    threads: int
    straggle_factor: float
    down: bool = False

    @property
    def pressure(self) -> float:
        if self.down:
            return float("inf")
        qd = self.inflight / max(1, self.threads)
        return self.straggle_factor * (1.0 + qd)


class OSD:
    """One storage node: object map + counters + failure/straggler knobs.

    Object-class execution is bounded by ``threads`` concurrent calls
    (``_cls_sem``); calls beyond that queue and show up in ``inflight`` —
    the queue-depth signal the adaptive scheduler reads via ``load_of``.
    """

    _uids = itertools.count()    # process-unique ids (cache keys must not
                                 # collide across clusters sharing osd_ids)

    def __init__(self, osd_id: int, threads: int = 8):
        self.osd_id = osd_id
        self.uid = next(OSD._uids)
        self.threads = threads
        self._objects: dict[str, bytes] = {}
        self._versions: dict[str, int] = {}
        self._lock = threading.Lock()
        self.stats = OSDStats()
        self.down = False
        self.straggle_factor = 1.0   # >1 = this node is slow (hedging tests)
        self.inflight = 0            # cls calls queued + executing
        self.background_load = 0     # simulated external clients' in-flight
                                     # cls calls (multi-tenant benchmarks)
        self._cls_sem = threading.BoundedSemaphore(max(1, threads))

    def _check(self):
        if self.down:
            raise OSDDownError(f"osd.{self.osd_id} is down")

    def put(self, name: str, data: bytes):
        self._check()
        with self._lock:
            old = self._objects.get(name)
            self._objects[name] = bytes(data)
            self._versions[name] = self._versions.get(name, 0) + 1
            self.stats.writes += 1
            self.stats.bytes_written += len(data)
            self.stats.bytes_stored += len(data) - (len(old) if old else 0)
            if old is None:
                self.stats.objects += 1

    def get(self, name: str, offset: int = 0, length: int | None = None
            ) -> bytes:
        self._check()
        with self._lock:
            if name not in self._objects:
                raise ObjectNotFound(name)
            data = self._objects[name]
            self.stats.reads += 1
            end = len(data) if length is None else offset + length
            out = data[offset:end]
            self.stats.bytes_read += len(out)
            return out

    def stat(self, name: str) -> int:
        self._check()
        with self._lock:
            if name not in self._objects:
                raise ObjectNotFound(name)
            return len(self._objects[name])

    def delete(self, name: str):
        self._check()
        with self._lock:
            if name in self._objects:
                data = self._objects.pop(name)
                self._versions[name] = self._versions.get(name, 0) + 1
                self.stats.bytes_stored -= len(data)
                self.stats.objects -= 1

    def contains(self, name: str) -> bool:
        with self._lock:
            return name in self._objects

    def version(self, name: str) -> int:
        """Monotonic per-object write counter (0 = never written here)."""
        with self._lock:
            return self._versions.get(name, 0)

    def list_objects(self) -> list[str]:
        with self._lock:
            return sorted(self._objects)


def _hash32(s: str) -> int:
    return int.from_bytes(hashlib.blake2s(s.encode(),
                                          digest_size=4).digest(), "little")


class ObjectStore:
    """PG-mapped, replicated object store over N OSDs."""

    def __init__(self, num_osds: int, *, replication: int = 3,
                 pg_num: int = DEFAULT_PG_NUM, threads_per_osd: int = 8):
        if num_osds < 1:
            raise ValueError("need at least one OSD")
        self.osds = [OSD(i, threads_per_osd) for i in range(num_osds)]
        self.replication = min(replication, num_osds)
        self.pg_num = pg_num
        self._cls: dict[str, Callable] = {}

    # -- placement -------------------------------------------------------------
    def pg_of(self, name: str) -> int:
        return _hash32(name) % self.pg_num

    def acting_set(self, name: str) -> list[OSD]:
        """CRUSH-like: deterministic pseudo-random replica set for the PG."""
        pg = self.pg_of(name)
        n = len(self.osds)
        seed = _hash32(f"pg:{pg}")
        order = sorted(range(n), key=lambda i: _hash32(f"{seed}:{i}"))
        return [self.osds[i] for i in order[: self.replication]]

    def primary_of(self, name: str) -> OSD:
        return self.acting_set(name)[0]

    # -- I/O ---------------------------------------------------------------------
    def put(self, name: str, data: bytes):
        acting = self.acting_set(name)
        wrote = 0
        for osd in acting:
            try:
                osd.put(name, data)
                wrote += 1
            except OSDDownError:
                continue
        quorum = (self.replication // 2) + 1
        if wrote < quorum:
            raise OSDDownError(
                f"write quorum failed for {name}: {wrote}/{quorum}")

    def get(self, name: str, offset: int = 0, length: int | None = None
            ) -> bytes:
        err: Exception | None = None
        for osd in self.acting_set(name):
            try:
                return osd.get(name, offset, length)
            except OSDDownError as e:   # failover to replica
                err = e
            except ObjectNotFound as e:
                err = e
        raise err if err else ObjectNotFound(name)

    def stat(self, name: str) -> int:
        err: Exception | None = None
        for osd in self.acting_set(name):
            try:
                return osd.stat(name)
            except (OSDDownError, ObjectNotFound) as e:
                err = e
        raise err if err else ObjectNotFound(name)

    def delete(self, name: str):
        for osd in self.acting_set(name):
            try:
                osd.delete(name)
            except OSDDownError:
                pass

    def exists(self, name: str) -> bool:
        return any(o.contains(name) for o in self.acting_set(name))

    def version_of(self, name: str) -> int:
        """Cluster-wide object version: the max per-replica write counter.
        Any overwrite (or delete) advances it — result-cache keys carry it
        so stale decoded results can never be served."""
        return max((o.version(name) for o in self.acting_set(name)),
                   default=0)

    # -- load signals (adaptive scheduler inputs) -------------------------------
    def load_of(self, osd: "OSD | int") -> OSDLoad:
        """Snapshot one OSD's load: busy seconds, in-flight cls queue depth,
        straggle factor.  ``OSDLoad.pressure`` condenses these into the
        expected service-time inflation the scan scheduler compares against
        a client-side scan."""
        o = self.osds[osd] if isinstance(osd, int) else osd
        return OSDLoad(o.osd_id, o.stats.busy_s,
                       o.inflight + o.background_load, o.threads,
                       o.straggle_factor, o.down)

    def list_objects(self) -> list[str]:
        names: set[str] = set()
        for o in self.osds:
            if not o.down:
                names.update(o.list_objects())
        return sorted(names)

    # -- object classes (the Ceph ObjectClass SDK analogue) ---------------------
    def register_cls(self, method: str, fn: Callable):
        self._cls[method] = fn

    def cls_call(self, name: str, method: str, payload: dict | None = None,
                 *, prefer_osd: OSD | None = None) -> Any:
        """Execute a registered object-class method ON the storage node
        holding the object.  Returns (result, osd_id, elapsed_s)."""
        if method not in self._cls:
            raise KeyError(f"no object class method {method!r}")
        acting = self.acting_set(name)
        candidates = ([prefer_osd] if prefer_osd is not None else []) + acting
        err: Exception | None = None
        for osd in candidates:
            if osd.down or not osd.contains(name):
                continue
            with osd._lock:          # queued: visible to load_of immediately
                osd.inflight += 1
            try:
                with osd._cls_sem:   # per-OSD concurrency = thread count
                    t0 = time.perf_counter()
                    try:
                        result = self._cls[method](ObjectHandle(osd, name),
                                                   payload or {})
                    except OSDDownError as e:
                        err = e
                        continue
                    el = (time.perf_counter() - t0) * osd.straggle_factor
            finally:
                with osd._lock:
                    osd.inflight -= 1
            osd.stats.cls_calls += 1
            osd.stats.busy_s += el
            if isinstance(result, (bytes, bytearray)):
                osd.stats.bytes_returned += len(result)
            return result, osd.osd_id, el
        raise err if err else ObjectNotFound(name)

    # -- health ------------------------------------------------------------------
    def fail_osd(self, osd_id: int):
        self.osds[osd_id].down = True

    def recover_osd(self, osd_id: int):
        self.osds[osd_id].down = False
        # re-replicate: pull objects this OSD should hold from peers
        healed = 0
        for name in self.list_objects():
            acting = self.acting_set(name)
            me = self.osds[osd_id]
            if me in acting and not me.contains(name):
                data = self.get(name)
                me.put(name, data)
                healed += 1
        return healed

    def scrub(self) -> list[str]:
        """Verify replica consistency via checksums; returns bad objects."""
        bad = []
        for name in self.list_objects():
            sums = set()
            for osd in self.acting_set(name):
                if osd.down or not osd.contains(name):
                    continue
                sums.add(zlib.crc32(osd.get(name)))
            if len(sums) > 1:
                bad.append(name)
        return bad

    def total_stats(self) -> OSDStats:
        agg = OSDStats()
        for o in self.osds:
            for f in dataclasses.fields(OSDStats):
                setattr(agg, f.name,
                        getattr(agg, f.name) + getattr(o.stats, f.name))
        return agg


class ObjectHandle:
    """File-like random-access view of one object on one OSD — the
    RandomAccessObject of the paper: lets the embedded access library run
    unmodified against object bytes (implements RandomAccessSource)."""

    def __init__(self, osd: OSD, name: str):
        self._osd = osd
        self.name = name

    @property
    def osd_id(self) -> int:
        return self._osd.osd_id

    @property
    def osd_uid(self) -> int:
        return self._osd.uid

    def version(self) -> int:
        """Write counter of this replica — cache keys for anything derived
        from the object's bytes (parsed footers, decoded results)."""
        return self._osd.version(self.name)

    def read(self, offset: int, length: int) -> bytes:
        return self._osd.get(self.name, offset, length)

    def size(self) -> int:
        return self._osd.stat(self.name)

    def read_all(self) -> bytes:
        return self._osd.get(self.name)
