"""RADOS-analogue programmable object store.

Real data structures and byte-level semantics; the transport is in-process.
Objects are placed on OSDs via PG hashing + a deterministic CRUSH-like
replica permutation, written with 3-way replication, and read from the
primary with automatic failover to replicas.  Every OSD tracks busy-time
and byte counters — the inputs to the paper's Fig.-6 CPU-utilization
reproduction — and supports failure + straggler injection.

Two pieces feed the adaptive scan scheduler
(``repro.dataset.scheduler``):

* **Load accounting** — each OSD tracks in-flight object-class calls
  (queued + executing) and caps concurrent execution at its thread count;
  ``ObjectStore.load_of`` snapshots (busy_s, inflight, straggle_factor)
  into an :class:`OSDLoad` whose ``pressure`` is the scheduler's
  saturation signal.
* **Object versions** — every ``put``/``delete`` bumps a per-object
  version counter; ``ObjectStore.version_of`` exposes it so decoded
  result caches are invalidated by overwrites.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import threading
import time
import zlib
from typing import Any, Callable

DEFAULT_PG_NUM = 128


class OSDDownError(RuntimeError):
    pass


class ObjectNotFound(KeyError):
    pass


class VersionConflictError(RuntimeError):
    """Optimistic-commit failure: the object's cluster version moved past
    the version the writer read (``ObjectStore.put_if_version``)."""

    def __init__(self, name: str, expected: int, actual: int):
        super().__init__(
            f"version conflict on {name!r}: expected {expected}, "
            f"found {actual}")
        self.name = name
        self.expected = expected
        self.actual = actual


@dataclasses.dataclass
class OSDStats:
    bytes_stored: int = 0
    objects: int = 0
    reads: int = 0
    writes: int = 0
    cls_calls: int = 0
    busy_s: float = 0.0
    bytes_read: int = 0
    bytes_written: int = 0
    bytes_returned: int = 0
    hedge_wasted_s: float = 0.0   # busy time burned by losing hedge calls
                                  # (duplicated work, Fig.-6 accounting)
    repaired: int = 0             # objects healed onto this OSD by recovery


@dataclasses.dataclass(frozen=True)
class OSDLoad:
    """Point-in-time load snapshot of one OSD (``ObjectStore.load_of``).

    ``inflight`` counts object-class calls queued *or* executing on the
    node; ``pressure`` is the service-time inflation the scheduler should
    expect relative to an idle node: the straggle factor scaled by how
    oversubscribed the node's thread pool is.
    """

    osd_id: int
    busy_s: float
    inflight: int
    threads: int
    straggle_factor: float
    down: bool = False
    by_tenant: Any = None       # {(tenant, lane): inflight} snapshot, or None
    external: int = 0           # simulated external clients' in-flight calls

    @property
    def pressure(self) -> float:
        if self.down:
            return float("inf")
        qd = self.inflight / max(1, self.threads)
        return self.straggle_factor * (1.0 + qd)


class OSD:
    """One storage node: object map + counters + failure/straggler knobs.

    Object-class execution is bounded by ``threads`` concurrent calls
    (``_cls_sem``); calls beyond that queue and show up in ``inflight`` —
    the queue-depth signal the adaptive scheduler reads via ``load_of``.
    """

    _uids = itertools.count()    # process-unique ids (cache keys must not
                                 # collide across clusters sharing osd_ids)

    def __init__(self, osd_id: int, threads: int = 8):
        self.osd_id = osd_id
        self.uid = next(OSD._uids)
        self.threads = threads
        self._objects: dict[str, bytes] = {}
        self._versions: dict[str, int] = {}
        self._lock = threading.Lock()
        self.stats = OSDStats()
        self.down = False
        self.straggle_factor = 1.0   # >1 = this node is slow (hedging tests)
        self.max_straggle_delay_s = 0.25   # cap on the *real* injected wall
                                     # delay per cls call, so pathological
                                     # factors (1e6 in tests) model huge
                                     # service times without actually
                                     # sleeping them out
        self.inflight = 0            # cls calls queued + executing
        self.inflight_tags: dict[tuple[str, str], int] = {}
                                     # in-flight split by (tenant, lane) —
                                     # the per-tenant load signal behind
                                     # lane-visible placement pricing
        self.background_load = 0     # simulated external clients' in-flight
                                     # cls calls (multi-tenant benchmarks)
        self._cls_sem = threading.BoundedSemaphore(max(1, threads))

    def _check(self):
        if self.down:
            raise OSDDownError(f"osd.{self.osd_id} is down")

    def put(self, name: str, data: bytes):
        self._check()
        with self._lock:
            old = self._objects.get(name)
            self._objects[name] = bytes(data)
            self._versions[name] = self._versions.get(name, 0) + 1
            self.stats.writes += 1
            self.stats.bytes_written += len(data)
            self.stats.bytes_stored += len(data) - (len(old) if old else 0)
            if old is None:
                self.stats.objects += 1

    def get(self, name: str, offset: int = 0, length: int | None = None
            ) -> bytes:
        self._check()
        with self._lock:
            if name not in self._objects:
                raise ObjectNotFound(name)
            data = self._objects[name]
            self.stats.reads += 1
            end = len(data) if length is None else offset + length
            out = data[offset:end]
            self.stats.bytes_read += len(out)
            return out

    def stat(self, name: str) -> int:
        self._check()
        with self._lock:
            if name not in self._objects:
                raise ObjectNotFound(name)
            return len(self._objects[name])

    def delete(self, name: str):
        self._check()
        with self._lock:
            if name in self._objects:
                data = self._objects.pop(name)
                self._versions[name] = self._versions.get(name, 0) + 1
                self.stats.bytes_stored -= len(data)
                self.stats.objects -= 1

    def contains(self, name: str) -> bool:
        with self._lock:
            return name in self._objects

    def peek(self, name: str) -> bytes:
        """Read object bytes for cluster-internal traffic (scrub, recovery)
        without touching the client-visible read counters — Fig.-6 replays
        ``reads``/``bytes_read`` as client load, and background maintenance
        must not pollute them."""
        self._check()
        with self._lock:
            if name not in self._objects:
                raise ObjectNotFound(name)
            return self._objects[name]

    def repair(self, name: str, data: bytes | None, version: int):
        """Install (or, with ``data=None``, remove) an object copy at an
        exact peer version — the recovery path.  Unlike ``put`` this never
        *bumps* the version counter: recovery restores replica agreement,
        it is not a new write, so result/footer caches keyed on the
        version must not be spuriously invalidated."""
        with self._lock:
            old = self._objects.get(name)
            if data is None:
                if old is not None:
                    self._objects.pop(name)
                    self.stats.bytes_stored -= len(old)
                    self.stats.objects -= 1
            else:
                self._objects[name] = bytes(data)
                self.stats.bytes_stored += len(data) - \
                    (len(old) if old is not None else 0)
                if old is None:
                    self.stats.objects += 1
            self._versions[name] = version
            self.stats.repaired += 1

    def version(self, name: str) -> int:
        """Monotonic per-object write counter (0 = never written here)."""
        with self._lock:
            return self._versions.get(name, 0)

    def list_objects(self) -> list[str]:
        with self._lock:
            return sorted(self._objects)


def _hash32(s: str) -> int:
    return int.from_bytes(hashlib.blake2s(s.encode(),
                                          digest_size=4).digest(), "little")


class ObjectStore:
    """PG-mapped, replicated object store over N OSDs."""

    def __init__(self, num_osds: int, *, replication: int = 3,
                 pg_num: int = DEFAULT_PG_NUM, threads_per_osd: int = 8):
        if num_osds < 1:
            raise ValueError("need at least one OSD")
        self.osds = [OSD(i, threads_per_osd) for i in range(num_osds)]
        self.replication = min(replication, num_osds)
        self.pg_num = pg_num
        self._cls: dict[str, Callable] = {}
        self._cas_lock = threading.Lock()   # serializes put_if_version —
                                # the primary-OSD write-serialization point

    # -- placement -------------------------------------------------------------
    def pg_of(self, name: str) -> int:
        return _hash32(name) % self.pg_num

    def acting_set(self, name: str) -> list[OSD]:
        """CRUSH-like: deterministic pseudo-random replica set for the PG."""
        pg = self.pg_of(name)
        n = len(self.osds)
        seed = _hash32(f"pg:{pg}")
        order = sorted(range(n), key=lambda i: _hash32(f"{seed}:{i}"))
        return [self.osds[i] for i in order[: self.replication]]

    def primary_of(self, name: str) -> OSD:
        return self.acting_set(name)[0]

    # -- I/O ---------------------------------------------------------------------
    def put(self, name: str, data: bytes):
        acting = self.acting_set(name)
        wrote = 0
        for osd in acting:
            try:
                osd.put(name, data)
                wrote += 1
            except OSDDownError:
                continue
        quorum = (self.replication // 2) + 1
        if wrote < quorum:
            raise OSDDownError(
                f"write quorum failed for {name}: {wrote}/{quorum}")

    def get(self, name: str, offset: int = 0, length: int | None = None
            ) -> bytes:
        err: Exception | None = None
        for osd in self.acting_set(name):
            try:
                return osd.get(name, offset, length)
            except OSDDownError as e:   # failover to replica
                err = e
            except ObjectNotFound as e:
                err = e
        raise err if err else ObjectNotFound(name)

    def stat(self, name: str) -> int:
        err: Exception | None = None
        for osd in self.acting_set(name):
            try:
                return osd.stat(name)
            except (OSDDownError, ObjectNotFound) as e:
                err = e
        raise err if err else ObjectNotFound(name)

    def delete(self, name: str) -> int:
        """Delete the object from every reachable acting replica.  Returns
        the number of replicas that actually dropped a copy; a down
        replica keeps its (now-stale) copy and counters until
        :meth:`recover_osd` reconciles it by version."""
        dropped = 0
        for osd in self.acting_set(name):
            try:
                held = osd.contains(name)
                osd.delete(name)
                dropped += held
            except OSDDownError:
                pass
        return dropped

    def exists(self, name: str) -> bool:
        """True if any *up* acting replica holds the object.  Down OSDs
        are excluded: their object map is unreachable and may hold ghost
        copies of objects deleted while they were down — membership must
        reflect what the cluster can actually serve."""
        return any(not o.down and o.contains(name)
                   for o in self.acting_set(name))

    def put_if_version(self, name: str, data: bytes,
                       expected_version: int) -> int:
        """Optimistic-concurrency write: install ``data`` only if the
        object's cluster version (:meth:`version_of`) still equals
        ``expected_version`` (0 = object must not exist yet).  The
        check-and-write is serialized store-wide — the analogue of the
        primary OSD ordering all writes to one object — so two writers
        racing on the same head object cannot both win.  Returns the new
        version; raises :class:`VersionConflictError` on a lost race.

        This is the commit primitive of the snapshot/manifest layer
        (``repro.dataset.snapshot``): read head @ v, prepare, commit iff
        still @ v."""
        with self._cas_lock:
            actual = self.version_of(name)
            if actual != expected_version:
                raise VersionConflictError(name, expected_version, actual)
            self.put(name, data)
            return self.version_of(name)

    def version_of(self, name: str) -> int:
        """Cluster-wide object version: the max per-replica write counter.
        Any overwrite (or delete) advances it — result-cache keys carry it
        so stale decoded results can never be served."""
        return max((o.version(name) for o in self.acting_set(name)),
                   default=0)

    # -- load signals (adaptive scheduler inputs) -------------------------------
    def load_of(self, osd: "OSD | int") -> OSDLoad:
        """Snapshot one OSD's load: busy seconds, in-flight cls queue depth,
        straggle factor.  ``OSDLoad.pressure`` condenses these into the
        expected service-time inflation the scan scheduler compares against
        a client-side scan."""
        o = self.osds[osd] if isinstance(osd, int) else osd
        with o._lock:
            tags = dict(o.inflight_tags) if o.inflight_tags else None
        return OSDLoad(o.osd_id, o.stats.busy_s,
                       o.inflight + o.background_load, o.threads,
                       o.straggle_factor, o.down, tags, o.background_load)

    def list_objects(self) -> list[str]:
        names: set[str] = set()
        for o in self.osds:
            if not o.down:
                names.update(o.list_objects())
        return sorted(names)

    # -- object classes (the Ceph ObjectClass SDK analogue) ---------------------
    def register_cls(self, method: str, fn: Callable):
        self._cls[method] = fn

    def cls_call(self, name: str, method: str, payload: dict | None = None,
                 *, prefer_osd: OSD | None = None, tenant: str = "default",
                 lane: str = "bulk") -> Any:
        """Execute a registered object-class method ON the storage node
        holding the object.  Returns (result, osd_id, elapsed_s).

        ``tenant``/``lane`` tag the call in the node's per-tenant in-flight
        accounting (``OSD.inflight_tags``, snapshotted by :meth:`load_of`)
        so placement pricing can see *whose* work is queued where."""
        if method not in self._cls:
            raise KeyError(f"no object class method {method!r}")
        acting = self.acting_set(name)
        candidates = ([prefer_osd] if prefer_osd is not None else []) + acting
        err: Exception | None = None
        tag = (tenant, lane)
        for osd in candidates:
            if osd.down or not osd.contains(name):
                continue
            with osd._lock:          # queued: visible to load_of immediately
                osd.inflight += 1
                osd.inflight_tags[tag] = osd.inflight_tags.get(tag, 0) + 1
            try:
                with osd._cls_sem:   # per-OSD concurrency = thread count
                    t0 = time.perf_counter()
                    try:
                        result = self._cls[method](ObjectHandle(osd, name),
                                                   payload or {})
                    except OSDDownError as e:
                        err = e
                        continue
                    raw = time.perf_counter() - t0
                    el = raw * osd.straggle_factor
                    if osd.straggle_factor > 1.0:
                        # a straggler is *actually* slow: burn bounded real
                        # wall time while holding the execution slot, so
                        # hedging races have something real to overlap
                        time.sleep(min(el - raw, osd.max_straggle_delay_s))
            finally:
                with osd._lock:
                    osd.inflight -= 1
                    n = osd.inflight_tags.get(tag, 0) - 1
                    if n > 0:
                        osd.inflight_tags[tag] = n
                    else:
                        osd.inflight_tags.pop(tag, None)
            osd.stats.cls_calls += 1
            osd.stats.busy_s += el
            if isinstance(result, (bytes, bytearray)):
                osd.stats.bytes_returned += len(result)
            return result, osd.osd_id, el
        raise err if err else ObjectNotFound(name)

    # -- health ------------------------------------------------------------------
    def fail_osd(self, osd_id: int):
        self.osds[osd_id].down = True

    def recover_osd(self, osd_id: int) -> int:
        """Bring an OSD back and re-sync every object it participates in.

        Recovery compares this replica against its up peers by *version*
        (every overwrite while the node was down advanced the peers') and,
        at equal versions, by checksum (bit rot).  Missing and stale copies
        are both healed via :meth:`OSD.repair`, which installs the bytes at
        the authoritative peer version rather than ``put``-bumping it —
        a recovery must restore agreement, not look like a new write that
        spuriously invalidates result/footer caches.  Objects deleted
        while the node was down are removed.  Returns objects healed."""
        me = self.osds[osd_id]
        me.down = False
        healed = 0
        # union of what the cluster knows and what this OSD holds: a local
        # object deleted cluster-wide while we were down is only visible
        # on our side
        names = set(self.list_objects()) | set(me.list_objects())
        for name in sorted(names):
            acting = self.acting_set(name)
            if me not in acting:
                continue
            peers = [o for o in acting
                     if o is not me and not o.down]
            holders = [o for o in peers if o.contains(name)]
            if holders:
                best = max(holders, key=lambda o: o.version(name))
                bv = best.version(name)
                if not me.contains(name):
                    me.repair(name, best.peek(name), bv)
                    healed += 1
                elif me.version(name) < bv or \
                        zlib.crc32(me.peek(name)) != \
                        zlib.crc32(best.peek(name)):
                    me.repair(name, best.peek(name), bv)
                    healed += 1
            else:
                # no up peer holds it: deleted while we were down if any
                # peer's version counter moved past ours
                pv = max((o.version(name) for o in peers), default=0)
                if me.contains(name) and pv > me.version(name):
                    me.repair(name, None, pv)
                    healed += 1
        return healed

    def scrub(self) -> list[str]:
        """Verify replica consistency via checksums; returns bad objects.
        Reads replicas through :meth:`OSD.peek` so background verification
        never inflates the client-visible ``reads``/``bytes_read`` stats
        the Fig.-6 accounting replays."""
        bad = []
        for name in self.list_objects():
            sums = set()
            for osd in self.acting_set(name):
                if osd.down or not osd.contains(name):
                    continue
                sums.add(zlib.crc32(osd.peek(name)))
            if len(sums) > 1:
                bad.append(name)
        return bad

    def total_stats(self) -> OSDStats:
        agg = OSDStats()
        for o in self.osds:
            for f in dataclasses.fields(OSDStats):
                setattr(agg, f.name,
                        getattr(agg, f.name) + getattr(o.stats, f.name))
        return agg


class ObjectHandle:
    """File-like random-access view of one object on one OSD — the
    RandomAccessObject of the paper: lets the embedded access library run
    unmodified against object bytes (implements RandomAccessSource)."""

    def __init__(self, osd: OSD, name: str):
        self._osd = osd
        self.name = name

    @property
    def osd_id(self) -> int:
        return self._osd.osd_id

    @property
    def osd_uid(self) -> int:
        return self._osd.uid

    def version(self) -> int:
        """Write counter of this replica — cache keys for anything derived
        from the object's bytes (parsed footers, decoded results)."""
        return self._osd.version(self.name)

    def read(self, offset: int, length: int) -> bytes:
        return self._osd.get(self.name, offset, length)

    def size(self) -> int:
        return self._osd.stat(self.name)

    def read_all(self) -> bytes:
        return self._osd.get(self.name)

    def open_peer(self, name: str) -> "ObjectHandle":
        """Handle to another object co-located on this same OSD — the
        mechanism ``compact_op`` uses to merge neighbouring small objects
        without any bytes leaving the node.  Raises ObjectNotFound if
        this OSD holds no copy (the caller planned a non-co-located
        group and must fall back)."""
        if not self._osd.contains(name):
            raise ObjectNotFound(name)
        return ObjectHandle(self._osd, name)

    def peek_all(self) -> bytes:
        """Whole-object read for cluster-internal maintenance traffic
        (compaction, like scrub/recovery) — bypasses the client-visible
        ``reads``/``bytes_read`` counters the Fig.-6 accounting replays
        as client load."""
        return self._osd.peek(self.name)
