"""Deterministic cluster performance model for the Fig. 5 / Fig. 6 replays.

This container has one CPU core, so scan parallelism cannot be *measured*
as wall time.  Instead every scan records honest per-fragment costs
(decode/filter CPU seconds actually burned, wire bytes actually produced —
see ``TaskRecord``), and this module replays them through a discrete-event
model of the paper's testbed: m510 nodes (8 cores), a single client, and a
10 GbE client NIC.  The model is list scheduling over three resource kinds:

  client CPU   k-server pool (16 scan threads on the paper's client)
  node CPU     k-server pool per storage node (8 OSD threads)
  client NIC   serialized FIFO link (all result bytes funnel into one NIC)

Client-side scan:  NIC transfer (compressed bytes)  ->  client decode CPU.
Pushdown scan:     node decode CPU  ->  NIC transfer (Arrow IPC bytes)
                   ->  client materialize CPU (tiny).

Storage-device time is not modeled: the paper's point is that NVMe+network
outrun the CPU, and its experiments are CPU/NIC-bound throughout.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

from repro.dataset.format import TaskRecord

GBE10 = 10e9 / 8            # 10 GbE in bytes/s


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    nodes: int = 8
    node_threads: int = 8
    client_threads: int = 16
    net_bw: float = GBE10
    queue_depth: int = 4


class _Pool:
    """k-server resource; returns task completion time."""

    def __init__(self, k: int):
        self._free = [0.0] * max(1, k)
        heapq.heapify(self._free)
        self.busy_s = 0.0
        self.finish = 0.0

    def run(self, ready: float, dur: float) -> float:
        start = max(ready, heapq.heappop(self._free))
        end = start + dur
        heapq.heappush(self._free, end)
        self.busy_s += dur
        self.finish = max(self.finish, end)
        return end


class _Link:
    """Serialized FIFO link."""

    def __init__(self, bw: float):
        self.bw = bw
        self.free = 0.0
        self.busy_s = 0.0

    def xfer(self, ready: float, nbytes: int) -> float:
        dur = nbytes / self.bw
        start = max(ready, self.free)
        self.free = start + dur
        self.busy_s += dur
        return self.free


@dataclasses.dataclass
class SimResult:
    makespan_s: float
    client_busy_s: float
    node_busy_s: dict[int, float]
    nic_busy_s: float
    bottleneck: str

    def client_util(self, spec: ClusterSpec) -> float:
        return self.client_busy_s / (self.makespan_s * spec.client_threads
                                     + 1e-12)

    def node_util(self, spec: ClusterSpec) -> dict[int, float]:
        return {n: b / (self.makespan_s * spec.node_threads + 1e-12)
                for n, b in self.node_busy_s.items()}

    def nic_util(self) -> float:
        return self.nic_busy_s / (self.makespan_s + 1e-12)


def simulate_scan(tasks: Sequence[TaskRecord], spec: ClusterSpec
                  ) -> SimResult:
    client = _Pool(spec.client_threads)
    nic = _Link(spec.net_bw)
    nodes: dict[int, _Pool] = {}

    def node_pool(nid: int) -> _Pool:
        if nid not in nodes:
            nodes[nid] = _Pool(spec.node_threads)
        return nodes[nid]

    makespan = 0.0
    for t in tasks:
        if t.where == "client":
            # fetch compressed chunks, then decode on a client thread
            ready = nic.xfer(0.0, t.wire_bytes)
            end = client.run(ready, t.cpu_s)
        else:
            # scan on the storage node, ship IPC, materialize on client
            nid = t.node % spec.nodes if spec.nodes else t.node
            ready = node_pool(nid).run(0.0, t.cpu_s)
            ready = nic.xfer(ready, t.wire_bytes)
            end = client.run(ready, t.client_cpu_s)
        makespan = max(makespan, end)

    node_busy = {n: p.busy_s for n, p in sorted(nodes.items())}
    terms = {
        "client_cpu": client.busy_s / max(1, spec.client_threads),
        "network": nic.busy_s,
        "storage_cpu": (max(node_busy.values()) / spec.node_threads
                        if node_busy else 0.0),
    }
    bottleneck = max(terms, key=terms.get)  # type: ignore[arg-type]
    return SimResult(makespan, client.busy_s, node_busy, nic.busy_s,
                     bottleneck)


def simulate_multi_client(tasks: Sequence[TaskRecord], spec: ClusterSpec,
                          clients: int = 1) -> list[float]:
    """Replay the same scan from ``clients`` concurrent clients.

    Each client owns its CPU pool and NIC (private resources: client-side
    scans don't contend with each other), while the storage node pools are
    shared — the contention that produces the paper's crossover.  Returns
    the per-client scan latency (makespan); tasks are interleaved
    round-robin across clients so no client gets systematic priority.
    """
    cl_cpu = [_Pool(spec.client_threads) for _ in range(clients)]
    cl_nic = [_Link(spec.net_bw) for _ in range(clients)]
    nodes: dict[int, _Pool] = {}

    def node_pool(nid: int) -> _Pool:
        if nid not in nodes:
            nodes[nid] = _Pool(spec.node_threads)
        return nodes[nid]

    ends = [0.0] * clients
    for t in tasks:
        for c in range(clients):
            if t.where == "client":
                ready = cl_nic[c].xfer(0.0, t.wire_bytes)
                end = cl_cpu[c].run(ready, t.cpu_s)
            else:
                nid = t.node % spec.nodes if spec.nodes else t.node
                ready = node_pool(nid).run(0.0, t.cpu_s)
                ready = cl_nic[c].xfer(ready, t.wire_bytes)
                end = cl_cpu[c].run(ready, t.client_cpu_s)
            ends[c] = max(ends[c], end)
    return ends


def rebalance_nodes(tasks: Sequence[TaskRecord], nodes: int
                    ) -> list[TaskRecord]:
    """Re-map OSD ids onto an n-node cluster (scaling replays: the same
    measured work, hypothetically spread over 4 / 8 / 16 nodes).  Placement
    is round-robin over OSD tasks — the PG-hash uniform-placement
    idealization."""
    out = []
    i = 0
    for t in tasks:
        if t.where == "osd":
            out.append(dataclasses.replace(t, node=i % nodes))
            i += 1
        else:
            out.append(t)
    return out
