"""Object-class methods (the Ceph ObjectClass SDK analogue).

``scan_op`` is the paper's core: it runs the *same* aformat scan code that a
client would run, but against the object's bytes on the storage node, and
returns the filtered/projected result in IPC (Arrow) wire format.

The scan path is cache-aware: parsed footers are memoized per
(osd, object, version) so repeat scans of a hot object skip the
metadata-decode step entirely; any overwrite bumps the object version and
naturally invalidates the entry.

Registered methods receive (ObjectHandle, payload dict) and return bytes.
"""

from __future__ import annotations

import json
import struct
import threading
import zlib
from collections import OrderedDict

from repro.aformat import parquet
from repro.aformat.aggregate import (AggSpec, AggState, CardinalityError,
                                     needed_columns, partial_aggregate,
                                     partial_from_stats)
from repro.aformat.expressions import Expr, NONE
from repro.aformat.table import Table
from repro.storage.objstore import ObjectStore, ObjectHandle

#: agg_op's reply when the group-by bound is exceeded: the client must
#: fall back to a scan (spill-to-scan).
SPILL = json.dumps({"spill": True}).encode()

# -- storage-side footer cache ----------------------------------------------
# Keyed by (osd_id, object name, object version): a new write produces a new
# version, so stale footers age out of the LRU rather than being served.
_FOOTER_CACHE: OrderedDict[tuple, parquet.FileMeta] = OrderedDict()
_FOOTER_CACHE_CAP = 1024
_FOOTER_LOCK = threading.Lock()


def cached_footer(obj: ObjectHandle) -> parquet.FileMeta:
    """Parse (or recall) the footer of a self-contained ARW1 object."""
    key = (obj.osd_uid, obj.name, obj.version())
    with _FOOTER_LOCK:
        meta = _FOOTER_CACHE.get(key)
        if meta is not None:
            _FOOTER_CACHE.move_to_end(key)
            return meta
    meta = parquet.read_footer(obj)
    with _FOOTER_LOCK:
        _FOOTER_CACHE[key] = meta
        while len(_FOOTER_CACHE) > _FOOTER_CACHE_CAP:
            _FOOTER_CACHE.popitem(last=False)
    return meta


def _payload_footer(obj: ObjectHandle, payload: dict) -> parquet.FileMeta:
    """Footer from the payload (striped layout ships the parent's) or from
    the object itself via the version-keyed cache."""
    raw = payload.get("footer")
    if raw:
        return parquet.FileMeta.deserialize(
            raw.encode() if isinstance(raw, str) else raw)
    return cached_footer(obj)


def scan_op(obj: ObjectHandle, payload: dict) -> bytes:
    """Scan a self-contained ARW1 object: decode + filter + project.

    payload: {"columns": [...]|None, "predicate": expr-json|None,
              "limit": int|None (row budget: stop decoding row groups once
              met, ship at most that many rows — limit pushdown),
              "footer": serialized FileMeta|None (striped layout passes the
              parent footer; split layout objects carry their own)}
    """
    meta = _payload_footer(obj, payload)
    predicate = Expr.from_json(payload.get("predicate"))
    columns = payload.get("columns")
    limit = payload.get("limit")
    row_groups = payload.get("row_groups")  # indices within this object
    metas = (meta.row_groups if row_groups is None
             else [meta.row_groups[i] for i in row_groups])
    parts = []
    rows = 0
    for rg in metas:
        if predicate is not None:
            # storage-side stats skip: a row group whose min/max prove the
            # predicate (e.g. a pushed semi-join key filter) matches no
            # rows is never decoded
            if predicate.prune(rg.column_stats(meta.schema)) == NONE:
                continue
        # storage nodes decode on the host path (default backend): an
        # OSD has no accelerator, so the Pallas decode engine exists
        # only behind the *client-side* formats (aformat.decode)
        part = parquet.scan_row_group(obj, meta, rg, columns, predicate)
        parts.append(part)
        rows += len(part)
        if limit is not None and rows >= limit:
            break                       # budget met: skip later row groups
    table = Table.concat(parts) if parts else None
    if table is not None and limit is not None:
        table = table.head(limit)       # ship only the budgeted rows
    if table is None:
        sel = columns or meta.schema.names
        import numpy as np

        from repro.aformat.table import Column
        sch = meta.schema.select(sel)
        table = Table(sch, [Column(f, np.empty(0, object if f.type == "string"
                                               else f.numpy_dtype))
                            for f in sch])
    return table.to_ipc()


def stat_op(obj: ObjectHandle, payload: dict) -> bytes:
    """Return the footer (metadata) of an ARW1 object — used by the split
    layout's .index discovery."""
    meta = cached_footer(obj)
    return meta.serialize()


def _run_agg(obj: ObjectHandle, meta: parquet.FileMeta,
             specs: list[AggSpec], group_by: str | None, pred,
             metas, max_groups: int | None) -> AggState:
    """The shared storage-side aggregation kernel: per row group, answer
    from footer stats where provable (ungrouped + no predicate), else
    decode only the referenced columns, filter, and fold into the partial
    state.  Raises CardinalityError past ``max_groups``."""
    state = AggState.empty(specs, group_by)
    cols = needed_columns(specs, group_by, meta.schema, pred)
    for rg in metas:
        part = None
        if pred is None and group_by is None:
            part = partial_from_stats(specs,
                                      rg.column_stats(meta.schema),
                                      rg.num_rows, meta.schema)
        if part is None:
            t = parquet.scan_row_group(obj, meta, rg, cols, pred)
            part = partial_aggregate(t, specs, group_by,
                                     max_groups=max_groups)
        state.merge(part)
        if max_groups is not None and state.num_groups > max_groups:
            raise CardinalityError(
                f"group-by {group_by!r}: object-level cardinality "
                f"exceeds {max_groups}")
    return state


def agg_op(obj: ObjectHandle, payload: dict) -> bytes:
    """Partial aggregation on the storage node: decode only the referenced
    columns, filter, fold into an AggState, ship back the compact
    serialized partial state (the client merges states across objects).

    payload: {"aggs": [AggSpec json...], "group_by": str|None,
              "predicate": expr-json|None, "row_groups": [...]|None,
              "footer": serialized FileMeta|None,
              "max_groups": int|None (group-cardinality bound)}

    A fragment whose group-by cardinality exceeds ``max_groups`` returns
    the SPILL marker instead — the client falls back to a scan
    (spill-to-scan), so a hostile key can never balloon node memory or
    the wire payload.  ``rowcount_op`` is the degenerate ungrouped
    COUNT(*) case of this method."""
    meta = _payload_footer(obj, payload)
    specs = [AggSpec.from_json(s) for s in payload["aggs"]]
    group_by = payload.get("group_by")
    pred = Expr.from_json(payload.get("predicate"))
    row_groups = payload.get("row_groups")
    metas = (meta.row_groups if row_groups is None
             else [meta.row_groups[i] for i in row_groups])
    try:
        state = _run_agg(obj, meta, specs, group_by, pred, metas,
                         payload.get("max_groups"))
    except CardinalityError:
        return SPILL
    return state.serialize()


def rowcount_op(obj: ObjectHandle, payload: dict) -> bytes:
    """COUNT(*) [WHERE pred] on the storage node — kept for its tiny
    ``{"rows": n}`` wire contract, now the degenerate case of the agg_op
    kernel (same code path, one count cell, no grouping)."""
    meta = _payload_footer(obj, payload)
    pred = Expr.from_json(payload.get("predicate"))
    row_groups = payload.get("row_groups")
    metas = (meta.row_groups if row_groups is None
             else [meta.row_groups[i] for i in row_groups])
    state = _run_agg(obj, meta, [AggSpec("count")], None, pred, metas,
                     None)
    return json.dumps({"rows": state.cells[0]}).encode()


def compact_op(store: ObjectStore, obj: ObjectHandle,
               payload: dict) -> bytes:
    """Merge co-located small row groups into right-sized ones ON the
    storage node (the mutable-dataset compaction offload).

    payload: {"sources": [{"name": object-name, "keep": expr-json|None},
                          ...],
              "target": object name for the rewritten ARW1 file,
              "row_group_rows": int, "codec": str,
              "advise": bool — re-encode each column into the measured
              encoding advisor's pick (repro.aformat.advisor) instead of
              the one-shot heuristic}

    Every source must be a self-contained ARW1 object held by THIS OSD
    (co-located; the driver groups victims by holder).  The node decodes
    each source (applying the per-source ``keep`` predicate, i.e.
    NOT(tombstone), so deleted rows are physically dropped), concatenates,
    re-encodes at ``row_group_rows`` — statistics are regenerated by the
    encoder — and writes the new object back into the cluster directly
    (``store.put``: an OSD-to-OSD transfer, not a client round-trip).

    Only metadata returns to the client: ``{"ok": true, "rows": n,
    "size": bytes, "bytes_before": source row-group bytes,
    "encodings": {column: encoding chosen for the rewrite},
    "footer": FileMeta json}``.  The raw row-group bytes never cross the
    client wire in either direction (the reply footer is serialized
    *without* index blocks — the new object's own footer keeps them for
    storage-side pruning).  A source this OSD does not hold returns
    ``{"ok": false, "missing": [...]}`` — the driver re-plans or falls
    back to a client-side rewrite.

    Source bytes are read via :meth:`ObjectHandle.peek_all` (cluster-
    internal traffic, like scrub/recovery): compaction must not inflate
    the client-visible read counters."""
    sources = payload["sources"]
    missing = [s["name"] for s in sources
               if not (s["name"] == obj.name
                       or _peer_held(obj, s["name"]))]
    if missing:
        return json.dumps({"ok": False, "missing": missing}).encode()
    parts = []
    bytes_before = 0
    for s in sources:
        handle = obj if s["name"] == obj.name else obj.open_peer(s["name"])
        src = parquet.BytesSource(handle.peek_all())
        meta = parquet.read_footer(src)
        keep = Expr.from_json(s.get("keep"))
        for rg in meta.row_groups:
            bytes_before += rg.total_bytes
            parts.append(parquet.scan_row_group(src, meta, rg, None, keep))
    merged = Table.concat(parts) if parts else None
    rows = len(merged) if merged is not None else 0
    if rows == 0:          # everything tombstoned: nothing to rewrite
        return json.dumps({"ok": True, "rows": 0, "size": 0,
                           "bytes_before": bytes_before,
                           "encodings": {}, "footer": None}).encode()
    data = parquet.write_table(merged,
                               row_group_rows=payload["row_group_rows"],
                               codec=payload.get("codec", "zlib"),
                               advise=bool(payload.get("advise")))
    store.put(payload["target"], data)
    meta = parquet.read_footer(parquet.BytesSource(data))
    encodings = {f.name: c.encoding
                 for f, c in zip(meta.schema, meta.row_groups[0].chunks)}
    return json.dumps({"ok": True, "rows": rows, "size": len(data),
                       "bytes_before": bytes_before,
                       "encodings": encodings,
                       "footer": meta.to_json(include_indexes=False)
                       }).encode()


def _peer_held(obj: ObjectHandle, name: str) -> bool:
    try:
        obj.open_peer(name)
        return True
    except KeyError:
        return False


def checksum_op(obj: ObjectHandle, payload: dict) -> bytes:
    data = obj.read_all()
    return struct.pack("<I", zlib.crc32(data))


def read_op(obj: ObjectHandle, payload: dict) -> bytes:
    """Plain byte read through the cls interface (offset/length payload)."""
    off = int(payload.get("offset", 0))
    ln = payload.get("length")
    return obj.read(off, ln if ln is None else int(ln))


def register_default_classes(store: ObjectStore):
    store.register_cls("scan_op", scan_op)
    store.register_cls("stat_op", stat_op)
    store.register_cls("agg_op", agg_op)
    store.register_cls("rowcount_op", rowcount_op)
    store.register_cls("checksum_op", checksum_op)
    store.register_cls("read_op", read_op)
    # compact_op writes the rewritten object back into the cluster, so it
    # closes over the store (the Ceph cls SDK's ioctx write-back analogue)
    store.register_cls("compact_op",
                       lambda obj, payload: compact_op(store, obj, payload))
    return store
