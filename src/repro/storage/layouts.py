"""The paper's two file-layout designs (§2.3).

Striped (Fig. 3): the table is rewritten so every row group is padded to a
common object-aligned size; CephFS striping then puts exactly one row group
per RADOS object.  Row group 0 shares its object with the 4-byte magic; the
footer lands in the final object(s).  The writer returns the client-side
rowgroup -> object map, which is also persisted as an xattr.

Split (Fig. 4): a file with R row groups becomes R single-row-group ARW1
files plus one ``.index`` file holding the parent schema + per-row-group
stats — so predicate pushdown survives the split.
"""

from __future__ import annotations

import dataclasses
import json
import struct

from repro.aformat import compression, parquet
from repro.aformat.statistics import ColumnStats
from repro.aformat.table import Table
from repro.storage.cephfs import CephFS

ALIGN = 4096


@dataclasses.dataclass
class StripedFile:
    path: str
    stripe_unit: int
    num_row_groups: int
    rg_objects: list[int]        # row group i -> object index
    footer_objects: list[int]


def write_striped(fs: CephFS, path: str, table: Table, *,
                  row_group_rows: int = 65536,
                  codec: str = compression.ZLIB,
                  object_size: int | None = None) -> StripedFile:
    parts = list(parquet.iter_row_groups(table, row_group_rows))
    encoded = [parquet.encode_row_group(p, codec) for p in parts]
    raw_max = max(len(d) for d, _ in encoded)
    # stripe unit: padded row-group size, object-aligned; rg0 shares its
    # stripe with the leading magic.
    su = -(-(raw_max + len(parquet.MAGIC)) // ALIGN) * ALIGN
    if object_size is not None:
        # the "one row group per object" invariant is load-bearing for
        # every pushdown path: an encoded group too big for its object
        # would be split mid-chunk and unscannable storage-side.  Detect
        # the bad knob combination at write time, loudly.
        if object_size % ALIGN:
            raise ValueError(
                f"object_size={object_size} must be a multiple of the "
                f"{ALIGN}-byte object alignment")
        if su > object_size:
            raise ValueError(
                f"write_striped({path!r}): row_group_rows="
                f"{row_group_rows} encodes a row group of {raw_max} "
                f"bytes ({su} after magic+alignment), which cannot fit "
                f"the requested object_size={object_size}; lower "
                f"row_group_rows or raise object_size so every row "
                f"group stays inside one object")
        su = object_size
    out = bytearray(parquet.MAGIC)
    groups = []
    for i, (data, rg) in enumerate(encoded):
        target = i * su + (len(parquet.MAGIC) if i == 0 else 0)
        out.extend(b"\x00" * (target - len(out)))
        shifted = parquet._shift_group(rg, len(out))
        out.extend(data)
        shifted.total_bytes = su
        groups.append(shifted)
    out.extend(b"\x00" * (len(parts) * su - len(out)))
    footer = parquet.FileMeta(table.schema, groups, len(table)).serialize()
    footer_start = len(out)
    out.extend(footer)
    out.extend(struct.pack("<I", len(footer)))
    out.extend(parquet.MAGIC)
    rg_objects = list(range(len(parts)))
    footer_objects = list(range(footer_start // su,
                                (len(out) - 1) // su + 1))
    meta = StripedFile(path, su, len(parts), rg_objects, footer_objects)
    fs.write_file(path, bytes(out), stripe_unit=su, xattrs={
        "layout": "striped",
        "stripe_unit": su,
        "rg_objects": rg_objects,
        "footer_objects": footer_objects,
    })
    return meta


def read_striped_footer(fs: CephFS, path: str) -> parquet.FileMeta:
    """Read the footer from the *last object(s)* only, via striping
    metadata — no full-file read (paper: 'the last object ... is read')."""
    ino = fs.stat(path)
    next_obj = ino.object_count - 1
    last = fs.store.get(fs.object_name(ino, next_obj))
    next_obj -= 1
    if len(last) < 8:
        last = fs.store.get(fs.object_name(ino, next_obj)) + last
        next_obj -= 1
    if last[-4:] != parquet.MAGIC:
        raise ValueError("bad striped footer magic")
    (flen,) = struct.unpack("<I", last[-8:-4])
    while flen + 8 > len(last) and next_obj >= 0:
        # footer spills across objects (index blocks make big footers):
        # keep prepending earlier objects until the length is covered
        last = fs.store.get(fs.object_name(ino, next_obj)) + last
        next_obj -= 1
    return parquet.FileMeta.deserialize(last[-8 - flen:-8])


# ---------------------------------------------------------------------------
# Split layout
# ---------------------------------------------------------------------------


def _index_payload(schema, rg_files, rg_metas) -> bytes:
    return json.dumps({
        "schema": schema.to_json(),
        "row_groups": [
            {"file": f, "num_rows": rg.num_rows,
             "stats": {name: st.to_json() for name, st in
                       rg.column_stats(schema).items()}}
            for f, rg in zip(rg_files, rg_metas)],
    }).encode()


@dataclasses.dataclass
class SplitIndex:
    schema: object
    row_groups: list[dict]   # {"file", "num_rows", "stats": {col: ColumnStats}}

    @staticmethod
    def deserialize(data: bytes) -> "SplitIndex":
        from repro.aformat.schema import Schema

        d = json.loads(data)
        sch = Schema.from_json(d["schema"])
        rgs = []
        for rg in d["row_groups"]:
            rgs.append({
                "file": rg["file"], "num_rows": rg["num_rows"],
                "stats": {k: ColumnStats.from_json(v)
                          for k, v in rg["stats"].items()},
            })
        return SplitIndex(sch, rgs)


def write_split(fs: CephFS, path: str, table: Table, *,
                row_group_rows: int = 65536,
                codec: str = compression.ZLIB,
                object_size: int | None = None) -> str:
    """Writes R single-row-group files + ``<path>.index``; returns the
    index path (dataset discovery finds only .index files, paper Fig. 4).

    ``object_size``, when given, pins every split file's stripe unit; a
    row group whose encoded file exceeds it is a hard error (the
    row-group-within-one-object invariant that all pushdown relies on).
    """
    if object_size is not None and object_size % ALIGN:
        raise ValueError(
            f"object_size={object_size} must be a multiple of the "
            f"{ALIGN}-byte object alignment")
    parts = list(parquet.iter_row_groups(table, row_group_rows))
    rg_files, rg_metas = [], []
    for i, part in enumerate(parts):
        sub = parquet.write_table(part, row_group_rows=max(len(part), 1),
                                  codec=codec)
        sub_path = f"{path}.rg{i:05d}.arw"
        # one object per split file: stripe unit >= file size, aligned
        su = max(ALIGN, -(-len(sub) // ALIGN) * ALIGN)
        if object_size is not None:
            if su > object_size:
                raise ValueError(
                    f"write_split({path!r}): row_group_rows="
                    f"{row_group_rows} encodes row group {i} into "
                    f"{len(sub)} bytes ({su} aligned), which cannot fit "
                    f"the requested object_size={object_size}; lower "
                    f"row_group_rows or raise object_size so every row "
                    f"group stays inside one object")
            su = object_size
        fs.write_file(sub_path, sub, stripe_unit=su,
                      xattrs={"layout": "split-part", "parent": path})
        rg_files.append(sub_path)
        rg_metas.append(parquet.read_footer(
            parquet.BytesSource(sub)).row_groups[0])
    index_path = f"{path}.index"
    fs.write_file(index_path, _index_payload(table.schema, rg_files,
                                             rg_metas),
                  xattrs={"layout": "split-index", "parent": path})
    return index_path


def read_split_index(fs: CephFS, index_path: str) -> SplitIndex:
    return SplitIndex.deserialize(fs.read_file(index_path))


# ---------------------------------------------------------------------------
# Flat layout — the paper's §3 experimental configuration: one ARW1 file per
# object (stripe unit >= file size), single or few row groups per file.
# ---------------------------------------------------------------------------


def write_flat(fs: CephFS, path: str, table: Table, *,
               row_group_rows: int = 65536,
               codec: str = compression.ZLIB,
               build_indexes: bool = True,
               advise: bool = False) -> parquet.FileMeta:
    """Write ``table`` as one self-contained single-object ARW1 file.
    Returns the file's footer (the mutable-dataset append path embeds it
    in the manifest so discovery never re-reads the file).
    ``build_indexes``/``advise`` pass through to
    :func:`parquet.write_table` (bloom index blocks; measured encoding
    selection)."""
    data = parquet.write_table(table, row_group_rows=row_group_rows,
                               codec=codec, build_indexes=build_indexes,
                               advise=advise)
    su = max(ALIGN, -(-len(data) // ALIGN) * ALIGN)
    fs.write_file(path, data, stripe_unit=su, xattrs={"layout": "flat"})
    return parquet.read_footer(parquet.BytesSource(data))
