"""CephFS shim: POSIX-ish files striped over RADOS objects, plus the
DirectObjectAccess API that translates filenames to object IDs and invokes
object-class methods on them (paper §2.2).

Striping: file bytes are cut into ``stripe_unit``-sized objects named
``<ino>.<%08x index>``; the MDS table maps path -> (ino, size, stripe_unit,
object_count).  This is the metadata DirectObjectAccess leverages.
"""

from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor
from concurrent.futures import wait as futures_wait
from typing import Any, Callable

from repro.storage.objstore import ObjectNotFound, ObjectStore

DEFAULT_STRIPE_UNIT = 4 * 1024 * 1024


@dataclasses.dataclass
class Inode:
    ino: int
    path: str
    size: int
    stripe_unit: int
    object_count: int
    xattrs: dict[str, Any] = dataclasses.field(default_factory=dict)


class CephFS:
    """Filesystem facade over an ObjectStore."""

    def __init__(self, store: ObjectStore,
                 stripe_unit: int = DEFAULT_STRIPE_UNIT):
        self.store = store
        self.default_stripe_unit = stripe_unit
        self._mds: dict[str, Inode] = {}
        self._next_ino = 0x10000
        self._lock = threading.Lock()

    # -- namespace ----------------------------------------------------------
    def _alloc_ino(self) -> int:
        with self._lock:
            self._next_ino += 1
            return self._next_ino

    def exists(self, path: str) -> bool:
        return path in self._mds

    def listdir(self, prefix: str) -> list[str]:
        prefix = prefix.rstrip("/") + "/" if prefix else ""
        return sorted(p for p in self._mds if p.startswith(prefix))

    def stat(self, path: str) -> Inode:
        if path not in self._mds:
            raise FileNotFoundError(path)
        return self._mds[path]

    def unlink(self, path: str):
        for name in self.object_names(path):
            self.store.delete(name)
        del self._mds[path]

    # -- data path ------------------------------------------------------------
    def object_name(self, ino: Inode, idx: int) -> str:
        return f"{ino.ino:x}.{idx:08x}"

    def object_names(self, path: str) -> list[str]:
        ino = self.stat(path)
        return [self.object_name(ino, i) for i in range(ino.object_count)]

    def write_file(self, path: str, data: bytes,
                   stripe_unit: int | None = None,
                   xattrs: dict | None = None) -> Inode:
        su = stripe_unit or self.default_stripe_unit
        if path in self._mds:
            self.unlink(path)
        ino = Inode(self._alloc_ino(), path, len(data), su,
                    max(1, -(-len(data) // su)), dict(xattrs or {}))
        for i in range(ino.object_count):
            chunk = data[i * su:(i + 1) * su]
            self.store.put(self.object_name(ino, i), chunk)
        self._mds[path] = ino
        return ino

    def reserve_ino(self) -> int:
        """Allocate an inode number without installing a path yet — the
        first half of a storage-side write: the client derives the target
        object name (``f"{ino:x}.{idx:08x}"``) before any bytes exist,
        hands it to an object-class method that writes the data inside
        the cluster, then installs the path with :meth:`register_file`."""
        return self._alloc_ino()

    def register_file(self, path: str, ino_num: int, size: int,
                      stripe_unit: int,
                      xattrs: dict | None = None) -> Inode:
        """Install MDS metadata for a file whose object bytes were
        written inside the storage tier (``compact_op``) — a pure
        metadata operation: no data bytes cross the client wire."""
        if path in self._mds:
            raise FileExistsError(path)
        if size <= 0 or stripe_unit <= 0:
            raise ValueError(f"register_file({path!r}): need positive "
                             f"size/stripe_unit, got {size}/{stripe_unit}")
        ino = Inode(ino_num, path, size, stripe_unit,
                    max(1, -(-size // stripe_unit)), dict(xattrs or {}))
        with self._lock:
            self._mds[path] = ino
        return ino

    def read_file(self, path: str) -> bytes:
        ino = self.stat(path)
        parts = []
        for i in range(ino.object_count):
            parts.append(self.store.get(self.object_name(ino, i)))
        return b"".join(parts)[: ino.size]

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        """Random-access read through the striping map."""
        ino = self.stat(path)
        su = ino.stripe_unit
        end = min(offset + length, ino.size)
        out = bytearray()
        idx = offset // su
        while offset < end:
            within = offset - idx * su
            take = min(su - within, end - offset)
            out += self.store.get(self.object_name(ino, idx), within, take)
            offset += take
            idx += 1
        return bytes(out)

    def file_size(self, path: str) -> int:
        return self.stat(path).size


class FileSource:
    """RandomAccessSource over a CephFS file (client-side scan path)."""

    def __init__(self, fs: CephFS, path: str,
                 on_read: Callable[[int], None] | None = None):
        self.fs = fs
        self.path = path
        self._size = fs.file_size(path)
        self._on_read = on_read

    def read(self, offset: int, length: int) -> bytes:
        data = self.fs.read_range(self.path, offset, length)
        if self._on_read:
            self._on_read(len(data))
        return data

    def size(self) -> int:
        return self._size


class DirectObjectAccess:
    """Filename -> object IDs translation + cls invocation (paper §2.2).

    This is the key mechanism: clients keep a filesystem view while
    manipulating the underlying RADOS objects directly.
    """

    def __init__(self, fs: CephFS):
        self.fs = fs
        self.store = fs.store

    def object_ids(self, path: str) -> list[str]:
        return self.fs.object_names(path)

    def stat_object(self, path: str, idx: int) -> int:
        return self.store.stat(self.fs.object_names(path)[idx])

    def call(self, path: str, idx: int, method: str,
             payload: dict | None = None, *, tenant: str = "default",
             lane: str = "bulk"):
        """Invoke an object-class method on the idx-th object of a file.
        Returns (result_bytes, osd_id, elapsed_s).  ``tenant``/``lane``
        tag the node's per-tenant in-flight accounting."""
        names = self.fs.object_names(path)
        return self.store.cls_call(names[idx], method, payload,
                                   tenant=tenant, lane=lane)

    def call_last(self, path: str, method: str, payload=None, *,
                  tenant: str = "default", lane: str = "bulk"):
        names = self.fs.object_names(path)
        return self.store.cls_call(names[-1], method, payload,
                                   tenant=tenant, lane=lane)

    def call_hedged(self, path: str, idx: int, method: str,
                    payload: dict | None = None, *,
                    hedge_threshold_s: float = 0.05,
                    tenant: str = "default", lane: str = "bulk"):
        """Straggler-mitigated cls call with *first-wins racing*: issue the
        call on the primary; if it has not completed within the hedge
        deadline, issue the same call on a replica **while the primary is
        still running** and return whichever finishes first.  Wall time is
        therefore ``min(primary, deadline + backup)`` — never the sum.

        The loser keeps running on its node (an in-flight cls call cannot
        be revoked, exactly as in Ceph): its service time still lands in
        the node's ``busy_s`` and is additionally recorded as
        ``hedge_wasted_s`` — the duplicated storage CPU hedging trades for
        tail latency.

        Returns (result, osd_id, elapsed_s, hedged_bool)."""
        name = self.fs.object_names(path)[idx]
        store = self.store

        acting = store.acting_set(name)
        # the OSD cls_call will execute on: first up replica holding the
        # object (needed up front so the hedge goes somewhere *else*)
        primary = next((o for o in acting
                        if not o.down and o.contains(name)), None)
        fut1 = _hedge_pool().submit(
            lambda: store.cls_call(name, method, payload, tenant=tenant,
                                   lane=lane))
        done, _ = futures_wait([fut1], timeout=hedge_threshold_s)
        if fut1 in done or primary is None:
            result, osd_id, el = fut1.result()   # may raise: no racing yet
            return result, osd_id, el, False

        backup = next((o for o in acting
                       if o.osd_id != primary.osd_id and not o.down
                       and o.contains(name)), None)
        if backup is None:
            result, osd_id, el = fut1.result()
            return result, osd_id, el, False
        fut2 = _hedge_pool().submit(
            lambda: store.cls_call(name, method, payload, prefer_osd=backup,
                                   tenant=tenant, lane=lane))

        pending = {fut1, fut2}
        err: Exception | None = None
        winner: Future | None = None
        losers: list[Future] = []
        while pending and winner is None:
            done, pending = futures_wait(pending,
                                         return_when=FIRST_COMPLETED)
            for fut in done:
                exc = fut.exception()
                if exc is not None:
                    err = exc
                elif winner is None:
                    winner = fut
                else:
                    losers.append(fut)
        if winner is None:
            raise err if err else ObjectNotFound(name)
        waste = _account_hedge_waste(store)
        for loser in pending:          # still running: book when it lands
            loser.add_done_callback(waste)
        for loser in losers:           # finished in the same wait round
            waste(loser)
        result, osd_id, el = winner.result()
        return result, osd_id, el, True


def _account_hedge_waste(store: ObjectStore):
    """Done-callback for a losing hedge call: its service time is
    duplicated storage CPU — book it on the node that burned it."""

    def cb(fut: Future):
        if fut.cancelled() or fut.exception() is not None:
            return
        _, osd_id, el = fut.result()
        osd = store.osds[osd_id]
        with osd._lock:     # callbacks run on foreign hedge-pool threads
            osd.stats.hedge_wasted_s += el

    return cb


_HEDGE_POOL: ThreadPoolExecutor | None = None
_HEDGE_POOL_LOCK = threading.Lock()


def _hedge_pool() -> ThreadPoolExecutor:
    """Process-wide executor for racing hedged cls calls.  Sized well past
    any single scan's parallelism: a slot is held for the full (possibly
    straggling) call, and an exhausted pool would serialize the very races
    it exists to run."""
    global _HEDGE_POOL
    with _HEDGE_POOL_LOCK:
        if _HEDGE_POOL is None:
            _HEDGE_POOL = ThreadPoolExecutor(max_workers=128,
                                             thread_name_prefix="hedge")
        return _HEDGE_POOL
