"""FileFormat: where a fragment's scan executes.

``ParquetFormat``          — client-side scan: column-chunk bytes travel
                             over the wire, decode/filter burn client CPU.
``PushdownParquetFormat``  — the paper's contribution: ``scan_op`` runs on
                             the storage node holding the object; only the
                             filtered/projected Arrow-IPC result travels.
``AdaptiveFormat``         — per-fragment placement chosen at runtime by a
                             ScanScheduler from live OSD load, with hedged
                             storage scans and an LRU result cache
                             (``repro.dataset.scheduler``).

Switching the format argument switches the placement — nothing else in the
Dataset/Scanner API changes (paper §2.2, RadosParquetFileFormat).

Task options travel on one :class:`~repro.dataset.qos.TaskContext` passed
as the single ``ctx`` argument of ``scan_fragment`` / ``aggregate_fragment``
/ ``execute_task`` — admission controller, live row budget, selectivity
hint, and the tenant/lane/deadline identity the QoS machinery reads.  The
old ``admission=`` / ``limit=`` / ``selectivity_hint=`` kwarg tail and
pre-TaskContext subclass overrides are adapted by a one-release
compatibility shim that warns (``repro.dataset.qos.resolve_context``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import inspect
import json
import threading
import time
import warnings
from typing import Any, Sequence

from repro.aformat import decode as decode_mod
from repro.aformat import parquet
from repro.aformat.aggregate import (AggSpec, AggState, DEFAULT_MAX_GROUPS,
                                     needed_columns, partial_aggregate)
from repro.aformat.expressions import Expr
from repro.aformat.table import Table
from repro.dataset.fragment import Fragment
from repro.dataset.qos import TaskContext, resolve_context
from repro.storage.cephfs import CephFS, DirectObjectAccess, FileSource


@dataclasses.dataclass
class TaskRecord:
    """Per-fragment accounting — feeds the Fig. 5/6 performance model."""

    where: str            # "client" or "osd"
    node: int             # osd id (-1 for client-only work)
    cpu_s: float          # decode/filter CPU burned at `where`
    wire_bytes: int       # bytes that crossed the network to the client
    client_cpu_s: float   # residual client CPU (IPC decode / materialize)
    rows_out: int
    hedged: bool = False
    cached: bool = False  # served from the columnar result cache


# -- one-release override shim ------------------------------------------------
# Format subclasses written before TaskContext declare the old kwarg tail
# (`admission=`, `limit=`, ...).  The executor detects them by signature
# (no `ctx` parameter), warns once per class, and calls them old-style
# with whatever subset of the tail they accept.

_CTX_AWARE: dict[tuple[type, str], bool] = {}
_LEGACY_WARNED: set[tuple[type, str]] = set()


def _takes_ctx(cls: type, name: str) -> bool:
    key = (cls, name)
    hit = _CTX_AWARE.get(key)
    if hit is None:
        params = inspect.signature(getattr(cls, name)).parameters
        hit = "ctx" in params
        _CTX_AWARE[key] = hit
    return hit


def _legacy_call_kwargs(cls: type, name: str, ctx: TaskContext) -> dict:
    if (cls, name) not in _LEGACY_WARNED:
        _LEGACY_WARNED.add((cls, name))
        warnings.warn(
            f"{cls.__name__}.{name} overrides the pre-TaskContext "
            f"signature; adapt it to accept `ctx` (this shim is "
            f"one release only)", DeprecationWarning, stacklevel=4)
    params = inspect.signature(getattr(cls, name)).parameters
    kwargs: dict[str, Any] = {}
    if "admission" in params:
        kwargs["admission"] = ctx.admission
    if ctx.limit is not None and "limit" in params:
        kwargs["limit"] = ctx.limit
    if ctx.selectivity_hint is not None and "selectivity_hint" in params:
        kwargs["selectivity_hint"] = ctx.selectivity_hint
    return kwargs


def _call_scan(fmt: "FileFormat", fs: CephFS, frag: Fragment, columns,
               predicate, ctx: TaskContext):
    """Dispatch to ``fmt.scan_fragment`` through the override shim."""
    if _takes_ctx(type(fmt), "scan_fragment"):
        return fmt.scan_fragment(fs, frag, columns, predicate, ctx)
    return fmt.scan_fragment(
        fs, frag, columns, predicate,
        **_legacy_call_kwargs(type(fmt), "scan_fragment", ctx))


class FileFormat:
    """Scan a fragment; returns (Table, TaskRecord).

    ``ctx`` (a :class:`~repro.dataset.qos.TaskContext` or None) carries
    every task option: the admission controller bounding in-flight
    fragment operations per storage node, the live row budget, the
    selectivity hint, and the tenant/lane/deadline QoS identity.  Every
    format acquires a slot on the node it is about to touch — storage-side
    cls calls and client-side byte pulls alike."""

    name = "abstract"

    def scan_fragment(self, fs: CephFS, frag: Fragment,
                      columns: Sequence[str] | None,
                      predicate: Expr | None,
                      ctx: TaskContext | None = None,
                      **legacy) -> tuple[Table, TaskRecord]:
        raise NotImplementedError

    def aggregate_fragment(self, fs: CephFS, frag: Fragment,
                           specs: Sequence[AggSpec], group_by: str | None,
                           predicate: Expr | None, *, schema,
                           max_groups: int = DEFAULT_MAX_GROUPS,
                           ctx: TaskContext | None = None,
                           **legacy) -> tuple[AggState, TaskRecord]:
        """Partial-aggregate one fragment; returns (AggState, TaskRecord).
        ``schema`` is the dataset schema (split-layout fragments carry no
        client-side footer of their own).  The default is the client-side
        path — scan the needed columns, fold locally — so every format
        answers ``Scanner.aggregate``."""
        ctx = resolve_context(ctx, legacy)
        return aggregate_client(self, fs, frag, specs, group_by,
                                predicate, schema=schema, ctx=ctx)

    def execute_task(self, fs: CephFS, task,
                     ctx: TaskContext | None = None, **legacy):
        """The single physical-task entry point the shared query executor
        routes through: one ``FragmentTask`` in (see ``dataset.plan``),
        one (Table | AggState, TaskRecord) out.  Dispatches to the
        format's ``scan_fragment`` / ``aggregate_fragment`` placement
        with the task's own limit / selectivity hint folded into ``ctx``
        (pre-TaskContext subclass overrides go through the one-release
        shim)."""
        ctx = resolve_context(ctx, legacy)
        if task.kind == "scan":
            hint = getattr(task, "selectivity_hint", None)
            if task.limit is not None or hint is not None:
                ctx = dataclasses.replace(
                    ctx,
                    limit=task.limit if task.limit is not None
                    else ctx.limit,
                    selectivity_hint=hint if hint is not None
                    else ctx.selectivity_hint)
            return _call_scan(self, fs, task.fragment, task.columns,
                              task.predicate, ctx)
        if _takes_ctx(type(self), "aggregate_fragment"):
            return self.aggregate_fragment(
                fs, task.fragment, task.specs, task.group_by,
                task.predicate, schema=task.schema,
                max_groups=task.max_groups, ctx=ctx)
        return self.aggregate_fragment(
            fs, task.fragment, task.specs, task.group_by, task.predicate,
            schema=task.schema, max_groups=task.max_groups,
            **_legacy_call_kwargs(type(self), "aggregate_fragment", ctx))

    def explain_task(self, fs: CephFS, task) -> str:
        """One-line placement/cache/hedge annotation for ``explain()``."""
        return f"placement={self.name}"


def resolve_format(format: "FileFormat | str",
                   decode_backend=None) -> "FileFormat":
    """Resolve the Scanner/Query ``format`` argument: a FileFormat
    instance passes through; a known name constructs a fresh instance; an
    unknown value raises a ValueError naming the choices.

    ``decode_backend`` (None / "numpy" / "pallas" / a DecodeBackend)
    picks the *client-side* decode engine: it configures the constructed
    ``ParquetFormat`` or ``AdaptiveFormat`` (whose storage side always
    runs the host path — OSDs have no accelerator).  It cannot be
    combined with an already-built instance or with the pure
    storage-side "pushdown" format."""
    if isinstance(format, FileFormat):
        if decode_backend is not None:
            raise ValueError(
                "decode_backend= cannot reconfigure an existing FileFormat "
                "instance; pass it to the format's constructor instead")
        return format
    choices = {"parquet": ParquetFormat, "pushdown": PushdownParquetFormat,
               "adaptive": AdaptiveFormat}
    if isinstance(format, str) and format in choices:
        if decode_backend is not None:
            if format == "pushdown":
                raise ValueError(
                    "decode_backend= does not apply to format='pushdown': "
                    "scan_op decodes on the storage node, which keeps the "
                    "host (numpy) path")
            return choices[format](decode_backend=decode_backend)
        return choices[format]()
    raise ValueError(
        f"unknown format {format!r}: pass one of "
        f"{sorted(choices)} or a FileFormat instance")


def is_degenerate_count(specs: Sequence[AggSpec],
                        group_by: str | None) -> bool:
    """Ungrouped bare COUNT(*): the case with the tiny ``rowcount_op``
    ``{"rows": n}`` wire contract (an integer, not a partial state)."""
    return (group_by is None and len(specs) == 1
            and specs[0].op == "count" and specs[0].column is None)


def count_state(n: int) -> AggState:
    """The degenerate COUNT(*) partial state for ``n`` matched rows."""
    return AggState([AggSpec("count")], None, cells=[int(n)], rows=int(n))


def aggregate_client(fmt: FileFormat, fs: CephFS, frag: Fragment,
                     specs, group_by, predicate, *, schema,
                     ctx: TaskContext | None = None,
                     **legacy) -> "tuple[AggState, TaskRecord]":
    """Client-side aggregation over any format's scan path: pull only the
    referenced columns through ``scan_fragment`` and fold them locally
    (no cardinality bound — the client owns its memory)."""
    ctx = resolve_context(ctx, legacy)
    cols = needed_columns(specs, group_by, schema, predicate)
    # an aggregate folds the fragment's full matching rows — the scan
    # below must not inherit a row budget from the context
    scan_ctx = dataclasses.replace(ctx, limit=None)
    tbl, rec = _call_scan(fmt, fs, frag, cols, predicate, scan_ctx)
    t0 = time.perf_counter()
    state = partial_aggregate(tbl, specs, group_by)
    fold = time.perf_counter() - t0
    # the fold burns client CPU; it counts toward cpu_s only when the
    # record's `where` IS the client (a pushdown spill keeps its cpu_s as
    # the OSD's decode time)
    rec = dataclasses.replace(
        rec, cpu_s=rec.cpu_s + (fold if rec.where == "client" else 0.0),
        client_cpu_s=rec.client_cpu_s + fold, rows_out=state.rows)
    return state, rec


def _admit_fragment(fs: CephFS, frag: Fragment, ctx: TaskContext):
    """Slot on the OSD this fragment's bytes live on (no-op without an
    admission controller on the context)."""
    if ctx.admission is None:
        return contextlib.nullcontext()
    name = fs.object_names(frag.path)[frag.obj_idx]
    return ctx.admission.admit_object(name, ctx)


class ParquetFormat(FileFormat):
    """Client-side scan: read (compressed) column chunks through CephFS,
    decode + filter on the client.  ``decode_backend`` picks the decode
    engine — None/"numpy" for the host path, "pallas" to route DICT
    decode / predicate evaluation / selection through the
    ``repro.kernels`` accelerator ops (``repro.aformat.decode``)."""

    name = "parquet"

    def __init__(self, *, decode_backend=None):
        self.decode_backend = decode_mod.resolve_backend(decode_backend)

    def scan_fragment(self, fs, frag, columns, predicate, ctx=None,
                      **legacy):
        ctx = resolve_context(ctx, legacy)
        wire = 0

        def on_read(n):
            nonlocal wire
            wire += n

        src = FileSource(fs, frag.path, on_read=on_read)
        with _admit_fragment(fs, frag, ctx):
            t0 = time.perf_counter()
            meta = frag.client_meta
            if meta is None:
                meta = parquet.read_footer(src)
            rg = meta.row_groups[frag.client_rg_index]
            tbl = parquet.scan_row_group(src, meta, rg, columns, predicate,
                                         backend=self.decode_backend)
            if ctx.limit is not None:
                # the raw chunk bytes already crossed the wire (client
                # placement decodes whole chunks); the slice only trims
                # what the caller materializes
                tbl = tbl.head(ctx.limit)
            cpu = time.perf_counter() - t0
        rec = TaskRecord("client", -1, cpu, wire, cpu, len(tbl))
        return tbl, rec

    def describe_backend(self, task) -> str:
        """The decode backend's static routing for ``task``'s fragment
        (per-column kernel-vs-host fallbacks, predicate lowering) — the
        ``backend=`` annotation in ``explain()``.  Split-layout fragments
        carry no client-side footer, so their per-column routing resolves
        at scan time."""
        frag = task.fragment
        meta = frag.client_meta if frag.client_meta is not None \
            else frag.footer
        if meta is None:
            return f"{self.decode_backend.name}(meta@scan)"
        rg_index = frag.client_rg_index if frag.client_meta is not None \
            else 0
        columns = task.columns if task.kind == "scan" else None
        return self.decode_backend.describe(
            meta, meta.row_groups[rg_index], columns, task.predicate)

    def explain_task(self, fs, task):
        return f"placement=client backend={self.describe_backend(task)}"


def scan_payload(frag: Fragment, columns, predicate,
                 limit: int | None = None) -> dict[str, Any]:
    """The ``scan_op`` request for one fragment — shared by the static
    pushdown format and the adaptive scheduler so the wire contract can
    never diverge between the two.  ``limit`` is the scan's remaining row
    budget: the storage node stops decoding once it is met and ships at
    most that many rows."""
    payload: dict[str, Any] = {
        "columns": list(columns) if columns is not None else None,
        "predicate": predicate.to_json() if predicate is not None else None,
        "row_groups": [frag.rg_in_object],
    }
    if limit is not None:
        payload["limit"] = int(limit)
    if frag.footer is not None:
        # wire form: bloom index blocks stripped — the OSD prunes with
        # min/max stats (and its own object footer, which keeps them)
        payload["footer"] = frag.footer.serialize(include_indexes=False)
    return payload


def agg_payload(frag: Fragment, specs: Sequence[AggSpec],
                group_by: str | None, predicate: Expr | None,
                max_groups: int) -> dict[str, Any]:
    """The ``agg_op`` request for one fragment — shared by the static
    pushdown format and the adaptive scheduler (same wire-contract rule
    as :func:`scan_payload`)."""
    payload: dict[str, Any] = {
        "aggs": [s.to_json() for s in specs],
        "group_by": group_by,
        "predicate": predicate.to_json() if predicate is not None else None,
        "row_groups": [frag.rg_in_object],
        "max_groups": max_groups,
    }
    if frag.footer is not None:
        # wire form: bloom index blocks stripped — the OSD prunes with
        # min/max stats (and its own object footer, which keeps them)
        payload["footer"] = frag.footer.serialize(include_indexes=False)
    return payload


def parse_agg_reply(raw: bytes) -> "AggState | None":
    """Decode an ``agg_op`` reply; None means the storage node spilled
    (group cardinality over the bound) and the caller must fall back to a
    scan."""
    if json.loads(raw).get("spill"):
        return None
    return AggState.deserialize(raw)


class PushdownParquetFormat(FileFormat):
    """Storage-side scan (the paper's RADOS Parquet): invoke ``scan_op`` on
    the object through DirectObjectAccess; the node decodes/filters and
    returns Arrow IPC; the client only deserializes buffers."""

    name = "pushdown"

    def __init__(self, *, hedge_threshold_s: float | None = None):
        self.hedge_threshold_s = hedge_threshold_s

    def scan_fragment(self, fs, frag, columns, predicate, ctx=None,
                      **legacy):
        # the hint prices placement choices; a static placement ignores it
        ctx = resolve_context(ctx, legacy)
        doa = DirectObjectAccess(fs)
        payload = scan_payload(frag, columns, predicate, ctx.limit)
        with _admit_fragment(fs, frag, ctx):
            if self.hedge_threshold_s is not None:
                result, osd_id, el, hedged = doa.call_hedged(
                    frag.path, frag.obj_idx, "scan_op", payload,
                    hedge_threshold_s=self.hedge_threshold_s,
                    tenant=ctx.tenant, lane=ctx.lane)
            else:
                result, osd_id, el = doa.call(frag.path, frag.obj_idx,
                                              "scan_op", payload,
                                              tenant=ctx.tenant,
                                              lane=ctx.lane)
                hedged = False
        t0 = time.perf_counter()
        tbl = Table.from_ipc(result)
        client_cpu = time.perf_counter() - t0
        rec = TaskRecord("osd", osd_id, el, len(result), client_cpu,
                         len(tbl), hedged=hedged)
        return tbl, rec

    def aggregate_fragment(self, fs, frag, specs, group_by, predicate, *,
                           schema, max_groups=DEFAULT_MAX_GROUPS,
                           ctx=None, **legacy):
        """``agg_op`` on the storage node: only the serialized partial
        state crosses the wire.  A SPILL reply (cardinality over
        ``max_groups``) falls back to the storage-side *scan* — filtered
        columns ship, the client folds them (spill-to-scan).  The
        degenerate ungrouped COUNT(*) keeps the historic ``rowcount_op``
        contract: a bare integer on the wire, not a partial state."""
        ctx = resolve_context(ctx, legacy)
        if is_degenerate_count(specs, group_by):
            return self._count_fragment(fs, frag, predicate, ctx)
        doa = DirectObjectAccess(fs)
        payload = agg_payload(frag, specs, group_by, predicate, max_groups)
        with _admit_fragment(fs, frag, ctx):
            if self.hedge_threshold_s is not None:
                raw, osd_id, el, hedged = doa.call_hedged(
                    frag.path, frag.obj_idx, "agg_op", payload,
                    hedge_threshold_s=self.hedge_threshold_s,
                    tenant=ctx.tenant, lane=ctx.lane)
            else:
                raw, osd_id, el = doa.call(frag.path, frag.obj_idx,
                                           "agg_op", payload,
                                           tenant=ctx.tenant, lane=ctx.lane)
                hedged = False
        t0 = time.perf_counter()
        state = parse_agg_reply(raw)
        if state is None:
            state, rec = aggregate_client(self, fs, frag, specs, group_by,
                                          predicate, schema=schema,
                                          ctx=ctx)
            # the refused agg_op reply still crossed the wire
            rec = dataclasses.replace(
                rec, wire_bytes=rec.wire_bytes + len(raw), hedged=hedged)
            return state, rec
        client_cpu = time.perf_counter() - t0
        rec = TaskRecord("osd", osd_id, el, len(raw), client_cpu,
                         state.rows, hedged=hedged)
        return state, rec

    def _count_fragment(self, fs, frag, predicate, ctx: TaskContext):
        """COUNT(*) [WHERE pred] via ``rowcount_op``: only an integer
        crosses the wire."""
        doa = DirectObjectAccess(fs)
        payload: dict[str, Any] = {
            "predicate": predicate.to_json()
            if predicate is not None else None,
            "row_groups": [frag.rg_in_object],
        }
        if frag.footer is not None:
            payload["footer"] = frag.footer.serialize(
                include_indexes=False)
        with _admit_fragment(fs, frag, ctx):
            if self.hedge_threshold_s is not None:
                raw, osd_id, el, hedged = doa.call_hedged(
                    frag.path, frag.obj_idx, "rowcount_op", payload,
                    hedge_threshold_s=self.hedge_threshold_s,
                    tenant=ctx.tenant, lane=ctx.lane)
            else:
                raw, osd_id, el = doa.call(frag.path, frag.obj_idx,
                                           "rowcount_op", payload,
                                           tenant=ctx.tenant, lane=ctx.lane)
                hedged = False
        n = json.loads(raw)["rows"]
        rec = TaskRecord("osd", osd_id, el, len(raw), 0.0, n,
                         hedged=hedged)
        return count_state(n), rec

    def explain_task(self, fs, task):
        hedge = (f" hedge@{self.hedge_threshold_s}s"
                 if self.hedge_threshold_s is not None else "")
        return f"placement=osd{hedge}"


class AdaptiveFormat(FileFormat):
    """Runtime per-fragment placement (the adaptive scheduler's front-end).

    Each fragment is routed storage-side or client-side by a
    ``ScanScheduler`` reading live OSD load (``ObjectStore.load_of``),
    with hedged storage scans and an LRU columnar result cache.  Keep one
    instance across scans to retain the cache and the learned rate
    estimates; pass ``scheduler=`` to share a scheduler between formats.
    """

    name = "adaptive"

    def __init__(self, scheduler: "Any | None" = None, *,
                 decode_backend=None, **scheduler_kwargs):
        # one scheduler per cluster: scanning dataset A then dataset B on
        # different clusters must not rebuild (and so lose) either
        # scheduler's cache and learned rates
        if scheduler is not None and decode_backend is not None:
            raise ValueError(
                "pass decode_backend to the ScanScheduler constructor "
                "when supplying a scheduler instance")
        if decode_backend is not None:
            # the client side of every scheduler this format builds runs
            # this decode engine; the storage side always stays on the
            # host path (scan_op runs on the OSD)
            scheduler_kwargs["decode_backend"] = decode_backend
        self._schedulers: dict[int, Any] = \
            {id(scheduler.fs): scheduler} if scheduler is not None else {}
        self._kwargs = scheduler_kwargs
        self._bind_lock = threading.Lock()

    def scheduler_for(self, fs: CephFS):
        """The scheduler bound to ``fs`` (created on first use)."""
        from repro.dataset.scheduler import ScanScheduler
        with self._bind_lock:
            sched = self._schedulers.get(id(fs))
            if sched is None:
                sched = ScanScheduler(fs, **self._kwargs)
                self._schedulers[id(fs)] = sched
            return sched

    def scan_fragment(self, fs, frag, columns, predicate, ctx=None,
                      **legacy):
        ctx = resolve_context(ctx, legacy)
        return self.scheduler_for(fs).scan_fragment(frag, columns,
                                                    predicate, ctx)

    def aggregate_fragment(self, fs, frag, specs, group_by, predicate, *,
                           schema, max_groups=DEFAULT_MAX_GROUPS,
                           ctx=None, **legacy):
        ctx = resolve_context(ctx, legacy)
        return self.scheduler_for(fs).aggregate_fragment(
            frag, specs, group_by, predicate, schema=schema,
            max_groups=max_groups, ctx=ctx)

    def explain_task(self, fs, task):
        """Live placement estimate + result-cache probe for explain().
        The probe mirrors the executor's key choice exactly (scan /
        degenerate-count / aggregate); for limited scans it uses the
        plan-time budget, which is what the first-issued tasks run
        with."""
        sched = self.scheduler_for(fs)
        frag = task.fragment
        est = sched.estimate(frag)
        if task.kind == "scan":
            key = sched.cache_key(frag, task.columns, task.predicate,
                                  task.limit)
        elif is_degenerate_count(task.specs, task.group_by):
            key = sched.count_cache_key(frag, task.predicate)
        else:
            key = sched.agg_cache_key(frag, task.specs, task.group_by,
                                      task.max_groups, task.predicate)
        cached = sched.cache.contains(key)
        # name the decode engine each side would run: the storage side is
        # always the host path, the client side is whatever backend the
        # scheduler's client format carries (with its per-column
        # kernel-vs-host routing)
        backend = sched._client_fmt.describe_backend(task)
        return (f"placement={est.where} est_osd={est.est_osd_s * 1e3:.2f}ms "
                f"est_client={est.est_client_s * 1e3:.2f}ms "
                f"pressure={est.pressure:.2f} "
                f"cached={'yes' if cached else 'no'} "
                f"backend[client]={backend} backend[osd]=numpy")

    def stats(self) -> dict:
        """Decision/hedge/cache counters, summed across every cluster
        this format has scanned."""
        out: dict[str, Any] = {}
        for sched in self._schedulers.values():
            for key, val in sched.stats().items():
                if isinstance(val, dict):
                    agg = out.setdefault(key, {})
                    for k, v in val.items():
                        agg[k] = agg.get(k, 0) + v
                else:
                    out[key] = out.get(key, 0) + val
        return out
