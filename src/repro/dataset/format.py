"""FileFormat: where a fragment's scan executes.

``ParquetFormat``          — client-side scan: column-chunk bytes travel
                             over the wire, decode/filter burn client CPU.
``PushdownParquetFormat``  — the paper's contribution: ``scan_op`` runs on
                             the storage node holding the object; only the
                             filtered/projected Arrow-IPC result travels.

Switching the format argument switches the placement — nothing else in the
Dataset/Scanner API changes (paper §2.2, RadosParquetFileFormat).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

from repro.aformat import parquet
from repro.aformat.expressions import Expr
from repro.aformat.table import Table
from repro.dataset.fragment import Fragment
from repro.storage.cephfs import CephFS, DirectObjectAccess, FileSource


@dataclasses.dataclass
class TaskRecord:
    """Per-fragment accounting — feeds the Fig. 5/6 performance model."""

    where: str            # "client" or "osd"
    node: int             # osd id (-1 for client-only work)
    cpu_s: float          # decode/filter CPU burned at `where`
    wire_bytes: int       # bytes that crossed the network to the client
    client_cpu_s: float   # residual client CPU (IPC decode / materialize)
    rows_out: int
    hedged: bool = False


class FileFormat:
    """Scan a fragment; returns (Table, TaskRecord)."""

    name = "abstract"

    def scan_fragment(self, fs: CephFS, frag: Fragment,
                      columns: Sequence[str] | None,
                      predicate: Expr | None) -> tuple[Table, TaskRecord]:
        raise NotImplementedError


class ParquetFormat(FileFormat):
    """Client-side scan: read (compressed) column chunks through CephFS,
    decode + filter on the client."""

    name = "parquet"

    def scan_fragment(self, fs, frag, columns, predicate):
        wire = 0

        def on_read(n):
            nonlocal wire
            wire += n

        src = FileSource(fs, frag.path, on_read=on_read)
        t0 = time.perf_counter()
        meta = frag.client_meta
        if meta is None:
            meta = parquet.read_footer(src)
        rg = meta.row_groups[frag.client_rg_index]
        tbl = parquet.scan_row_group(src, meta, rg, columns, predicate)
        cpu = time.perf_counter() - t0
        rec = TaskRecord("client", -1, cpu, wire, cpu, len(tbl))
        return tbl, rec


class PushdownParquetFormat(FileFormat):
    """Storage-side scan (the paper's RADOS Parquet): invoke ``scan_op`` on
    the object through DirectObjectAccess; the node decodes/filters and
    returns Arrow IPC; the client only deserializes buffers."""

    name = "pushdown"

    def __init__(self, *, hedge_threshold_s: float | None = None):
        self.hedge_threshold_s = hedge_threshold_s

    def _payload(self, frag, columns, predicate) -> dict[str, Any]:
        payload: dict[str, Any] = {
            "columns": list(columns) if columns is not None else None,
            "predicate": predicate.to_json() if predicate is not None else None,
            "row_groups": [frag.rg_in_object],
        }
        if frag.footer is not None:
            payload["footer"] = frag.footer.serialize()
        return payload

    def scan_fragment(self, fs, frag, columns, predicate):
        doa = DirectObjectAccess(fs)
        payload = self._payload(frag, columns, predicate)
        if self.hedge_threshold_s is not None:
            result, osd_id, el, hedged = doa.call_hedged(
                frag.path, frag.obj_idx, "scan_op", payload,
                hedge_threshold_s=self.hedge_threshold_s)
        else:
            result, osd_id, el = doa.call(frag.path, frag.obj_idx,
                                          "scan_op", payload)
            hedged = False
        t0 = time.perf_counter()
        tbl = Table.from_ipc(result)
        client_cpu = time.perf_counter() - t0
        rec = TaskRecord("osd", osd_id, el, len(result), client_cpu,
                         len(tbl), hedged=hedged)
        return tbl, rec
