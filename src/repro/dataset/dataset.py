"""Dataset / Scanner — the Arrow Dataset API analogue (paper §2.2).

Discovery maps a CephFS prefix to a list of self-contained Fragments for
any of the three layouts (flat single-object files, striped, split).
Queries are built lazily through :meth:`Dataset.query` (select / filter /
limit / aggregate / count / join — joins push the build side's keys into
the probe scan as an IN-list or bloom filter), optimized as a logical
plan, and lowered to
per-fragment physical tasks run by the one shared streaming executor
(``repro.dataset.plan``) through whichever FileFormat placement the
caller picked:

* ``format="parquet"``   — client-side decode (the paper's baseline),
* ``format="pushdown"``  — storage-side ``scan_op`` (the paper's RADOS
  Parquet),
* ``format="adaptive"``  — per-fragment placement decided at runtime by
  the :class:`~repro.dataset.scheduler.ScanScheduler` from live OSD load,
  with hedged storage scans and an LRU columnar result cache (this repo's
  extension past the paper's static-placement limitation).

:class:`Scanner` survives as the eager compatibility wrapper: each of its
verbs builds the equivalent lazy query and runs it, so every optimization
written for the plan layer (pruning, projection/limit pushdown, metadata
rewrites) applies to all verbs at once.
"""

from __future__ import annotations

import struct
from typing import Iterator, Sequence

from repro.aformat import parquet
from repro.aformat.aggregate import DEFAULT_MAX_GROUPS
from repro.aformat.expressions import Expr
from repro.aformat.schema import Schema
from repro.aformat.table import Table
from repro.dataset.format import FileFormat, resolve_format
from repro.dataset.fragment import Fragment
from repro.dataset.plan import Query, ScanMetrics
from repro.storage import layouts
from repro.storage.cephfs import CephFS


# ---------------------------------------------------------------------------
# Discovery
# ---------------------------------------------------------------------------


class Dataset:
    def __init__(self, fs: CephFS, schema: Schema,
                 fragments: list[Fragment], *, layout: str,
                 discovery_bytes: int = 0):
        self.fs = fs
        self.schema = schema
        self._fragments = fragments
        self.layout = layout
        self.discovery_bytes = discovery_bytes

    def fragments(self) -> list[Fragment]:
        return list(self._fragments)

    @property
    def num_rows(self) -> int:
        return sum(f.num_rows for f in self._fragments)

    def query(self, *, format: FileFormat | str = "pushdown",
              num_threads: int = 16, queue_depth: int = 4,
              decode_backend=None, tenant=None) -> Query:
        """Start a lazy query: ``ds.query().select(...).filter(...)
        .limit(n)`` / ``.aggregate(...)`` / ``.count()``, executed via
        ``to_table`` / ``to_batches`` / ``to_scalar`` and inspectable via
        ``explain()``.  ``format`` picks the placement exactly as in
        :meth:`scanner`; ``decode_backend`` picks the client-side decode
        engine (None/"numpy" for the host path, "pallas" for the
        ``repro.kernels`` accelerator ops) for the "parquet" and
        "adaptive" formats.  ``tenant`` tags the run for multi-tenant
        QoS: a tenant name, a :class:`~repro.dataset.qos.TaskContext`
        (usually ``TenantRegistry.context(name)`` — weight, lane,
        deadline), or None for the default tenant."""
        return Query(self, format=format, num_threads=num_threads,
                     queue_depth=queue_depth,
                     decode_backend=decode_backend, tenant=tenant)

    def scanner(self, *, format: FileFormat | str = "pushdown",
                columns: Sequence[str] | None = None,
                predicate: Expr | None = None,
                num_threads: int = 16, queue_depth: int = 4,
                decode_backend=None, tenant=None) -> "Scanner":
        """Build a Scanner.  ``format`` is a FileFormat instance or one of
        "parquet" (client-side), "pushdown" (storage-side), "adaptive"
        (scheduler-placed; pass an ``AdaptiveFormat`` instance instead to
        keep its result cache warm across scans).  ``decode_backend``
        picks the client-side decode engine exactly as in :meth:`query`;
        ``tenant`` tags every verb's run for multi-tenant QoS exactly as
        in :meth:`query`."""
        return Scanner(self,
                       resolve_format(format,
                                      decode_backend=decode_backend),
                       columns, predicate, num_threads=num_threads,
                       queue_depth=queue_depth, tenant=tenant)


def _footer_tail_bytes(fs: CephFS, path: str) -> tuple[parquet.FileMeta, int]:
    """Read just the footer of a flat ARW1 file through CephFS (two range
    reads: length word, then the footer) — returns (meta, bytes_read)."""
    size = fs.file_size(path)
    tail = fs.read_range(path, size - 8, 8)
    (flen,) = struct.unpack("<I", tail[:4])
    raw = fs.read_range(path, size - 8 - flen, flen)
    return parquet.FileMeta.deserialize(raw), flen + 8


def dataset(fs: CephFS, prefix: str, layout: str = "auto") -> Dataset:
    """Discover a dataset under ``prefix``.

    A prefix that carries a snapshot log (``MutableDataset.create`` /
    ``append``) is discovered through its *manifest*, not by re-listing
    the prefix: one HEAD read materializes the current snapshot with
    every footer embedded — exact under concurrent appends, and
    uncommitted or retired data files are invisible.

    Otherwise: auto = split if ``.index`` files exist, else striped if
    the files carry the striped xattr, else flat.
    """
    if layout in ("auto", "mutable"):
        from repro.dataset import snapshot as snapshot_mod

        if snapshot_mod.is_mutable(fs, prefix):
            return snapshot_mod.MutableDataset.open(fs, prefix).as_of()
        if layout == "mutable":
            raise FileNotFoundError(
                f"no mutable dataset (snapshot log) at {prefix!r}")
    paths = fs.listdir(prefix)
    if not paths:
        raise FileNotFoundError(f"no files under {prefix!r}")
    index_paths = [p for p in paths if p.endswith(".index")]
    if layout == "auto":
        if index_paths:
            layout = "split"
        elif any(fs.stat(p).xattrs.get("layout") == "striped"
                 for p in paths):
            layout = "striped"
        else:
            layout = "flat"

    if layout == "split":
        return _discover_split(fs, index_paths)
    if layout == "striped":
        striped = [p for p in paths
                   if fs.stat(p).xattrs.get("layout") == "striped"]
        return _discover_striped(fs, striped)
    flat = [p for p in paths if p.endswith(".arw")
            and fs.stat(p).xattrs.get("layout") not in ("split-part",
                                                        "split-index")]
    return _discover_flat(fs, flat)


def _discover_flat(fs, paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for path in sorted(paths):
        meta, nbytes = _footer_tail_bytes(fs, path)
        disc += nbytes
        schema = schema or meta.schema
        ino = fs.stat(path)
        for i, rg in enumerate(meta.row_groups):
            obj_idx = rg.offset // ino.stripe_unit
            end_obj = (rg.offset + rg.total_bytes - 1) // ino.stripe_unit
            if obj_idx != end_obj:
                raise ValueError(
                    f"{path}: row group {i} spans objects; write flat "
                    "files with write_flat (single object) or use the "
                    "striped/split layouts")
            frags.append(Fragment(
                path, obj_idx, i, rg.num_rows,
                stats=rg.column_stats(meta.schema),
                footer=None, client_meta=meta, client_rg_index=i))
    return Dataset(fs, schema, frags, layout="flat", discovery_bytes=disc)


def _discover_striped(fs, paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for path in sorted(paths):
        meta = layouts.read_striped_footer(fs, path)
        ino = fs.stat(path)
        su = ino.stripe_unit
        disc += len(meta.serialize()) + 8
        schema = schema or meta.schema
        for i, rg in enumerate(meta.row_groups):
            obj_idx = rg.offset // su
            # rebase the row group's chunk offsets to the object's origin
            rebased = parquet._shift_group(rg, -obj_idx * su)
            sub = parquet.FileMeta(meta.schema, [rebased], rg.num_rows)
            frags.append(Fragment(
                path, obj_idx, 0, rg.num_rows,
                stats=rg.column_stats(meta.schema),
                footer=sub, client_meta=meta, client_rg_index=i))
    return Dataset(fs, schema, frags, layout="striped",
                   discovery_bytes=disc)


def _discover_split(fs, index_paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for ipath in sorted(index_paths):
        raw = fs.read_file(ipath)
        disc += len(raw)
        index = layouts.SplitIndex.deserialize(raw)
        schema = schema or index.schema
        for rg in index.row_groups:
            frags.append(Fragment(
                rg["file"], 0, 0, rg["num_rows"], stats=rg["stats"],
                footer=None, client_meta=None, client_rg_index=0))
    return Dataset(fs, schema, frags, layout="split", discovery_bytes=disc)


# ---------------------------------------------------------------------------
# Scanner — eager compatibility wrappers over the lazy query plan
# ---------------------------------------------------------------------------


class Scanner:
    """Eager facade over :class:`~repro.dataset.plan.Query`.

    Every verb builds the equivalent lazy query, runs it through the one
    optimizer + streaming executor, and snapshots that execution's
    :class:`ScanMetrics` into ``self.metrics`` (the last run's record —
    re-running a verb on the same Scanner never double-counts).  Prefer
    ``Dataset.query()`` for new code; these verbs stay for the paper's
    original API shape.
    """

    def __init__(self, ds: Dataset, fmt: FileFormat,
                 columns: Sequence[str] | None, predicate: Expr | None, *,
                 num_threads: int = 16, queue_depth: int = 4, tenant=None):
        self.ds = ds
        self.fmt = fmt
        self.columns = list(columns) if columns is not None else None
        self.predicate = predicate
        self.num_threads = num_threads
        self.queue_depth = queue_depth
        self.tenant = tenant
        self.metrics = ScanMetrics(discovery_bytes=ds.discovery_bytes)

    def query(self) -> Query:
        """The lazy query equivalent to this Scanner's columns/predicate
        (the verbs below all lower through it)."""
        q = Query(self.ds, format=self.fmt, num_threads=self.num_threads,
                  queue_depth=self.queue_depth, tenant=self.tenant)
        if self.predicate is not None:
            q = q.filter(self.predicate)
        if self.columns is not None:
            q = q.select(self.columns)
        return q

    def explain(self) -> str:
        """Render the plan this Scanner's ``to_table`` would run."""
        return self.query().explain()

    def _run(self, q: Query, result):
        self.metrics = q.metrics
        return result

    def to_batches(self, *, max_inflight: int | None = None
                   ) -> Iterator[Table]:
        """Stream the scan as an iterator of per-fragment Tables in
        completion order.  In-flight work is bounded by ``max_inflight``
        (default: the scanner's ``num_threads``) and driven by
        consumption: a paused consumer pauses the scan after at most
        ``max_inflight`` buffered fragments.  Empty fragments are
        skipped."""
        q = self.query()
        batches = q.to_batches(max_inflight=max_inflight)
        self.metrics = q.metrics      # mutated live as batches stream
        return batches

    def to_table(self) -> Table:
        """Materialize the full result (plan order)."""
        q = self.query()
        return self._run(q, q.to_table())

    def aggregate(self, aggs, *, group_by: str | None = None,
                  max_groups: int = DEFAULT_MAX_GROUPS) -> Table:
        """SUM/MIN/MAX/MEAN/COUNT — optionally GROUP BY one key column —
        with storage-side partial aggregation (see ``Query.aggregate``):
        stats-pruned, footer-metadata-answered where provable, fanned out
        through the shared executor, partial states merged in completion
        order."""
        q = self.query().aggregate(aggs, group_by=group_by,
                                   max_groups=max_groups)
        return self._run(q, q.to_table())

    def count_rows(self) -> int:
        """COUNT(*): the degenerate ungrouped aggregate.  Stats-provable
        fragments are answered from metadata with zero I/O; the rest ship
        only integers (``rowcount_op`` for the static pushdown format,
        placement-priced / hedged / result-cached through the scheduler
        for ``format="adaptive"``); only the client-side format decodes a
        column to count it."""
        q = self.query().count()
        return self._run(q, int(q.to_scalar()))
