"""Dataset / Scanner — the Arrow Dataset API analogue (paper §2.2).

Discovery maps a CephFS prefix to a list of self-contained Fragments for
any of the three layouts (flat single-object files, striped, split); the
Scanner prunes fragments on footer/index statistics (predicate pushdown),
then scans the survivors in parallel with a bounded per-storage-node queue
depth, through whichever FileFormat placement the caller picked:

* ``format="parquet"``   — client-side decode (the paper's baseline),
* ``format="pushdown"``  — storage-side ``scan_op`` (the paper's RADOS
  Parquet),
* ``format="adaptive"``  — per-fragment placement decided at runtime by
  the :class:`~repro.dataset.scheduler.ScanScheduler` from live OSD load,
  with hedged storage scans and an LRU columnar result cache (this repo's
  extension past the paper's static-placement limitation).
"""

from __future__ import annotations

import dataclasses
import struct
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from itertools import islice
from typing import Iterator, Sequence

import numpy as np

from repro.aformat import parquet
from repro.aformat.aggregate import (AggState, DEFAULT_MAX_GROUPS,
                                     parse_aggs, partial_from_stats)
from repro.aformat.expressions import ALL, NONE, Expr
from repro.aformat.schema import Schema
from repro.aformat.table import Column, Table
from repro.dataset.admission import AdmissionController
from repro.dataset.format import (AdaptiveFormat, FileFormat, ParquetFormat,
                                  PushdownParquetFormat, TaskRecord)
from repro.dataset.fragment import Fragment
from repro.storage import layouts
from repro.storage.cephfs import CephFS


# ---------------------------------------------------------------------------
# Discovery
# ---------------------------------------------------------------------------


class Dataset:
    def __init__(self, fs: CephFS, schema: Schema,
                 fragments: list[Fragment], *, layout: str,
                 discovery_bytes: int = 0):
        self.fs = fs
        self.schema = schema
        self._fragments = fragments
        self.layout = layout
        self.discovery_bytes = discovery_bytes

    def fragments(self) -> list[Fragment]:
        return list(self._fragments)

    @property
    def num_rows(self) -> int:
        return sum(f.num_rows for f in self._fragments)

    def scanner(self, *, format: FileFormat | str = "pushdown",
                columns: Sequence[str] | None = None,
                predicate: Expr | None = None,
                num_threads: int = 16, queue_depth: int = 4) -> "Scanner":
        """Build a Scanner.  ``format`` is a FileFormat instance or one of
        "parquet" (client-side), "pushdown" (storage-side), "adaptive"
        (scheduler-placed; pass an ``AdaptiveFormat`` instance instead to
        keep its result cache warm across scans)."""
        if isinstance(format, str):
            format = {"parquet": ParquetFormat,
                      "pushdown": PushdownParquetFormat,
                      "adaptive": AdaptiveFormat}[format]()
        return Scanner(self, format, columns, predicate,
                       num_threads=num_threads, queue_depth=queue_depth)


def _footer_tail_bytes(fs: CephFS, path: str) -> tuple[parquet.FileMeta, int]:
    """Read just the footer of a flat ARW1 file through CephFS (two range
    reads: length word, then the footer) — returns (meta, bytes_read)."""
    size = fs.file_size(path)
    tail = fs.read_range(path, size - 8, 8)
    (flen,) = struct.unpack("<I", tail[:4])
    raw = fs.read_range(path, size - 8 - flen, flen)
    return parquet.FileMeta.deserialize(raw), flen + 8


def dataset(fs: CephFS, prefix: str, layout: str = "auto") -> Dataset:
    """Discover a dataset under ``prefix``.

    auto: split if ``.index`` files exist, else striped if the files carry
    the striped xattr, else flat.
    """
    paths = fs.listdir(prefix)
    if not paths:
        raise FileNotFoundError(f"no files under {prefix!r}")
    index_paths = [p for p in paths if p.endswith(".index")]
    if layout == "auto":
        if index_paths:
            layout = "split"
        elif any(fs.stat(p).xattrs.get("layout") == "striped"
                 for p in paths):
            layout = "striped"
        else:
            layout = "flat"

    if layout == "split":
        return _discover_split(fs, index_paths)
    if layout == "striped":
        striped = [p for p in paths
                   if fs.stat(p).xattrs.get("layout") == "striped"]
        return _discover_striped(fs, striped)
    flat = [p for p in paths if p.endswith(".arw")
            and fs.stat(p).xattrs.get("layout") not in ("split-part",
                                                        "split-index")]
    return _discover_flat(fs, flat)


def _discover_flat(fs, paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for path in sorted(paths):
        meta, nbytes = _footer_tail_bytes(fs, path)
        disc += nbytes
        schema = schema or meta.schema
        ino = fs.stat(path)
        for i, rg in enumerate(meta.row_groups):
            obj_idx = rg.offset // ino.stripe_unit
            end_obj = (rg.offset + rg.total_bytes - 1) // ino.stripe_unit
            if obj_idx != end_obj:
                raise ValueError(
                    f"{path}: row group {i} spans objects; write flat "
                    "files with write_flat (single object) or use the "
                    "striped/split layouts")
            frags.append(Fragment(
                path, obj_idx, i, rg.num_rows,
                stats=rg.column_stats(meta.schema),
                footer=None, client_meta=meta, client_rg_index=i))
    return Dataset(fs, schema, frags, layout="flat", discovery_bytes=disc)


def _discover_striped(fs, paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for path in sorted(paths):
        meta = layouts.read_striped_footer(fs, path)
        ino = fs.stat(path)
        su = ino.stripe_unit
        disc += len(meta.serialize()) + 8
        schema = schema or meta.schema
        for i, rg in enumerate(meta.row_groups):
            obj_idx = rg.offset // su
            # rebase the row group's chunk offsets to the object's origin
            rebased = parquet._shift_group(rg, -obj_idx * su)
            sub = parquet.FileMeta(meta.schema, [rebased], rg.num_rows)
            frags.append(Fragment(
                path, obj_idx, 0, rg.num_rows,
                stats=rg.column_stats(meta.schema),
                footer=sub, client_meta=meta, client_rg_index=i))
    return Dataset(fs, schema, frags, layout="striped",
                   discovery_bytes=disc)


def _discover_split(fs, index_paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for ipath in sorted(index_paths):
        raw = fs.read_file(ipath)
        disc += len(raw)
        index = layouts.SplitIndex.deserialize(raw)
        schema = schema or index.schema
        for rg in index.row_groups:
            frags.append(Fragment(
                rg["file"], 0, 0, rg["num_rows"], stats=rg["stats"],
                footer=None, client_meta=None, client_rg_index=0))
    return Dataset(fs, schema, frags, layout="split", discovery_bytes=disc)


# ---------------------------------------------------------------------------
# Scanner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScanMetrics:
    tasks: list[TaskRecord] = dataclasses.field(default_factory=list)
    fragments_total: int = 0
    fragments_pruned: int = 0
    discovery_bytes: int = 0
    rows: int = 0
    wall_s: float = 0.0
    admission: dict = dataclasses.field(default_factory=dict)

    @property
    def client_cpu_s(self) -> float:
        return sum(t.client_cpu_s for t in self.tasks)

    @property
    def osd_cpu_s(self) -> float:
        return sum(t.cpu_s for t in self.tasks if t.where == "osd")

    @property
    def wire_bytes(self) -> int:
        return self.discovery_bytes + sum(t.wire_bytes for t in self.tasks)

    @property
    def cache_hits(self) -> int:
        return sum(1 for t in self.tasks if t.cached)

    @property
    def hedged_tasks(self) -> int:
        return sum(1 for t in self.tasks if t.hedged)

    def summary(self) -> dict:
        return {
            "fragments": self.fragments_total,
            "pruned": self.fragments_pruned,
            "rows": self.rows,
            "wire_bytes": self.wire_bytes,
            "client_cpu_s": round(self.client_cpu_s, 4),
            "osd_cpu_s": round(self.osd_cpu_s, 4),
            "wall_s": round(self.wall_s, 4),
            "cache_hits": self.cache_hits,
            "hedged": self.hedged_tasks,
            "admission_waits": self.admission.get("waits", 0),
        }


class Scanner:
    """Prune -> parallel scan -> materialize (paper's query execution)."""

    def __init__(self, ds: Dataset, fmt: FileFormat,
                 columns: Sequence[str] | None, predicate: Expr | None, *,
                 num_threads: int = 16, queue_depth: int = 4):
        self.ds = ds
        self.fmt = fmt
        self.columns = list(columns) if columns is not None else None
        self.predicate = predicate
        self.num_threads = num_threads
        self.queue_depth = queue_depth
        self.metrics = ScanMetrics(discovery_bytes=ds.discovery_bytes)

    # -- pruning ---------------------------------------------------------------
    def plan(self) -> list[tuple[Fragment, Expr | None]]:
        """Stats-based row-group pruning; returns (fragment, predicate) with
        the predicate dropped where stats prove every row matches."""
        out = []
        self.metrics.fragments_total = len(self.ds._fragments)
        for frag in self.ds._fragments:
            pred = self.predicate
            if pred is not None and frag.stats:
                verdict = pred.prune(frag.stats)
                if verdict == NONE:
                    self.metrics.fragments_pruned += 1
                    continue
                if verdict == ALL:
                    pred = None
            out.append((frag, pred))
        return out

    # -- execution ---------------------------------------------------------------
    def _fan_out(self, items, run) -> list:
        """Run ``run`` over ``items`` on up to ``num_threads`` workers
        (serially when that buys nothing); results in input order.  The
        shared dispatch for every per-fragment aggregate/count fan-out —
        the streaming scan path has its own backpressured engine."""
        if len(items) <= 1 or self.num_threads <= 1:
            return [run(x) for x in items]
        with ThreadPoolExecutor(max_workers=self.num_threads) as pool:
            return list(pool.map(run, items))

    def _admission(self) -> AdmissionController:
        """One admission controller per scan: every placement (client
        byte-pulls, pushdown cls calls, adaptive either-way) draws from
        the same bounded per-OSD slots, so no format can bury a single
        storage node in queued fragment work."""
        return AdmissionController(self.ds.fs.store, self.queue_depth)

    def _scan_stream(self, max_inflight: int
                     ) -> Iterator[tuple[int, Table]]:
        """Concurrent streaming execution: at most ``max_inflight``
        fragments are in flight at once, and a new fragment is issued only
        when a finished one has been *consumed* — backpressure, so peak
        client memory is O(in-flight fragments), not O(dataset).

        Yields (plan index, Table) in completion order, empty results
        included (callers filter)."""
        plan = self.plan()
        admission = self._admission()
        lock = threading.Lock()

        def run(idx_item):
            idx, (frag, pred) = idx_item
            tbl, rec = self.fmt.scan_fragment(self.ds.fs, frag,
                                              self.columns, pred,
                                              admission=admission)
            with lock:
                self.metrics.tasks.append(rec)
            return idx, tbl

        t0 = time.perf_counter()
        items = list(enumerate(plan))
        try:
            if max_inflight <= 1 or len(items) <= 1:
                for it in items:
                    idx, tbl = run(it)
                    self.metrics.rows += len(tbl)
                    yield idx, tbl
                return
            it = iter(items)
            with ThreadPoolExecutor(max_workers=max_inflight) as pool:
                pending = {pool.submit(run, x)
                           for x in islice(it, max_inflight)}
                try:
                    while pending:
                        done, pending = wait(pending,
                                             return_when=FIRST_COMPLETED)
                        for fut in done:
                            idx, tbl = fut.result()
                            nxt = next(it, None)
                            if nxt is not None:
                                pending.add(pool.submit(run, nxt))
                            self.metrics.rows += len(tbl)
                            yield idx, tbl
                finally:
                    for fut in pending:   # consumer stopped early
                        fut.cancel()
        finally:
            self.metrics.wall_s = time.perf_counter() - t0
            self.metrics.admission = admission.stats()

    def to_batches(self, *, max_inflight: int | None = None
                   ) -> Iterator[Table]:
        """Stream the scan as an iterator of per-fragment Tables in
        completion order.  In-flight work is bounded by ``max_inflight``
        (default: the scanner's ``num_threads``) and driven by
        consumption: a paused consumer pauses the scan after at most
        ``max_inflight`` buffered fragments.  Empty fragments are
        skipped."""
        for _, tbl in self._scan_stream(max_inflight or self.num_threads):
            if len(tbl):
                yield tbl

    def to_table(self) -> Table:
        """Materialize the full result (built on the streaming engine;
        partial tables are re-assembled in plan order)."""
        parts = sorted(self._scan_stream(self.num_threads),
                       key=lambda p: p[0])
        tables = [t for _, t in parts if len(t)]
        if tables:
            result = Table.concat(tables)
        else:
            names = self.columns or self.ds.schema.names
            sch = self.ds.schema.select(names)
            result = Table(sch, [
                Column(f, np.empty(0, object if f.type == "string"
                                   else f.numpy_dtype)) for f in sch])
        self.metrics.rows = len(result)
        return result

    def aggregate(self, aggs, *, group_by: str | None = None,
                  max_groups: int = DEFAULT_MAX_GROUPS) -> Table:
        """SUM/MIN/MAX/MEAN/COUNT — optionally GROUP BY one key column —
        with storage-side partial aggregation.

        ``aggs`` is a list of :class:`~repro.aformat.aggregate.AggSpec`,
        ``(op, column)`` tuples, or ``"op(column)"`` strings ("count"
        alone is COUNT(*)).  Per fragment: stats prove NONE -> pruned;
        ungrouped, predicate-free count/min/max -> answered from footer
        metadata with zero I/O; everything else fans out over
        ``num_threads`` (admission-bounded per OSD) through the format's
        ``aggregate_fragment`` placement — ``agg_op`` partial states on
        the wire for pushdown, placement-priced / hedged / result-cached
        through the scheduler for ``format="adaptive"``, a
        projected-column scan folded locally for the client format.
        Partial states merge in completion order; the merged state is
        finalized into a result Table (one row ungrouped, one row per
        key, sorted, grouped).  ``max_groups`` bounds storage-side group
        cardinality — past it a fragment spills to a scan."""
        specs = parse_aggs(aggs)
        for s in specs:                 # validate early, not per-fragment
            if s.column is not None:
                self.ds.schema.field(s.column)
        if group_by is not None:
            self.ds.schema.field(group_by)
        state = AggState.empty(specs, group_by)
        admission = self._admission()
        lock = threading.Lock()
        remote: list[tuple[Fragment, Expr | None]] = []
        t0 = time.perf_counter()
        for frag, pred in self.plan():
            if pred is None and group_by is None and frag.stats:
                part = partial_from_stats(specs, frag.stats,
                                          frag.num_rows, self.ds.schema)
                if part is not None:    # metadata-only: zero I/O
                    state.merge(part)
                    self.metrics.tasks.append(TaskRecord(
                        "client", -1, 0.0, 0, 0.0, frag.num_rows,
                        cached=True))
                    continue
            remote.append((frag, pred))

        def run(item):
            frag, pred = item
            part, rec = self.fmt.aggregate_fragment(
                self.ds.fs, frag, specs, group_by, pred,
                schema=self.ds.schema, max_groups=max_groups,
                admission=admission)
            with lock:                  # merge in completion order
                state.merge(part)
                self.metrics.tasks.append(rec)

        try:
            self._fan_out(remote, run)
        finally:
            self.metrics.rows = state.rows
            self.metrics.wall_s = time.perf_counter() - t0
            self.metrics.admission = admission.stats()
        return state.finalize(self.ds.schema)

    def count_rows(self) -> int:
        """COUNT(*) with aggregate pushdown (the S3-Select-style extension
        of the paper's scan_op).

        Per fragment: stats prove ALL -> count from metadata with zero
        I/O; stats prove NONE -> pruned; otherwise only an integer
        crosses the wire — via ``rowcount_op`` on the storage node for
        the static pushdown format (fanned out over ``num_threads``,
        admission-bounded like any scan), or via the adaptive scheduler
        (placement-priced, hedged, result-cached) for
        ``format="adaptive"``.  Only the client-side format falls back to
        a materializing scan."""
        import json

        from repro.storage.cephfs import DirectObjectAccess

        if isinstance(self.fmt, AdaptiveFormat):
            return self._count_rows_adaptive()
        if not isinstance(self.fmt, PushdownParquetFormat):
            return len(self.to_table())
        total = 0
        self.metrics.fragments_total = len(self.ds._fragments)
        doa = DirectObjectAccess(self.ds.fs)
        admission = self._admission()
        lock = threading.Lock()
        remote: list[Fragment] = []
        for frag in self.ds._fragments:
            pred = self.predicate
            if pred is None:
                total += frag.num_rows          # metadata-only count
                continue
            if frag.stats:
                verdict = pred.prune(frag.stats)
                if verdict == NONE:
                    self.metrics.fragments_pruned += 1
                    continue
                if verdict == ALL:
                    total += frag.num_rows      # metadata-only count
                    continue
            remote.append(frag)

        def run(frag: Fragment) -> int:
            payload: dict = {
                "predicate": self.predicate.to_json(),
                "row_groups": [frag.rg_in_object],
            }
            if frag.footer is not None:
                payload["footer"] = frag.footer.serialize()
            name = self.ds.fs.object_names(frag.path)[frag.obj_idx]
            with admission.admit_object(name):
                out, osd_id, el = doa.call(frag.path, frag.obj_idx,
                                           "rowcount_op", payload)
            n = json.loads(out)["rows"]
            with lock:
                self.metrics.tasks.append(TaskRecord(
                    "osd", osd_id, el, len(out), 0.0, n))
            return n

        total += sum(self._fan_out(remote, run))
        self.metrics.rows = total
        self.metrics.admission = admission.stats()
        return total

    def _count_rows_adaptive(self) -> int:
        """COUNT(*) through the adaptive scheduler: metadata-provable
        fragments never leave the client, everything else is a
        placement-priced, result-cached ``rowcount_op`` — fanned out over
        ``num_threads`` like a scan (admission bounds per-OSD pressure)."""
        sched = self.fmt.scheduler_for(self.ds.fs)
        admission = self._admission()
        lock = threading.Lock()
        total = 0
        remote: list[tuple[Fragment, Expr]] = []
        for frag, pred in self.plan():      # same pruning as every scan
            if pred is None:
                total += frag.num_rows      # metadata-only count
            else:
                remote.append((frag, pred))

        def run(item):
            frag, pred = item
            n, rec = sched.count_fragment(frag, pred, admission=admission)
            with lock:
                self.metrics.tasks.append(rec)
            return n

        total += sum(self._fan_out(remote, run))
        self.metrics.rows = total
        self.metrics.admission = admission.stats()
        return total
