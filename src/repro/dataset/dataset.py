"""Dataset / Scanner — the Arrow Dataset API analogue (paper §2.2).

Discovery maps a CephFS prefix to a list of self-contained Fragments for
any of the three layouts (flat single-object files, striped, split); the
Scanner prunes fragments on footer/index statistics (predicate pushdown),
then scans the survivors in parallel with a bounded per-storage-node queue
depth, through whichever FileFormat placement the caller picked:

* ``format="parquet"``   — client-side decode (the paper's baseline),
* ``format="pushdown"``  — storage-side ``scan_op`` (the paper's RADOS
  Parquet),
* ``format="adaptive"``  — per-fragment placement decided at runtime by
  the :class:`~repro.dataset.scheduler.ScanScheduler` from live OSD load,
  with hedged storage scans and an LRU columnar result cache (this repo's
  extension past the paper's static-placement limitation).
"""

from __future__ import annotations

import dataclasses
import struct
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

import numpy as np

from repro.aformat import parquet
from repro.aformat.expressions import ALL, NONE, Expr
from repro.aformat.schema import Schema
from repro.aformat.table import Column, Table
from repro.dataset.format import (AdaptiveFormat, FileFormat, ParquetFormat,
                                  PushdownParquetFormat, TaskRecord)
from repro.dataset.fragment import Fragment
from repro.storage import layouts
from repro.storage.cephfs import CephFS


# ---------------------------------------------------------------------------
# Discovery
# ---------------------------------------------------------------------------


class Dataset:
    def __init__(self, fs: CephFS, schema: Schema,
                 fragments: list[Fragment], *, layout: str,
                 discovery_bytes: int = 0):
        self.fs = fs
        self.schema = schema
        self._fragments = fragments
        self.layout = layout
        self.discovery_bytes = discovery_bytes

    def fragments(self) -> list[Fragment]:
        return list(self._fragments)

    @property
    def num_rows(self) -> int:
        return sum(f.num_rows for f in self._fragments)

    def scanner(self, *, format: FileFormat | str = "pushdown",
                columns: Sequence[str] | None = None,
                predicate: Expr | None = None,
                num_threads: int = 16, queue_depth: int = 4) -> "Scanner":
        """Build a Scanner.  ``format`` is a FileFormat instance or one of
        "parquet" (client-side), "pushdown" (storage-side), "adaptive"
        (scheduler-placed; pass an ``AdaptiveFormat`` instance instead to
        keep its result cache warm across scans)."""
        if isinstance(format, str):
            format = {"parquet": ParquetFormat,
                      "pushdown": PushdownParquetFormat,
                      "adaptive": AdaptiveFormat}[format]()
        return Scanner(self, format, columns, predicate,
                       num_threads=num_threads, queue_depth=queue_depth)


def _footer_tail_bytes(fs: CephFS, path: str) -> tuple[parquet.FileMeta, int]:
    """Read just the footer of a flat ARW1 file through CephFS (two range
    reads: length word, then the footer) — returns (meta, bytes_read)."""
    size = fs.file_size(path)
    tail = fs.read_range(path, size - 8, 8)
    (flen,) = struct.unpack("<I", tail[:4])
    raw = fs.read_range(path, size - 8 - flen, flen)
    return parquet.FileMeta.deserialize(raw), flen + 8


def dataset(fs: CephFS, prefix: str, layout: str = "auto") -> Dataset:
    """Discover a dataset under ``prefix``.

    auto: split if ``.index`` files exist, else striped if the files carry
    the striped xattr, else flat.
    """
    paths = fs.listdir(prefix)
    if not paths:
        raise FileNotFoundError(f"no files under {prefix!r}")
    index_paths = [p for p in paths if p.endswith(".index")]
    if layout == "auto":
        if index_paths:
            layout = "split"
        elif any(fs.stat(p).xattrs.get("layout") == "striped"
                 for p in paths):
            layout = "striped"
        else:
            layout = "flat"

    if layout == "split":
        return _discover_split(fs, index_paths)
    if layout == "striped":
        striped = [p for p in paths
                   if fs.stat(p).xattrs.get("layout") == "striped"]
        return _discover_striped(fs, striped)
    flat = [p for p in paths if p.endswith(".arw")
            and fs.stat(p).xattrs.get("layout") not in ("split-part",
                                                        "split-index")]
    return _discover_flat(fs, flat)


def _discover_flat(fs, paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for path in sorted(paths):
        meta, nbytes = _footer_tail_bytes(fs, path)
        disc += nbytes
        schema = schema or meta.schema
        ino = fs.stat(path)
        for i, rg in enumerate(meta.row_groups):
            obj_idx = rg.offset // ino.stripe_unit
            end_obj = (rg.offset + rg.total_bytes - 1) // ino.stripe_unit
            if obj_idx != end_obj:
                raise ValueError(
                    f"{path}: row group {i} spans objects; write flat "
                    "files with write_flat (single object) or use the "
                    "striped/split layouts")
            frags.append(Fragment(
                path, obj_idx, i, rg.num_rows,
                stats=rg.column_stats(meta.schema),
                footer=None, client_meta=meta, client_rg_index=i))
    return Dataset(fs, schema, frags, layout="flat", discovery_bytes=disc)


def _discover_striped(fs, paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for path in sorted(paths):
        meta = layouts.read_striped_footer(fs, path)
        ino = fs.stat(path)
        su = ino.stripe_unit
        disc += len(meta.serialize()) + 8
        schema = schema or meta.schema
        for i, rg in enumerate(meta.row_groups):
            obj_idx = rg.offset // su
            # rebase the row group's chunk offsets to the object's origin
            rebased = parquet._shift_group(rg, -obj_idx * su)
            sub = parquet.FileMeta(meta.schema, [rebased], rg.num_rows)
            frags.append(Fragment(
                path, obj_idx, 0, rg.num_rows,
                stats=rg.column_stats(meta.schema),
                footer=sub, client_meta=meta, client_rg_index=i))
    return Dataset(fs, schema, frags, layout="striped",
                   discovery_bytes=disc)


def _discover_split(fs, index_paths) -> Dataset:
    frags: list[Fragment] = []
    schema = None
    disc = 0
    for ipath in sorted(index_paths):
        raw = fs.read_file(ipath)
        disc += len(raw)
        index = layouts.SplitIndex.deserialize(raw)
        schema = schema or index.schema
        for rg in index.row_groups:
            frags.append(Fragment(
                rg["file"], 0, 0, rg["num_rows"], stats=rg["stats"],
                footer=None, client_meta=None, client_rg_index=0))
    return Dataset(fs, schema, frags, layout="split", discovery_bytes=disc)


# ---------------------------------------------------------------------------
# Scanner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScanMetrics:
    tasks: list[TaskRecord] = dataclasses.field(default_factory=list)
    fragments_total: int = 0
    fragments_pruned: int = 0
    discovery_bytes: int = 0
    rows: int = 0
    wall_s: float = 0.0

    @property
    def client_cpu_s(self) -> float:
        return sum(t.client_cpu_s for t in self.tasks)

    @property
    def osd_cpu_s(self) -> float:
        return sum(t.cpu_s for t in self.tasks if t.where == "osd")

    @property
    def wire_bytes(self) -> int:
        return self.discovery_bytes + sum(t.wire_bytes for t in self.tasks)

    @property
    def cache_hits(self) -> int:
        return sum(1 for t in self.tasks if t.cached)

    @property
    def hedged_tasks(self) -> int:
        return sum(1 for t in self.tasks if t.hedged)

    def summary(self) -> dict:
        return {
            "fragments": self.fragments_total,
            "pruned": self.fragments_pruned,
            "rows": self.rows,
            "wire_bytes": self.wire_bytes,
            "client_cpu_s": round(self.client_cpu_s, 4),
            "osd_cpu_s": round(self.osd_cpu_s, 4),
            "wall_s": round(self.wall_s, 4),
            "cache_hits": self.cache_hits,
            "hedged": self.hedged_tasks,
        }


class Scanner:
    """Prune -> parallel scan -> materialize (paper's query execution)."""

    def __init__(self, ds: Dataset, fmt: FileFormat,
                 columns: Sequence[str] | None, predicate: Expr | None, *,
                 num_threads: int = 16, queue_depth: int = 4):
        self.ds = ds
        self.fmt = fmt
        self.columns = list(columns) if columns is not None else None
        self.predicate = predicate
        self.num_threads = num_threads
        self.queue_depth = queue_depth
        self.metrics = ScanMetrics(discovery_bytes=ds.discovery_bytes)

    # -- pruning ---------------------------------------------------------------
    def plan(self) -> list[tuple[Fragment, Expr | None]]:
        """Stats-based row-group pruning; returns (fragment, predicate) with
        the predicate dropped where stats prove every row matches."""
        out = []
        self.metrics.fragments_total = len(self.ds._fragments)
        for frag in self.ds._fragments:
            pred = self.predicate
            if pred is not None and frag.stats:
                verdict = pred.prune(frag.stats)
                if verdict == NONE:
                    self.metrics.fragments_pruned += 1
                    continue
                if verdict == ALL:
                    pred = None
            out.append((frag, pred))
        return out

    # -- execution ---------------------------------------------------------------
    def to_table(self) -> Table:
        plan = self.plan()
        store = self.ds.fs.store
        lock = threading.Lock()
        sems: dict[int, threading.Semaphore] = {}
        # static pushdown scans honour a bounded per-node queue depth.
        # The adaptive format is NOT throttled here: fragments it serves
        # from cache or routes client-side never touch the node, and its
        # storage-side calls are already capped per OSD by the store's own
        # concurrency limit (OSD._cls_sem)
        use_qd = isinstance(self.fmt, PushdownParquetFormat)

        def node_sem(frag: Fragment) -> threading.Semaphore | None:
            if not use_qd:
                return None
            name = self.ds.fs.object_names(frag.path)[frag.obj_idx]
            osd = store.primary_of(name)
            with lock:
                if osd.osd_id not in sems:
                    sems[osd.osd_id] = threading.Semaphore(self.queue_depth)
                return sems[osd.osd_id]

        def run(item):
            frag, pred = item
            sem = node_sem(frag)
            if sem is not None:
                sem.acquire()
            try:
                tbl, rec = self.fmt.scan_fragment(self.ds.fs, frag,
                                                  self.columns, pred)
            finally:
                if sem is not None:
                    sem.release()
            with lock:
                self.metrics.tasks.append(rec)
            return tbl

        t0 = time.perf_counter()
        if self.num_threads <= 1 or len(plan) <= 1:
            parts = [run(i) for i in plan]
        else:
            with ThreadPoolExecutor(max_workers=self.num_threads) as pool:
                parts = list(pool.map(run, plan))
        parts = [p for p in parts if len(p)]
        if parts:
            result = Table.concat(parts)
        else:
            names = self.columns or self.ds.schema.names
            sch = self.ds.schema.select(names)
            result = Table(sch, [
                Column(f, np.empty(0, object if f.type == "string"
                                   else f.numpy_dtype)) for f in sch])
        self.metrics.wall_s = time.perf_counter() - t0
        self.metrics.rows = len(result)
        return result

    def count_rows(self) -> int:
        """COUNT(*) with aggregate pushdown (the S3-Select-style extension
        of the paper's scan_op).

        Per fragment: stats prove ALL -> count from metadata with zero
        I/O; stats prove NONE -> pruned; otherwise ``rowcount_op`` runs on
        the storage node and only an integer crosses the wire.  Falls back
        to a materializing scan for the client-side format."""
        import json

        from repro.storage.cephfs import DirectObjectAccess

        if not isinstance(self.fmt, PushdownParquetFormat):
            return len(self.to_table())
        total = 0
        self.metrics.fragments_total = len(self.ds._fragments)
        doa = DirectObjectAccess(self.ds.fs)
        for frag in self.ds._fragments:
            pred = self.predicate
            if pred is None:
                total += frag.num_rows          # metadata-only count
                continue
            if frag.stats:
                verdict = pred.prune(frag.stats)
                if verdict == NONE:
                    self.metrics.fragments_pruned += 1
                    continue
                if verdict == ALL:
                    total += frag.num_rows      # metadata-only count
                    continue
            payload: dict = {
                "predicate": pred.to_json() if pred is not None else None,
                "row_groups": [frag.rg_in_object],
            }
            if frag.footer is not None:
                payload["footer"] = frag.footer.serialize()
            out, osd_id, el = doa.call(frag.path, frag.obj_idx,
                                       "rowcount_op", payload)
            n = json.loads(out)["rows"]
            self.metrics.tasks.append(TaskRecord(
                "osd", osd_id, el, len(out), 0.0, n))
            total += n
        self.metrics.rows = total
        return total
