from repro.aformat.aggregate import AggSpec
from repro.dataset.admission import (AdmissionController, AdmissionTimeout,
                                     LANES)
from repro.dataset.dataset import Dataset, Scanner, dataset
from repro.dataset.format import (AdaptiveFormat, FileFormat, ParquetFormat,
                                  PushdownParquetFormat, TaskRecord,
                                  resolve_format)
from repro.dataset.fragment import Fragment
from repro.dataset.plan import (Aggregate, Count, Filter, FragmentTask,
                                Join, JoinStrategy, Limit, PhysicalPlan,
                                PlanNode, Project, Query, Scan, ScanMetrics)
from repro.dataset.qos import (Shed, TaskContext, TenantRegistry,
                               TenantSpec, as_task_context)
from repro.dataset.scheduler import (ResultCache, ScanScheduler,
                                     modeled_latency)
from repro.dataset.snapshot import (CommitConflict, CompactionReport,
                                    Manifest, MutableDataset)

__all__ = ["AdmissionController", "AdmissionTimeout", "LANES", "AggSpec",
           "Dataset", "ScanMetrics",
           "Scanner", "dataset", "FileFormat", "ParquetFormat",
           "PushdownParquetFormat", "AdaptiveFormat", "TaskRecord",
           "Fragment", "ResultCache", "ScanScheduler", "modeled_latency",
           "Query", "PlanNode", "Scan", "Filter", "Project", "Aggregate",
           "Limit", "Count", "Join", "JoinStrategy", "FragmentTask",
           "PhysicalPlan",
           "resolve_format", "MutableDataset", "Manifest",
           "CommitConflict", "CompactionReport",
           "Shed", "TaskContext", "TenantRegistry", "TenantSpec",
           "as_task_context"]
