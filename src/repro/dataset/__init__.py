from repro.dataset.dataset import Dataset, ScanMetrics, Scanner, dataset
from repro.dataset.format import (FileFormat, ParquetFormat,
                                  PushdownParquetFormat, TaskRecord)
from repro.dataset.fragment import Fragment

__all__ = ["Dataset", "ScanMetrics", "Scanner", "dataset", "FileFormat",
           "ParquetFormat", "PushdownParquetFormat", "TaskRecord",
           "Fragment"]
