from repro.aformat.aggregate import AggSpec
from repro.dataset.admission import AdmissionController
from repro.dataset.dataset import Dataset, ScanMetrics, Scanner, dataset
from repro.dataset.format import (AdaptiveFormat, FileFormat, ParquetFormat,
                                  PushdownParquetFormat, TaskRecord)
from repro.dataset.fragment import Fragment
from repro.dataset.scheduler import (ResultCache, ScanScheduler,
                                     modeled_latency)

__all__ = ["AdmissionController", "AggSpec", "Dataset", "ScanMetrics",
           "Scanner", "dataset", "FileFormat", "ParquetFormat",
           "PushdownParquetFormat", "AdaptiveFormat", "TaskRecord",
           "Fragment", "ResultCache", "ScanScheduler", "modeled_latency"]
