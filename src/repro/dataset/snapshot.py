"""Mutable datasets: a snapshot log over the object store (MVCC for scans).

The write path so far was write-once (``write_striped/split/flat`` emit a
file exactly once); this module makes a dataset *evolve* while every
reader keeps exact, repeatable results:

manifest log
    A dataset prefix owns a HEAD object and one immutable manifest
    object per snapshot, all stored directly in the object store (never
    listed through the CephFS namespace — discovery reads manifests, it
    does not re-list the prefix).  A manifest names the data files
    (with their full footers embedded, so building a snapshot's
    fragments needs zero reads of the data files) and the delete
    tombstones.

optimistic commits
    ``append`` / ``delete`` / ``compact`` prepare their data out of
    line, then commit by compare-and-swap on the HEAD object
    (``ObjectStore.put_if_version`` — the existing per-object version
    counters are the commit token).  A lost race re-reads HEAD, rebases
    the manifest mutation, and retries; writers never block readers.

snapshot isolation
    ``as_of(snapshot_id)`` materializes one manifest into an immutable
    :class:`~repro.dataset.dataset.Dataset`; ``query()`` resolves HEAD
    once, so a running query (or a long ``to_batches`` stream) is pinned
    to the snapshot it started from no matter how many commits land
    under it.

deletes as tombstones
    ``delete(predicate)`` commits a tombstone; fragments from files
    older than the tombstone carry it (``Fragment.tombstone``) and the
    query optimizer conjoins ``NOT(tombstone)`` into their residual
    predicate — deleted rows never resurface at any placement, and
    stats pruning stays exact.  Compaction physically drops the rows
    and retires tombstones that no remaining file predates.

storage-side compaction (``compact_op``)
    Continuous ingest produces many small row groups — the
    fragmentation that dominates scan cost.  ``compact()`` picks victim
    files from the row-group size histogram, groups them by the OSD
    that holds them, and asks *that node* to merge them
    (``compact_op`` in ``storage/objclass.py``): decode, drop
    tombstoned rows, re-encode right-sized groups, regenerate stats,
    and write the new object back into the cluster — only the new
    file's footer metadata ever crosses the client wire.  The rewrite
    commits as a new snapshot; readers pinned to older snapshots keep
    their files until ``expire()`` garbage-collects them.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import secrets
from typing import Callable, Sequence

from repro.aformat import compression, parquet
from repro.aformat.expressions import Expr, Not, Or
from repro.aformat.schema import Schema
from repro.aformat.table import Table
from repro.dataset.dataset import Dataset
from repro.dataset.fragment import Fragment
from repro.dataset.qos import TaskContext, as_task_context
from repro.storage.cephfs import CephFS
from repro.storage.layouts import ALIGN, write_flat
from repro.storage.objstore import ObjectNotFound, VersionConflictError

HEAD_TAG = "snapmeta"


class CommitConflict(RuntimeError):
    """An optimistic commit kept losing the HEAD race (append/delete
    rebase automatically; compaction aborts when its victim set or the
    tombstone set changed underneath it — re-run ``compact()``)."""


def head_object(prefix: str) -> str:
    return f"{HEAD_TAG}!{prefix.rstrip('/')}!HEAD"


def log_object(prefix: str, snapshot_id: int) -> str:
    return f"{HEAD_TAG}!{prefix.rstrip('/')}!{snapshot_id:010d}"


def is_mutable(fs: CephFS, prefix: str) -> bool:
    """True if ``prefix`` carries a snapshot log (a reachable HEAD
    object) — the discovery hook ``repro.dataset.dataset.dataset``
    checks before falling back to prefix listing."""
    return fs.store.exists(head_object(prefix))


# ---------------------------------------------------------------------------
# Manifest model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DataFile:
    """One immutable data file of a snapshot, footer embedded so a
    snapshot materializes without touching the file's objects."""

    path: str
    rows: int
    added_at: int  # snapshot id that introduced the file
    stripe_unit: int
    footer: parquet.FileMeta

    def to_json(self) -> dict:
        return {
            "path": self.path,
            "rows": self.rows,
            "added_at": self.added_at,
            "stripe_unit": self.stripe_unit,
            "footer": self.footer.to_json(),
        }

    @staticmethod
    def from_json(d: dict) -> "DataFile":
        return DataFile(
            d["path"],
            d["rows"],
            d["added_at"],
            d["stripe_unit"],
            parquet.FileMeta.from_json(d["footer"]),
        )


@dataclasses.dataclass
class Tombstone:
    """A delete: rows matching ``predicate`` are gone from every file
    that existed when it committed (``added_at < at``)."""

    at: int  # snapshot id of the delete commit
    predicate: Expr

    def to_json(self) -> dict:
        return {"at": self.at, "predicate": self.predicate.to_json()}

    @staticmethod
    def from_json(d: dict) -> "Tombstone":
        return Tombstone(d["at"], Expr.from_json(d["predicate"]))


@dataclasses.dataclass
class Manifest:
    """One snapshot's complete state: files + tombstones (+ the dataset
    schema, pinned by the first append and kept even when every file is
    later deleted or compacted away)."""

    snapshot_id: int = 0
    parent: int = -1
    files: list[DataFile] = dataclasses.field(default_factory=list)
    tombstones: list[Tombstone] = dataclasses.field(default_factory=list)
    dataset_schema: "Schema | None" = None

    def serialize(self) -> bytes:
        return json.dumps(
            {
                "snapshot_id": self.snapshot_id,
                "parent": self.parent,
                "files": [f.to_json() for f in self.files],
                "tombstones": [t.to_json() for t in self.tombstones],
                "schema": self.dataset_schema.to_json()
                if self.dataset_schema is not None
                else None,
            }
        ).encode()

    @staticmethod
    def deserialize(raw: bytes) -> "Manifest":
        d = json.loads(raw)
        return Manifest(
            d["snapshot_id"],
            d["parent"],
            [DataFile.from_json(f) for f in d["files"]],
            [Tombstone.from_json(t) for t in d["tombstones"]],
            Schema.from_json(d["schema"])
            if d.get("schema") is not None
            else None,
        )

    @property
    def physical_rows(self) -> int:
        """Stored rows, before tombstone filtering."""
        return sum(f.rows for f in self.files)

    def schema(self):
        if self.dataset_schema is not None:
            return self.dataset_schema
        return self.files[0].footer.schema if self.files else None

    def tombstone_for(self, f: DataFile) -> Expr | None:
        """The combined delete predicate applicable to ``f`` (tombstones
        committed after the file was added)."""
        preds = [t.predicate for t in self.tombstones if f.added_at < t.at]
        if not preds:
            return None
        combined = preds[0]
        for p in preds[1:]:
            combined = Or(combined, p)
        return combined


@dataclasses.dataclass
class CompactionReport:
    """What one ``compact()`` run did, with the wire-cost split that is
    the whole point: ``request_bytes + reply_bytes`` is everything that
    crossed the client wire (payload JSON out, footer metadata back);
    ``rewritten_bytes`` moved OSD-to-OSD inside the cluster."""

    snapshot_id: int
    files_in: int = 0
    files_out: int = 0
    rows: int = 0
    groups: int = 0
    fallbacks: int = 0  # client-side rewrites (co-location race)
    request_bytes: int = 0
    reply_bytes: int = 0
    fallback_wire_bytes: int = 0  # raw bytes a client-side rewrite moved
    rewritten_bytes: int = 0  # new objects' bytes (cluster-internal)
    tombstones_dropped: int = 0
    #: Physical-design accounting: source vs rewritten row-group data
    #: bytes, and the encoding the (advisor-driven) rewrite chose per
    #: column — how much the re-encode actually saved.
    bytes_before: int = 0
    bytes_after: int = 0
    encodings: dict = dataclasses.field(default_factory=dict)

    @property
    def wire_bytes(self) -> int:
        return self.request_bytes + self.reply_bytes + \
            self.fallback_wire_bytes


# ---------------------------------------------------------------------------
# The mutable dataset
# ---------------------------------------------------------------------------


class MutableDataset:
    """Transactional append/delete/compact over one dataset prefix.

    All data files are flat ARW1 files (one object per file, every row
    group inside it) so each row group stays a self-contained pushdown
    fragment.  Readers go through :meth:`as_of` / :meth:`query`, which
    pin one snapshot for the lifetime of the query.
    """

    def __init__(self, fs: CephFS, prefix: str):
        self.fs = fs
        self.prefix = prefix.rstrip("/")
        self.commit_conflicts = 0  # lost CAS races (all verbs)

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def create(cls, fs: CephFS, prefix: str) -> "MutableDataset":
        """Initialize an empty snapshot log at ``prefix`` (snapshot 0)."""
        md = cls(fs, prefix)
        genesis = Manifest(snapshot_id=0, parent=-1)
        try:
            fs.store.put_if_version(
                head_object(md.prefix), genesis.serialize(), 0
            )
        except VersionConflictError:
            raise FileExistsError(
                f"mutable dataset already exists at {prefix!r}"
            ) from None
        fs.store.put(log_object(md.prefix, 0), genesis.serialize())
        return md

    @classmethod
    def open(cls, fs: CephFS, prefix: str) -> "MutableDataset":
        md = cls(fs, prefix)
        md._read_head()  # raises if absent
        return md

    # -- snapshot log ------------------------------------------------------
    def _read_head(self) -> tuple[Manifest, int]:
        """Current manifest + the HEAD object version (the CAS token).
        Version is read *before* content: a commit landing in between
        makes the CAS fail and retry, never commit over unseen state."""
        name = head_object(self.prefix)
        version = self.fs.store.version_of(name)
        if version == 0:
            raise FileNotFoundError(
                f"no mutable dataset at {self.prefix!r} "
                "(MutableDataset.create it first)"
            )
        raw = self.fs.store.get(name)
        return Manifest.deserialize(raw), version

    def _commit(
        self,
        mutate: Callable[[Manifest], Manifest],
        *,
        max_retries: int = 32,
    ) -> Manifest:
        """Optimistic commit loop: read HEAD @ v, rebase the mutation on
        it, CAS @ v.  ``mutate`` gets the current manifest and returns
        the successor (``snapshot_id`` must be ``head + 1``); it runs
        again from scratch on every retry, so it must be pure."""
        for _ in range(max_retries):
            head, version = self._read_head()
            new = mutate(head)
            if new.snapshot_id != head.snapshot_id + 1:
                raise ValueError(
                    "mutate() must advance snapshot_id by exactly one"
                )
            try:
                self.fs.store.put_if_version(
                    head_object(self.prefix), new.serialize(), version
                )
            except VersionConflictError:
                self.commit_conflicts += 1
                continue
            self.fs.store.put(
                log_object(self.prefix, new.snapshot_id), new.serialize()
            )
            return new
        raise CommitConflict(
            f"commit on {self.prefix!r} lost {max_retries} CAS races"
        )

    def snapshot(self) -> int:
        """Current HEAD snapshot id."""
        return self._read_head()[0].snapshot_id

    @property
    def schema(self):
        return self._read_head()[0].schema()

    # -- writes ------------------------------------------------------------
    def append(
        self,
        table: Table,
        *,
        row_group_rows: int = 65536,
        codec: str = compression.ZLIB,
    ) -> int:
        """Commit ``table`` as a new data file; returns the snapshot id.
        The file is written before the commit, so a lost CAS race only
        retries the (tiny) manifest swap, never the data write.  A
        commit that fails outright (schema mismatch, exhausted retries)
        unlinks the file again — an uncommitted file is referenced by no
        manifest, so nothing else could ever reclaim it."""
        if len(table) == 0:
            raise ValueError("append() of an empty table")
        path = f"{self.prefix}/data/a{secrets.token_hex(6)}.arw"
        meta = write_flat(
            self.fs, path, table, row_group_rows=row_group_rows,
            codec=codec,
        )
        su = self.fs.stat(path).stripe_unit

        def mutate(head: Manifest) -> Manifest:
            self._check_schema(head, meta.schema)
            sid = head.snapshot_id + 1
            return Manifest(
                sid,
                head.snapshot_id,
                head.files + [DataFile(path, len(table), sid, su, meta)],
                list(head.tombstones),
                head.schema() or meta.schema,
            )

        try:
            return self._commit(mutate).snapshot_id
        except Exception:
            self.fs.unlink(path)
            raise

    def delete(self, predicate: Expr) -> int:
        """Commit a tombstone: rows matching ``predicate`` disappear
        from every snapshot >= the returned id (logical delete; bytes
        are reclaimed by ``compact()`` + ``expire()``)."""
        if not isinstance(predicate, Expr):
            raise TypeError("delete() takes an Expr predicate")

        def mutate(head: Manifest) -> Manifest:
            schema = head.schema()
            if schema is not None:
                for col in sorted(predicate.columns()):
                    schema.field(col)  # raises on unknown column
            sid = head.snapshot_id + 1
            return Manifest(
                sid,
                head.snapshot_id,
                list(head.files),
                head.tombstones + [Tombstone(sid, predicate)],
                head.schema(),
            )

        return self._commit(mutate).snapshot_id

    def _check_schema(self, head: Manifest, schema) -> None:
        current = head.schema()
        if current is not None and current != schema:
            raise ValueError(
                f"append() schema mismatch: dataset has "
                f"{[f.name for f in current]}, append has "
                f"{[f.name for f in schema]}"
            )

    # -- reads -------------------------------------------------------------
    def as_of(self, snapshot_id: int | None = None) -> Dataset:
        """Materialize one snapshot as an immutable Dataset (fragments
        built purely from the manifest's embedded footers — no data-file
        reads).  ``None`` = current HEAD."""
        head, _ = self._read_head()
        if snapshot_id is None or snapshot_id == head.snapshot_id:
            manifest = head
        else:
            try:
                raw = self.fs.store.get(
                    log_object(self.prefix, snapshot_id)
                )
            except (KeyError, ObjectNotFound):
                raise KeyError(
                    f"snapshot {snapshot_id} of {self.prefix!r} is "
                    "unknown or expired"
                ) from None
            manifest = Manifest.deserialize(raw)
        return self._materialize(manifest)

    def _materialize(self, manifest: Manifest) -> Dataset:
        frags: list[Fragment] = []
        schema = manifest.schema()
        for f in manifest.files:
            meta = f.footer
            tomb = manifest.tombstone_for(f)
            for i, rg in enumerate(meta.row_groups):
                obj_idx = rg.offset // f.stripe_unit
                end_obj = (rg.offset + rg.total_bytes - 1) // f.stripe_unit
                if obj_idx != end_obj:
                    raise ValueError(
                        f"{f.path}: row group {i} spans objects — the "
                        "manifest references a non-self-contained file"
                    )
                frags.append(
                    Fragment(
                        f.path,
                        obj_idx,
                        i,
                        rg.num_rows,
                        stats=rg.column_stats(meta.schema),
                        footer=None,
                        client_meta=meta,
                        client_rg_index=i,
                        tombstone=tomb,
                    )
                )
        ds = Dataset(
            self.fs,
            schema,
            frags,
            layout="mutable",
            discovery_bytes=len(manifest.serialize()),
        )
        ds.snapshot_id = manifest.snapshot_id
        return ds

    def query(self, **kwargs):
        """A lazy query pinned to the snapshot current *now*: commits
        landing while it plans or streams are invisible to it."""
        return self.as_of().query(**kwargs)

    def scanner(self, **kwargs):
        """Eager Scanner over a pinned snapshot (see :meth:`query`)."""
        return self.as_of().scanner(**kwargs)

    # -- compaction --------------------------------------------------------
    def compact(
        self,
        *,
        target_rows: int = 65536,
        min_fill: float = 0.5,
        codec: str = compression.ZLIB,
        client_fallback: bool = True,
        tenant=None,
        advisor: bool = True,
    ) -> CompactionReport:
        """Merge small row groups into right-sized ones, storage-side.

        ``advisor=True`` (the default) makes the rewrite the measured
        encoding advisor's customer: each column re-encodes into the
        cheapest candidate ``repro.aformat.advisor`` finds, and the
        report carries ``bytes_before``/``bytes_after``/``encodings``
        so the savings are observable.  ``advisor=False`` keeps the
        one-shot ``choose_encoding`` heuristic.

        Victims come from the row-group size histogram: files whose mean
        row group is under ``min_fill * target_rows`` rows, plus any
        file with an applicable tombstone (rewriting drops the deleted
        rows physically).  Victims are grouped by the OSD that will run
        ``compact_op`` (the first up holder — the same replica
        ``cls_call`` picks), so every merge happens between co-located
        objects with no data movement to the client; the node ships back
        only the new file's footer.  The rewrite commits as one new
        snapshot; old files stay on disk for snapshot readers until
        :meth:`expire`.

        If the cluster changed between planning and execution (an OSD
        died, a replica moved) a group can stop being co-located;
        ``client_fallback=True`` rewrites those groups through the
        client (bytes over the wire, counted in the report), otherwise
        they are skipped this run.

        Compaction is a first-class *background* tenant: by default
        every ``compact_op`` runs as tenant ``"compaction"`` on the
        ``background`` lane, and when ``tenant`` carries a
        :class:`~repro.dataset.qos.TenantRegistry` context its calls go
        through the cluster's shared admission controller — maintenance
        waits behind every foreground scan instead of hitting OSDs
        ungated."""
        if tenant is None:
            ctx = TaskContext(tenant="compaction", lane="background")
        else:
            ctx = as_task_context(tenant)
        if ctx.admission is None and ctx.registry is not None:
            ctx = dataclasses.replace(
                ctx, admission=ctx.registry.controller(self.fs.store))
        head, _ = self._read_head()
        report = CompactionReport(snapshot_id=head.snapshot_id)
        groups = self._plan_groups(head, target_rows, min_fill)
        if not groups:
            return report

        retired: set[str] = set()
        new_files: list[DataFile] = []
        for osd_id, group in groups:
            report.groups += 1
            ok, df = self._compact_group(
                head, osd_id, group, target_rows, codec, client_fallback,
                report, ctx, advisor,
            )
            if not ok:
                continue  # co-location race, no fallback: victims stay
            retired |= {f.path for f in group}
            if df is not None:  # None = every row was tombstoned away
                new_files.append(df)
        if not retired:
            return report
        planned_tombs = [t.to_json() for t in head.tombstones]

        def mutate(cur: Manifest) -> Manifest:
            live = {f.path for f in cur.files}
            if not retired <= live:
                raise CommitConflict(
                    "compaction victims changed under us (concurrent "
                    "compact?) — re-run compact()"
                )
            if [t.to_json() for t in cur.tombstones] != planned_tombs:
                raise CommitConflict(
                    "tombstones changed during compaction — re-run "
                    "compact() so the rewrite sees the new deletes"
                )
            sid = cur.snapshot_id + 1
            files = [f for f in cur.files if f.path not in retired]
            for df in new_files:
                files.append(dataclasses.replace(df, added_at=sid))
            tombs = [
                t
                for t in cur.tombstones
                if any(f.added_at < t.at for f in files)
            ]
            report.tombstones_dropped = len(cur.tombstones) - len(tombs)
            return Manifest(sid, cur.snapshot_id, files, tombs,
                            cur.schema())

        try:
            new = self._commit(mutate)
        except CommitConflict:
            # the rewrite is orphaned, not committed: drop its files so
            # they cannot leak storage, then surface the conflict
            for df in new_files:
                if self.fs.exists(df.path):
                    self.fs.unlink(df.path)
            raise
        report.snapshot_id = new.snapshot_id
        report.files_in = len(retired)
        report.files_out = len(new_files)
        report.rows = sum(df.rows for df in new_files)
        return report

    def _plan_groups(
        self, head: Manifest, target_rows: int, min_fill: float
    ) -> list[tuple[int, list[DataFile]]]:
        """Victim selection + co-location grouping.

        Victims (row-group size histogram: mean group under the fill
        threshold, or any applicable tombstone) are binned onto OSDs
        greedily over their *replica sets* — every object has
        ``replication`` candidate holders, so preferring the candidate
        whose bin is already largest packs far more victims per
        ``compact_op`` call than naive primary-only grouping.  Returns
        (executing osd id, files) groups."""
        threshold = min_fill * target_rows
        victims: list[DataFile] = []
        for f in head.files:
            rg_rows = [rg.num_rows for rg in f.footer.row_groups]
            small = sum(rg_rows) / len(rg_rows) < threshold
            if small or head.tombstone_for(f) is not None:
                victims.append(f)
        bins: dict[int, list[DataFile]] = {}
        for f in victims:
            holders = self._holders(f)
            if not holders:
                continue  # every replica down: nothing to do this run
            osd_id = max(
                holders, key=lambda o: (len(bins.get(o, ())), -o)
            )
            bins.setdefault(osd_id, []).append(f)
        groups = []
        for osd_id, files in sorted(bins.items()):
            multi_rg = any(len(f.footer.row_groups) > 1 for f in files)
            tombed = any(head.tombstone_for(f) is not None for f in files)
            if len(files) >= 2 or tombed or multi_rg:
                groups.append((osd_id, files))
        return groups

    def _object_of(self, f: DataFile) -> str:
        return self.fs.object_names(f.path)[0]

    def _holders(self, f: DataFile) -> list[int]:
        """Up OSDs holding this file's object (compact_op candidates)."""
        name = self._object_of(f)
        return [
            osd.osd_id
            for osd in self.fs.store.acting_set(name)
            if not osd.down and osd.contains(name)
        ]

    def _compact_group(
        self,
        head: Manifest,
        osd_id: int,
        group: Sequence[DataFile],
        target_rows: int,
        codec: str,
        client_fallback: bool,
        report: CompactionReport,
        ctx: TaskContext,
        advisor: bool = True,
    ) -> tuple[bool, DataFile | None]:
        """Rewrite one co-located victim group.  Returns (ok, file):
        ``(True, DataFile)`` on a successful rewrite, ``(True, None)``
        when every row was tombstoned away (victims retire with no
        successor), ``(False, None)`` when the group could not be
        rewritten (co-location race without a client fallback)."""
        path = f"{self.prefix}/data/c{secrets.token_hex(6)}.arw"
        ino_num = self.fs.reserve_ino()
        target = f"{ino_num:x}.{0:08x}"
        sources = []
        for f in group:
            tomb = head.tombstone_for(f)
            sources.append(
                {
                    "name": self._object_of(f),
                    "keep": Not(tomb).to_json() if tomb is not None
                    else None,
                }
            )
        payload = {
            "sources": sources,
            "target": target,
            "row_group_rows": target_rows,
            "codec": codec,
            "advise": advisor,
        }
        report.request_bytes += len(json.dumps(payload).encode())
        gate = (ctx.admission.admit(osd_id, ctx)
                if ctx.admission is not None else contextlib.nullcontext())
        with gate:
            raw, _osd_id, _el = self.fs.store.cls_call(
                sources[0]["name"], "compact_op", payload,
                prefer_osd=self.fs.store.osds[osd_id],
                tenant=ctx.tenant, lane=ctx.lane,
            )
        report.reply_bytes += len(raw)
        reply = json.loads(raw)
        if not reply.get("ok"):
            if not client_fallback:
                return False, None
            return True, self._compact_client(
                head, group, path, target_rows, codec, report, advisor
            )
        report.bytes_before += reply.get("bytes_before", 0)
        for col, enc in reply.get("encodings", {}).items():
            report.encodings[col] = enc
        if reply["rows"] == 0:
            return True, None
        size = reply["size"]
        su = max(ALIGN, -(-size // ALIGN) * ALIGN)
        self.fs.register_file(
            path, ino_num, size, su,
            xattrs={"layout": "flat", "compacted_from": len(group)},
        )
        report.rewritten_bytes += size
        footer = parquet.FileMeta.from_json(reply["footer"])
        report.bytes_after += sum(
            rg.total_bytes for rg in footer.row_groups
        )
        return True, DataFile(path, reply["rows"], 0, su, footer)

    def _compact_client(
        self,
        head: Manifest,
        group: Sequence[DataFile],
        path: str,
        target_rows: int,
        codec: str,
        report: CompactionReport,
        advisor: bool = True,
    ) -> DataFile | None:
        """Client-side rewrite fallback: the same merge, but the raw
        bytes round-trip through the client (read data + write new
        file) — the cost ``compact_op`` exists to avoid, kept for
        co-location races and as the benchmark's comparison arm."""
        report.fallbacks += 1
        parts = []
        for f in group:
            data = self.fs.read_file(f.path)
            report.fallback_wire_bytes += len(data)
            src = parquet.BytesSource(data)
            tomb = head.tombstone_for(f)
            keep = Not(tomb) if tomb is not None else None
            for rg in f.footer.row_groups:
                report.bytes_before += rg.total_bytes
                parts.append(
                    parquet.scan_row_group(src, f.footer, rg, None, keep)
                )
        merged = Table.concat(parts) if parts else None
        if merged is None or len(merged) == 0:
            return None
        meta = write_flat(
            self.fs, path, merged, row_group_rows=target_rows,
            codec=codec, advise=advisor,
        )
        report.bytes_after += sum(
            rg.total_bytes for rg in meta.row_groups
        )
        for f_, c in zip(meta.schema, meta.row_groups[0].chunks):
            report.encodings[f_.name] = c.encoding
        ino = self.fs.stat(path)
        report.fallback_wire_bytes += ino.size
        report.rewritten_bytes += ino.size
        return DataFile(path, len(merged), 0, ino.stripe_unit, meta)

    # -- garbage collection ------------------------------------------------
    def expire(self, retain_from: int | None = None) -> list[str]:
        """Physically remove data files unreachable from every snapshot
        >= ``retain_from`` (default: HEAD only) and drop the expired
        manifest log objects.  Readers pinned to older snapshots lose
        them — call only once those readers are done.  Unlinking bumps
        the deleted objects' versions, so any result-cache entry derived
        from them can never be served again."""
        head, _ = self._read_head()
        if retain_from is None:
            retain_from = head.snapshot_id
        retain_from = min(retain_from, head.snapshot_id)
        keep: set[str] = {f.path for f in head.files}
        all_paths: set[str] = set(keep)
        for sid in range(0, head.snapshot_id + 1):
            try:
                raw = self.fs.store.get(log_object(self.prefix, sid))
            except (KeyError, ObjectNotFound):
                continue
            manifest = Manifest.deserialize(raw)
            paths = {f.path for f in manifest.files}
            all_paths |= paths
            if sid >= retain_from:
                keep |= paths
            else:
                self.fs.store.delete(log_object(self.prefix, sid))
        removed = []
        for path in sorted(all_paths - keep):
            if self.fs.exists(path):
                self.fs.unlink(path)
                removed.append(path)
        return removed
