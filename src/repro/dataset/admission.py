"""Per-OSD admission control for fragment scans — one policy, all formats.

Every placement ultimately lands fragment work on the storage node that
holds the object: a pushdown scan burns the node's CPU in ``scan_op``, a
client-side scan pulls the raw column bytes off the same node, and the
adaptive scheduler does one or the other per fragment.  The admission
controller bounds how many fragment operations a single scan keeps
outstanding against any one OSD (``slots_per_osd``, the Scanner's
``queue_depth``), so a wide scan cannot bury one node in queued work
while its replicas idle — regardless of which format issued the work.

This replaces the old ``PushdownParquetFormat``-only semaphore special
case inside ``Scanner.to_table``: the controller is created per scan and
threaded through ``FileFormat.scan_fragment(..., admission=)``, so the
throttle lives where the storage interaction actually happens (a cache
hit in the adaptive format, for instance, never takes a slot).
"""

from __future__ import annotations

import contextlib
import threading

from repro.storage.objstore import ObjectStore


class AdmissionController:
    """Bounded per-OSD in-flight slots shared by every placement.

    ``admit(osd_id)`` is a context manager holding one slot on that node
    for the duration of the fragment operation.  ``waits`` counts the
    acquisitions that actually blocked — the backpressure signal surfaced
    in scan metrics.
    """

    def __init__(self, store: ObjectStore, slots_per_osd: int = 4):
        self.store = store
        self.slots_per_osd = max(1, slots_per_osd)
        self._sems: dict[int, threading.Semaphore] = {}
        self._lock = threading.Lock()
        self.admitted = 0
        self.waits = 0

    def _sem(self, osd_id: int) -> threading.Semaphore:
        with self._lock:
            sem = self._sems.get(osd_id)
            if sem is None:
                sem = threading.Semaphore(self.slots_per_osd)
                self._sems[osd_id] = sem
            return sem

    @contextlib.contextmanager
    def admit(self, osd_id: int):
        sem = self._sem(osd_id)
        if not sem.acquire(blocking=False):
            with self._lock:
                self.waits += 1
            sem.acquire()
        with self._lock:
            self.admitted += 1
        try:
            yield
        finally:
            sem.release()

    @contextlib.contextmanager
    def admit_object(self, name: str):
        """Admit against the node a fragment operation will land on: the
        first up replica holding the object (the same choice ``get`` and
        ``cls_call`` make)."""
        target = next((o for o in self.store.acting_set(name)
                       if not o.down and o.contains(name)), None)
        if target is None:           # failover path decides; don't gate
            yield
            return
        with self.admit(target.osd_id):
            yield

    def stats(self) -> dict:
        return {"slots_per_osd": self.slots_per_osd,
                "admitted": self.admitted, "waits": self.waits}
