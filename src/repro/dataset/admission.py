"""Multi-tenant admission control: weighted-fair per-OSD slots with
priority lanes, preemption, and deadline-aware waiting.

Every placement ultimately lands fragment work on the storage node that
holds the object: a pushdown scan burns the node's CPU in ``scan_op``, a
client-side scan pulls the raw column bytes off the same node, and the
adaptive scheduler does one or the other per fragment.  The admission
controller bounds how many fragment operations stay outstanding against
any one OSD (``slots_per_osd``) — and, when several tenants share the
controller (a :class:`~repro.dataset.qos.TenantRegistry` hands one per
cluster), it decides *whose* work gets the next slot:

priority lanes
    ``interactive`` > ``bulk`` > ``background``.  A free slot never goes
    to a lane while a higher lane is waiting, and an interactive arrival
    may (a) jump a queue of lower-lane waiters and (b) oversubscribe the
    node by up to ``preempt_slack`` extra slots — both are counted as
    ``preemptions``, the signal that the lane actually displaced someone.
    ``compact_op`` traffic rides the ``background`` lane (see
    ``MutableDataset.compact``), so maintenance can never starve a scan.

weighted fairness
    Within a lane, the next slot goes to the waiting tenant with the
    lowest ``inflight / weight`` share on that OSD (FIFO between equal
    shares), so under saturation the slot split converges to the
    registered weights.

deadline-aware waiting
    A waiter whose :class:`~repro.dataset.qos.TaskContext` deadline
    expires while queued is removed and raises :class:`AdmissionTimeout`;
    the streaming executor converts it into a typed ``Shed`` result —
    the query is rejected *at the queue*, before burning storage CPU it
    can no longer use in time.

Every acquisition records its wall ``wait_s`` (not just a blocked/not
counter): queue *time* is the latency signal deadline shedding and the
multi-tenant benchmark's p99 claims are built on.  ``admit(osd_id)``
without a context keeps the legacy single-tenant behavior (default
tenant, ``bulk`` lane, weight 1) byte-for-byte.
"""

from __future__ import annotations

import contextlib
import threading
import time

from repro.storage.objstore import ObjectStore

#: Priority lanes, highest priority first.
LANES = ("interactive", "bulk", "background")
LANE_PRIORITY = {name: rank for rank, name in enumerate(LANES)}
DEFAULT_LANE = "bulk"


class AdmissionTimeout(Exception):
    """A waiter's deadline expired while queued for an OSD slot.  The
    executor catches this and surfaces a typed ``Shed`` result; it never
    escapes to user code."""

    def __init__(self, osd_id: int, tenant: str, waited_s: float):
        super().__init__(
            f"tenant {tenant!r} deadline expired after waiting "
            f"{waited_s * 1e3:.1f}ms for a slot on osd.{osd_id}")
        self.osd_id = osd_id
        self.tenant = tenant
        self.waited_s = waited_s


class _Waiter:
    __slots__ = ("tenant", "rank", "weight", "seq", "granted", "preempting")

    def __init__(self, tenant: str, rank: int, weight: float, seq: int):
        self.tenant = tenant
        self.rank = rank
        self.weight = weight
        self.seq = seq
        self.granted = False
        self.preempting = False


class _OsdSlots:
    """Slot state for one OSD: a condition variable, per-tenant in-flight
    counts, and the waiter queue the grant policy picks from."""

    def __init__(self, slots: int, slack: int):
        self.slots = slots
        self.slack = slack
        self.cond = threading.Condition()
        self.inflight = 0
        self.by_tenant: dict[str, int] = {}
        self.waiters: list[_Waiter] = []
        self._seq = 0

    def _take(self, tenant: str):
        self.inflight += 1
        self.by_tenant[tenant] = self.by_tenant.get(tenant, 0) + 1

    def _pick(self) -> _Waiter:
        """Highest lane first; within the lane, the tenant with the
        smallest weighted share of this OSD's slots; FIFO between equal
        shares."""
        return min(self.waiters, key=lambda w: (
            w.rank, self.by_tenant.get(w.tenant, 0) / w.weight, w.seq))

    def _pump(self):
        granted = False
        while self.waiters and self.inflight < self.slots:
            w = self._pick()
            self.waiters.remove(w)
            w.granted = True
            self._take(w.tenant)
            granted = True
        if granted:
            self.cond.notify_all()

    def acquire(self, tenant: str, rank: int, weight: float,
                remaining_s) -> tuple[bool, bool, float]:
        """Block until granted; returns (waited, preempted, wait_s).
        ``remaining_s`` is a 0-arg callable giving the seconds left on
        the caller's deadline (or None for no deadline)."""
        t0 = time.perf_counter()
        with self.cond:
            if self.inflight < self.slots and not self.waiters:
                self._take(tenant)
                return False, False, 0.0
            if (rank == 0 and self.inflight < self.slots + self.slack
                    and not any(w.rank == 0 for w in self.waiters)):
                # interactive preemption: jump the lower-lane queue and,
                # when the node is full, oversubscribe into the slack
                self._take(tenant)
                return False, True, 0.0
            self._seq += 1
            w = _Waiter(tenant, rank, weight, self._seq)
            self.waiters.append(w)
            self._pump()          # a slot may have freed since the check
            while not w.granted:
                timeout = remaining_s()
                if timeout is not None and timeout <= 0:
                    self.waiters.remove(w)
                    raise AdmissionTimeout(-1, tenant,
                                           time.perf_counter() - t0)
                self.cond.wait(timeout)
            return True, w.preempting, time.perf_counter() - t0

    def release(self, tenant: str):
        with self.cond:
            self.inflight -= 1
            n = self.by_tenant.get(tenant, 0) - 1
            if n > 0:
                self.by_tenant[tenant] = n
            else:
                self.by_tenant.pop(tenant, None)
            self._pump()


class _NoDeadline:
    __slots__ = ()

    def __call__(self):
        return None


_NO_DEADLINE = _NoDeadline()


class AdmissionController:
    """Weighted-fair, lane-prioritized per-OSD in-flight slots shared by
    every placement (see the module docstring for the policy).

    ``admit(osd_id, ctx)`` is a context manager holding one slot on that
    node for the duration of the fragment operation; ``ctx`` is a
    :class:`~repro.dataset.qos.TaskContext` (or None for the legacy
    single-tenant behavior).  ``waits`` counts acquisitions that blocked,
    ``wait_s`` their summed queue time — the backpressure signals
    surfaced in scan metrics.
    """

    def __init__(self, store: ObjectStore, slots_per_osd: int = 4, *,
                 preempt_slack: int = 1):
        self.store = store
        self.slots_per_osd = max(1, slots_per_osd)
        self.preempt_slack = max(0, preempt_slack)
        self._slots: dict[int, _OsdSlots] = {}
        self._lock = threading.Lock()
        self.admitted = 0
        self.waits = 0
        self.wait_s = 0.0
        self.preemptions = 0
        self.sheds = 0
        self._by_tenant: dict[str, dict] = {}

    def _osd(self, osd_id: int) -> _OsdSlots:
        with self._lock:
            st = self._slots.get(osd_id)
            if st is None:
                st = _OsdSlots(self.slots_per_osd, self.preempt_slack)
                self._slots[osd_id] = st
            return st

    def _tenant_stats(self, tenant: str) -> dict:
        st = self._by_tenant.get(tenant)
        if st is None:
            st = {"admitted": 0, "waits": 0, "wait_s": 0.0,
                  "preemptions": 0, "sheds": 0}
            self._by_tenant[tenant] = st
        return st

    @contextlib.contextmanager
    def admit(self, osd_id: int, ctx=None):
        tenant = "default" if ctx is None else ctx.tenant
        rank = LANE_PRIORITY[DEFAULT_LANE] if ctx is None else \
            LANE_PRIORITY.get(ctx.lane, LANE_PRIORITY[DEFAULT_LANE])
        weight = 1.0 if ctx is None else max(ctx.weight, 1e-9)
        remaining = _NO_DEADLINE
        if ctx is not None and ctx.deadline_s is not None:
            remaining = ctx.remaining_s
        st = self._osd(osd_id)
        try:
            waited, preempted, wait_s = st.acquire(tenant, rank, weight,
                                                   remaining)
        except AdmissionTimeout as e:
            e.osd_id = osd_id
            with self._lock:
                self.sheds += 1
                self.waits += 1
                self.wait_s += e.waited_s
                ts = self._tenant_stats(tenant)
                ts["sheds"] += 1
                ts["waits"] += 1
                ts["wait_s"] += e.waited_s
            raise
        with self._lock:
            self.admitted += 1
            self.waits += 1 if waited else 0
            self.wait_s += wait_s
            self.preemptions += 1 if preempted else 0
            ts = self._tenant_stats(tenant)
            ts["admitted"] += 1
            ts["waits"] += 1 if waited else 0
            ts["wait_s"] += wait_s
            ts["preemptions"] += 1 if preempted else 0
        try:
            yield
        finally:
            st.release(tenant)

    @contextlib.contextmanager
    def admit_object(self, name: str, ctx=None):
        """Admit against the node a fragment operation will land on: the
        first up replica holding the object (the same choice ``get`` and
        ``cls_call`` make)."""
        target = next((o for o in self.store.acting_set(name)
                       if not o.down and o.contains(name)), None)
        if target is None:           # failover path decides; don't gate
            yield
            return
        with self.admit(target.osd_id, ctx):
            yield

    def stats(self) -> dict:
        with self._lock:
            return {"slots_per_osd": self.slots_per_osd,
                    "admitted": self.admitted, "waits": self.waits,
                    "wait_s": round(self.wait_s, 6),
                    "preemptions": self.preemptions, "sheds": self.sheds,
                    "by_tenant": {t: dict(s)
                                  for t, s in self._by_tenant.items()}}
