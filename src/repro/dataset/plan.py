"""Lazy query plans: one logical IR, one optimizer, one executor.

Every Scanner verb used to carry its own prune/fan-out body, so each new
optimization had to be written three times (``to_table``, ``aggregate``,
``count_rows``).  This module replaces those verb-private paths with a
declarative pipeline:

builder (``Dataset.query()``)
    ``ds.query().select(cols).filter(pred).limit(n)`` /
    ``.aggregate(aggs, group_by=...)`` / ``.count()`` construct a small
    logical-plan IR (Scan / Filter / Project / Aggregate / Limit nodes,
    plus Count sugar) without touching storage.

optimizer (``lower``)
    Named passes rewrite the logical plan and lower it to per-fragment
    physical tasks: ``rewrite_count`` (COUNT(*) is the degenerate
    ungrouped aggregate), ``pushdown_projection`` (decode only referenced
    columns), ``prune_fragments`` (footer-stats pruning; ALL-verdicts
    drop the residual predicate), ``rewrite_metadata_aggregate``
    (aggregates provable from footer stats never touch storage), and
    ``pushdown_limit`` (a row budget truncates the task list at plan time
    and rides into ``scan_op`` so storage nodes stop decoding early).

executor (``execute_scan`` / ``execute_aggregate``)
    One shared streaming engine (the backpressured, admission-bounded
    engine from the streaming-scan PR) runs the physical tasks for every
    verb and every placement via ``FileFormat.execute_task``.  A limit is
    a live row budget: once met, no further fragments are issued and
    still-queued work is cancelled.

``Query.explain()`` renders the logical plan, the optimizer's decisions,
and the per-fragment physical tasks with their placement/cache/hedge
state — the debugging and benchmarking surface for all of the above.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from itertools import islice
from typing import Any, Iterator, Sequence

import numpy as np

from repro.aformat.aggregate import (
    AggSpec,
    AggState,
    DEFAULT_MAX_GROUPS,
    needed_columns,
    parse_aggs,
    partial_from_stats,
)
from repro.aformat.expressions import (ALL, And, BloomIn, Cmp, Expr, IsIn,
                                       NONE, Not, Or)
from repro.aformat.schema import Field, Schema
from repro.aformat.table import Column, Table
from repro.dataset.admission import AdmissionController, AdmissionTimeout
from repro.dataset.format import TaskRecord, resolve_format
from repro.dataset.fragment import Fragment
from repro.dataset.qos import Shed, TaskContext, as_task_context

#: Distinct build-key cardinality at or below which the semi-join pass
#: pushes an exact IN-list into the probe scan; above it, a bloom filter
#: (approximate on the wire, re-verified at the client hash probe).
IN_LIST_MAX = 256

# ---------------------------------------------------------------------------
# Logical plan IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlanNode:
    """Base logical-plan node.  The tree is linear (each node has one
    input); ``Scan`` is the leaf."""

    def children(self) -> list["PlanNode"]:
        return []


@dataclasses.dataclass
class Scan(PlanNode):
    """Leaf: read a Dataset's fragments.  ``columns`` is filled in by the
    projection-pushdown pass (None = every column)."""

    dataset: Any
    columns: tuple[str, ...] | None = None


@dataclasses.dataclass
class Filter(PlanNode):
    input: PlanNode
    predicate: Expr

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Project(PlanNode):
    input: PlanNode
    columns: tuple[str, ...]

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Aggregate(PlanNode):
    input: PlanNode
    specs: tuple[AggSpec, ...]
    group_by: str | None = None
    max_groups: int = DEFAULT_MAX_GROUPS

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Limit(PlanNode):
    input: PlanNode
    n: int

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Count(PlanNode):
    """Builder sugar for ``.count()``; the ``rewrite_count`` pass lowers
    it to the degenerate ungrouped COUNT(*) Aggregate."""

    input: PlanNode

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Join(PlanNode):
    """Hash join: ``input`` is the probe side (streamed), ``build_query``
    a whole separate Query whose result is hashed on ``on_right``.  The
    join lowers per side — the build side runs first, then the semi-join
    pass turns its keys into an IN-list or bloom filter conjoined into
    the probe scan so OSDs drop non-matching rows before IPC."""

    input: PlanNode
    build_query: Any  # Query (may scan a different Dataset)
    on_left: str
    on_right: str
    how: str = "inner"  # "inner" | "left" | "semi"

    def children(self):
        return [self.input]


def render_expr(e: Expr | None) -> str:
    if e is None:
        return "true"
    if isinstance(e, Cmp):
        return f"{e.column} {e.op} {e.value!r}"
    if isinstance(e, And):
        return f"({render_expr(e.lhs)} & {render_expr(e.rhs)})"
    if isinstance(e, Or):
        return f"({render_expr(e.lhs)} | {render_expr(e.rhs)})"
    if isinstance(e, Not):
        return f"~({render_expr(e.expr)})"
    if isinstance(e, IsIn):
        if len(e.values) > 8:
            return f"{e.column} in <{len(e.values)}-key list>"
        return f"{e.column} in {e.values!r}"
    if isinstance(e, BloomIn):
        return (
            f"{e.column} in bloom({e.count} keys, {e.num_bits} bits, "
            f"digest={e.digest()})"
        )
    return repr(e)


def render_plan(root: PlanNode) -> list[str]:
    """Indented one-node-per-line rendering of a logical plan.  Join
    nodes branch: the probe subtree renders inline, the build side under
    an indented ``build:`` header."""

    def label(n: PlanNode) -> str:
        if isinstance(n, Scan):
            ds = n.dataset
            cols = "*" if n.columns is None else ", ".join(n.columns)
            return (
                f"Scan[{ds.layout}, fragments={len(ds._fragments)}, "
                f"rows={ds.num_rows}, columns={cols}]"
            )
        if isinstance(n, Filter):
            return f"Filter[{render_expr(n.predicate)}]"
        if isinstance(n, Project):
            return f"Project[{', '.join(n.columns)}]"
        if isinstance(n, Aggregate):
            aggs = ", ".join(s.name for s in n.specs)
            by = f", group_by={n.group_by}" if n.group_by else ""
            return f"Aggregate[{aggs}{by}]"
        if isinstance(n, Limit):
            return f"Limit[n={n.n}]"
        if isinstance(n, Count):
            return "Count[]"
        if isinstance(n, Join):
            return f"Join[{n.how}, {n.on_left} = {n.on_right}]"
        return type(n).__name__

    lines: list[str] = []

    def walk(node: PlanNode | None, depth: int):
        while node is not None:
            lines.append("  " * depth + label(node))
            if isinstance(node, Join):
                walk(node.input, depth + 1)
                lines.append("  " * (depth + 1) + "build:")
                walk(node.build_query._root, depth + 2)
                return
            kids = node.children()
            node = kids[0] if kids else None
            depth += 1

    walk(root, 0)
    return lines


# ---------------------------------------------------------------------------
# Optimizer passes (logical -> logical, then logical -> physical)
# ---------------------------------------------------------------------------


def rewrite_count(root: PlanNode) -> PlanNode:
    """COUNT(*) is the degenerate ungrouped aggregate: rewrite the Count
    sugar node so one aggregation path serves both verbs (and the
    metadata / ``rowcount_op`` fast paths apply automatically)."""
    if isinstance(root, Count):
        return Aggregate(root.input, (AggSpec("count"),), None)
    kids = root.children()
    if kids:
        root.input = rewrite_count(kids[0])  # type: ignore[attr-defined]
    return root


@dataclasses.dataclass
class _QuerySpec:
    """A validated, normalized view of the (linear) logical plan."""

    scan: Scan
    predicate: Expr | None
    project: tuple[str, ...] | None
    aggregate: Aggregate | None
    limit: int | None


def _decompose(root: PlanNode) -> _QuerySpec:
    predicate: Expr | None = None
    project: tuple[str, ...] | None = None
    aggregate: Aggregate | None = None
    limit: int | None = None
    seen_relational = False
    node = root
    while not isinstance(node, Scan):
        if isinstance(node, Limit):
            if aggregate is not None:
                # a Limit *below* the aggregate would mean "aggregate
                # any n rows" — refused at build time too (see
                # Query._require_unlimited)
                raise ValueError(
                    "aggregate()/count() over a limit()ed input is not "
                    "supported"
                )
            limit = node.n if limit is None else min(limit, node.n)
        elif isinstance(node, Aggregate):
            if aggregate is not None:
                raise ValueError("nested aggregates are not supported")
            if seen_relational:
                raise ValueError(
                    "filter()/select() above aggregate() is not supported"
                )
            aggregate = node
        elif isinstance(node, Project):
            seen_relational = True
            if project is None:  # outermost projection wins
                project = tuple(node.columns)
        elif isinstance(node, Filter):
            seen_relational = True
            predicate = (
                node.predicate
                if predicate is None
                else And(node.predicate, predicate)
            )
        elif isinstance(node, Count):
            raise ValueError("Count node left in plan: run rewrite_count")
        elif isinstance(node, Join):
            raise ValueError(
                "join plans lower per side; run them via Query.to_table()"
                "/to_batches()/explain()"
            )
        else:
            raise ValueError(f"unknown plan node {type(node).__name__}")
        node = node.children()[0]
    return _QuerySpec(node, predicate, project, aggregate, limit)


def pushdown_projection(
    spec: _QuerySpec, schema
) -> tuple[tuple[str, ...] | None, str]:
    """Columns the scan must decode: for a plain scan, the projected
    output columns (predicate columns are decoded transiently by
    ``scan_row_group`` itself); for an aggregate, exactly the columns the
    aggregate kernel references.  Returns (columns, explain note)."""
    if spec.aggregate is not None:
        if schema is None or len(schema) == 0:
            # an empty dataset (e.g. a mutable dataset before its first
            # append) has no columns to decode — and no tasks to decode
            # them in; only schema-free aggregates (COUNT(*)) get here,
            # the builder rejects column-referencing ones up front
            return None, "empty dataset: nothing to decode"
        cols = tuple(
            needed_columns(
                list(spec.aggregate.specs),
                spec.aggregate.group_by,
                schema,
                spec.predicate,
            )
        )
        return cols, f"aggregate references [{', '.join(cols)}]"
    if spec.project is not None:
        return spec.project, f"scan ships [{', '.join(spec.project)}]"
    return None, "no projection (all columns ship)"


@dataclasses.dataclass
class FragmentDecision:
    """One fragment's fate through the optimizer, for ``explain()``."""

    fragment: Fragment
    action: str  # "pruned" | "metadata" | "task" | "limit-dropped"
    detail: str = ""


def _stats_only(stats):
    """The same per-column stats with index blocks detached — used to
    attribute a NONE verdict to min/max stats vs the bloom index."""
    return {
        k: dataclasses.replace(st, index=None)
        if getattr(st, "index", None) is not None
        else st
        for k, st in stats.items()
    }


def prune_fragments(
    fragments: Sequence[Fragment], predicate: Expr | None
) -> tuple[list[tuple[Fragment, Expr | None]], list[FragmentDecision]]:
    """Footer-stats pruning: NONE-verdict fragments are dropped, ALL
    verdicts drop the residual predicate (the fragment is taken whole).

    Snapshot tombstones (``Fragment.tombstone``) are folded in here —
    the one choke point every verb and placement lowers through: a
    fragment whose stats prove the tombstone deletes *every* row is
    dropped; one whose stats prove it deletes *none* scans clean; the
    rest carry ``NOT(tombstone)`` conjoined into their residual
    predicate, so deleted rows are filtered at whatever placement runs
    the scan.  Fragment stats are physical (pre-delete), which keeps
    both verdicts exact: NONE/ALL over a superset of the live rows still
    hold for the live rows.
    """
    survivors: list[tuple[Fragment, Expr | None]] = []
    decisions: list[FragmentDecision] = []
    for frag in fragments:
        pred = predicate
        tomb = frag.tombstone
        if tomb is not None and frag.stats:
            verdict = tomb.prune(frag.stats)
            if verdict == NONE:
                tomb = None  # stats prove no deleted rows live here
            elif verdict == ALL:
                decisions.append(
                    FragmentDecision(
                        frag, "pruned", "tombstone deletes every row"
                    )
                )
                continue
        if pred is not None and frag.stats:
            verdict = pred.prune(frag.stats)
            if verdict == NONE:
                # attribute the NONE: re-prune with the index blocks
                # detached — only when min/max alone could NOT prove it
                # did the bloom index earn the skip (cheap: pruned
                # fragments only)
                detail = "stats prove NONE"
                if pred.prune(_stats_only(frag.stats)) != NONE:
                    detail = "bloom index proves NONE"
                decisions.append(FragmentDecision(frag, "pruned", detail))
                continue
            if verdict == ALL:
                pred = None
        if tomb is not None:
            anti = Not(tomb)
            pred = anti if pred is None else And(pred, anti)
        survivors.append((frag, pred))
    return survivors, decisions


def rewrite_metadata_aggregate(
    survivors: Sequence[tuple[Fragment, Expr | None]],
    specs: Sequence[AggSpec],
    group_by: str | None,
    schema,
) -> tuple[
    list[tuple[Fragment, Expr | None]], AggState, list[FragmentDecision]
]:
    """Zero-I/O rewrite: ungrouped aggregates over predicate-free
    fragments answerable from footer statistics merge straight into the
    seed state; only the rest become physical tasks."""
    state = AggState.empty(list(specs), group_by)
    remaining: list[tuple[Fragment, Expr | None]] = []
    decisions: list[FragmentDecision] = []
    for frag, pred in survivors:
        if pred is None and group_by is None:
            part = None
            if frag.stats:
                part = partial_from_stats(
                    list(specs), frag.stats, frag.num_rows, schema
                )
            elif all(s.op == "count" and s.column is None for s in specs):
                part = AggState(
                    list(specs),
                    None,
                    cells=[int(frag.num_rows) for _ in specs],
                    rows=frag.num_rows,
                )
            if part is not None:
                state.merge(part)
                decisions.append(
                    FragmentDecision(
                        frag, "metadata", f"footer answers {frag.num_rows} rows"
                    )
                )
                continue
        remaining.append((frag, pred))
    return remaining, state, decisions


def pushdown_limit(
    survivors: Sequence[tuple[Fragment, Expr | None]], limit: int | None
) -> tuple[
    list[tuple[Fragment, Expr | None]], list[FragmentDecision], int | None
]:
    """Plan-time limit truncation: walking plan order, once predicate-free
    fragments alone guarantee ``limit`` rows, every later fragment is
    dropped before any I/O is planned for it.  The returned budget is
    enforced again at run time (early exit) for the fragments that carry
    residual predicates."""
    if limit is None:
        return list(survivors), [], None
    kept: list[tuple[Fragment, Expr | None]] = []
    decisions: list[FragmentDecision] = []
    guaranteed = 0
    for frag, pred in survivors:
        if guaranteed >= limit:
            decisions.append(
                FragmentDecision(
                    frag, "limit-dropped", f"{guaranteed} rows already sure"
                )
            )
            continue
        kept.append((frag, pred))
        if pred is None:
            guaranteed += frag.num_rows
    return kept, decisions, limit


# ---------------------------------------------------------------------------
# Physical plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FragmentTask:
    """One unit of physical work: scan or partially aggregate one
    fragment at whatever placement the FileFormat picks.  ``limit`` is
    refreshed by the executor to the live remaining row budget just
    before the task is issued."""

    index: int
    kind: str  # "scan" | "aggregate"
    fragment: Fragment
    columns: Sequence[str] | None = None
    predicate: Expr | None = None
    specs: Sequence[AggSpec] | None = None
    group_by: str | None = None
    max_groups: int = DEFAULT_MAX_GROUPS
    schema: Any = None
    limit: int | None = None
    #: Expected surviving-row fraction when a semi-join key filter was
    #: pushed into this task — lets the adaptive scheduler price the
    #: reduced reply bytes without waiting for EWMA history.
    selectivity_hint: float | None = None


@dataclasses.dataclass
class PhysicalPlan:
    """The optimized, lowered plan: per-fragment tasks plus everything
    the optimizer already answered without I/O."""

    kind: str  # "scan" | "aggregate"
    dataset: Any
    tasks: list[FragmentTask]
    decisions: list[FragmentDecision]
    passes: list[str]
    columns: list[str] | None = None  # scan output projection
    specs: list[AggSpec] | None = None
    group_by: str | None = None
    max_groups: int = DEFAULT_MAX_GROUPS
    limit: int | None = None
    metadata_state: AggState | None = None
    metadata_answers: int = 0
    fragments_total: int = 0
    fragments_pruned: int = 0
    #: Of the pruned fragments, how many only the bloom index refuted
    #: (min/max stats alone returned SOME).
    fragments_index_pruned: int = 0


def partition_tasks(
    tasks: Sequence[FragmentTask], dp_size: int
) -> list[list[int]]:
    """Deterministic row-balanced partition of a plan's task list across
    ``dp_size`` data-parallel shards.

    Greedy LPT on ``fragment.num_rows``: tasks are placed largest-first
    onto the currently lightest shard, so shard loads stay within one
    fragment of each other without any coordination.  Ties break on
    shard index and task index, making the assignment a pure function of
    (task row counts, dp_size) — every rank computes the same partition
    independently, which is what lets a restored or re-sharded reader
    reproduce it exactly.

    Returns per-shard lists of *indices into* ``tasks``, each sorted
    ascending (plan order within a shard).  Empty shards are legal:
    with fewer tasks than shards the tail shards simply get ``[]``.
    """
    if dp_size <= 0:
        raise ValueError(f"dp_size must be >= 1, got {dp_size}")
    shards: list[list[int]] = [[] for _ in range(dp_size)]
    if not tasks:
        return shards
    order = sorted(
        range(len(tasks)),
        key=lambda i: (-tasks[i].fragment.num_rows, i),
    )
    heap = [(0, s) for s in range(dp_size)]  # (rows assigned, shard idx)
    for i in order:
        rows, s = heapq.heappop(heap)
        shards[s].append(i)
        heapq.heappush(heap, (rows + tasks[i].fragment.num_rows, s))
    for shard in shards:
        shard.sort()
    return shards


def lower(root: PlanNode) -> PhysicalPlan:
    """Run every optimizer pass and lower the logical plan to per-fragment
    physical tasks."""
    passes: list[str] = []
    had_count = isinstance(root, Count) or any(
        isinstance(n, Count) for n in _walk(root)
    )
    root = rewrite_count(root)
    if had_count:
        passes.append("count-as-aggregate: COUNT(*) lowered to Aggregate")
    spec = _decompose(root)
    ds = spec.scan.dataset
    schema = ds.schema

    scan_cols, note = pushdown_projection(spec, schema)
    spec.scan.columns = scan_cols
    passes.append(f"projection-pushdown: {note}")

    fragments = list(ds._fragments)
    survivors, prune_dec = prune_fragments(fragments, spec.predicate)
    n_all = sum(
        1
        for (f, p) in survivors
        if p is None and spec.predicate is not None
    )
    n_index = sum(
        1 for d in prune_dec if d.detail == "bloom index proves NONE"
    )
    passes.append(
        f"stats-pruning: {len(prune_dec)} of {len(fragments)} fragments "
        f"pruned ({n_index} by bloom index), {n_all} predicate-free "
        "after ALL verdicts"
    )

    decisions = list(prune_dec)
    meta_state: AggState | None = None
    meta_answers = 0
    if spec.aggregate is not None:
        agg = spec.aggregate
        survivors, meta_state, meta_dec = rewrite_metadata_aggregate(
            survivors, agg.specs, agg.group_by, schema
        )
        meta_answers = len(meta_dec)
        decisions.extend(meta_dec)
        passes.append(
            f"metadata-rewrite: {meta_answers} fragments answered from "
            "footer stats (zero I/O)"
        )
        tasks = [
            FragmentTask(
                i,
                "aggregate",
                frag,
                predicate=pred,
                specs=list(agg.specs),
                group_by=agg.group_by,
                max_groups=agg.max_groups,
                schema=schema,
            )
            for i, (frag, pred) in enumerate(survivors)
        ]
        limit = spec.limit  # applies to the finalized table client-side
    else:
        survivors, limit_dec, limit = pushdown_limit(survivors, spec.limit)
        if spec.limit is not None:
            passes.append(
                f"limit-pushdown: row budget {spec.limit}; plan truncated "
                f"to {len(survivors)} tasks ({len(limit_dec)} dropped), "
                "budget rides into scan_op"
            )
        decisions.extend(limit_dec)
        tasks = [
            FragmentTask(
                i,
                "scan",
                frag,
                columns=list(scan_cols) if scan_cols is not None else None,
                predicate=pred,
                limit=limit,
            )
            for i, (frag, pred) in enumerate(survivors)
        ]
    decisions.extend(
        FragmentDecision(t.fragment, "task", render_expr(t.predicate))
        for t in tasks
    )
    return PhysicalPlan(
        kind="scan" if spec.aggregate is None else "aggregate",
        dataset=ds,
        tasks=tasks,
        decisions=decisions,
        passes=passes,
        columns=list(scan_cols)
        if scan_cols is not None and spec.aggregate is None
        else None,
        specs=list(spec.aggregate.specs) if spec.aggregate else None,
        group_by=spec.aggregate.group_by if spec.aggregate else None,
        max_groups=spec.aggregate.max_groups
        if spec.aggregate
        else DEFAULT_MAX_GROUPS,
        limit=limit if spec.aggregate is None else spec.limit,
        metadata_state=meta_state,
        metadata_answers=meta_answers,
        fragments_total=len(fragments),
        fragments_pruned=len(prune_dec),
        fragments_index_pruned=n_index,
    )


def _walk(root: PlanNode) -> Iterator[PlanNode]:
    node: PlanNode | None = root
    while node is not None:
        yield node
        kids = node.children()
        node = kids[0] if kids else None


# ---------------------------------------------------------------------------
# Scan metrics (every verb records these uniformly)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScanMetrics:
    tasks: list[TaskRecord] = dataclasses.field(default_factory=list)
    fragments_total: int = 0
    fragments_pruned: int = 0
    fragments_index_pruned: int = 0  # pruned by bloom index, not min/max
    metadata_answers: int = 0  # fragments answered from footer stats
    discovery_bytes: int = 0
    rows: int = 0
    wall_s: float = 0.0
    admission: dict = dataclasses.field(default_factory=dict)
    #: Build-side metrics of a join run (its own scan), kept separate so
    #: probe-side wire bytes stay directly comparable across strategies.
    build: "ScanMetrics | None" = None
    tenant: str = "default"
    lane: str = "bulk"
    #: Set when the run was deadline-shed (the run verbs return it too).
    shed: Shed | None = None

    @property
    def client_cpu_s(self) -> float:
        return sum(t.client_cpu_s for t in self.tasks)

    @property
    def osd_cpu_s(self) -> float:
        return sum(t.cpu_s for t in self.tasks if t.where == "osd")

    @property
    def wire_bytes(self) -> int:
        return self.discovery_bytes + sum(t.wire_bytes for t in self.tasks)

    @property
    def cache_hits(self) -> int:
        return sum(1 for t in self.tasks if t.cached)

    @property
    def hedged_tasks(self) -> int:
        return sum(1 for t in self.tasks if t.hedged)

    def summary(self) -> dict:
        d = {
            "tenant": self.tenant,
            "lane": self.lane,
            "fragments": self.fragments_total,
            "pruned": self.fragments_pruned,
            "index_pruned": self.fragments_index_pruned,
            "metadata_answers": self.metadata_answers,
            "rows": self.rows,
            "wire_bytes": self.wire_bytes,
            "client_cpu_s": round(self.client_cpu_s, 4),
            "osd_cpu_s": round(self.osd_cpu_s, 4),
            "wall_s": round(self.wall_s, 4),
            "cache_hits": self.cache_hits,
            "hedged": self.hedged_tasks,
            "admission_waits": self.admission.get("waits", 0),
            "admission_wait_s": self.admission.get("wait_s", 0.0),
            "preemptions": self.admission.get("preemptions", 0),
            "sheds": self.admission.get("sheds", 0),
        }
        if self.shed is not None:
            d["shed"] = str(self.shed)
        if self.build is not None:
            d["build"] = self.build.summary()
        return d


# ---------------------------------------------------------------------------
# The shared streaming executor
# ---------------------------------------------------------------------------


def _admission_delta(before: dict, after: dict) -> dict:
    """This run's share of a (possibly shared, possibly long-lived)
    admission controller's counters."""
    d = {"slots_per_osd": after["slots_per_osd"]}
    for k in ("admitted", "waits", "wait_s", "preemptions", "sheds"):
        v = after[k] - before[k]
        d[k] = round(v, 6) if k == "wait_s" else v
    return d


def stream_tasks(
    plan: PhysicalPlan,
    fmt,
    metrics: ScanMetrics,
    *,
    max_inflight: int,
    queue_depth: int,
    ctx: TaskContext | None = None,
) -> Iterator[tuple[FragmentTask, Any]]:
    """Run the plan's fragment tasks through ``fmt.execute_task`` with at
    most ``max_inflight`` in flight, issuing new work only as finished
    work is consumed (backpressure) and per-OSD pressure bounded by one
    shared AdmissionController.

    Yields (task, Table | AggState) in completion order.  For scan plans
    with a limit, the live row budget stops issuance the moment it is
    met and cancels still-queued tasks — fragments past the budget are
    never scanned.

    ``ctx`` is the run's :class:`~repro.dataset.qos.TaskContext`.  With a
    registry attached, admission goes through the cluster's shared
    weighted-fair controller (every tenant arbitrated together);
    otherwise a run-private controller reproduces the historic
    single-tenant behavior.  A run that cannot meet ``ctx.deadline_s``
    stops issuing work and records a typed :class:`Shed` on
    ``metrics.shed`` — the stream simply ends early; the run verbs turn
    it into their return value."""
    ds = plan.dataset
    ctx = ctx if ctx is not None else TaskContext()
    if ctx.admission is not None:
        admission = ctx.admission
    elif ctx.registry is not None:
        admission = ctx.registry.controller(ds.fs.store)
    else:
        admission = AdmissionController(ds.fs.store, queue_depth)
    t0 = time.perf_counter()
    ctx = dataclasses.replace(
        ctx, admission=admission,
        started_at=t0 if ctx.started_at is None else ctx.started_at)
    lock = threading.Lock()
    remaining = plan.limit if plan.kind == "scan" else None
    completed = 0
    total = len(plan.tasks)

    def shed(reason: str):
        metrics.shed = Shed(ctx.tenant, ctx.lane, reason, ctx.deadline_s,
                            ctx.elapsed_s(), completed, total)

    def over_deadline() -> bool:
        r = ctx.remaining_s()
        return r is not None and r <= 0

    def run(task: FragmentTask):
        out, rec = fmt.execute_task(ds.fs, task, ctx)
        with lock:
            metrics.tasks.append(rec)
        return task, out

    before = admission.stats()
    try:
        tasks = plan.tasks
        if max_inflight <= 1 or len(tasks) <= 1:
            for task in tasks:
                if remaining is not None:
                    if remaining <= 0:
                        return
                    task.limit = remaining
                if over_deadline():
                    shed(f"deadline expired with {total - completed} "
                         f"tasks left")
                    return
                try:
                    task, out = run(task)
                except AdmissionTimeout as e:
                    shed(f"admission timeout on osd.{e.osd_id} after "
                         f"{e.waited_s * 1e3:.1f}ms queued")
                    return
                completed += 1
                if remaining is not None:
                    remaining -= len(out)
                yield task, out
            return
        it = iter(tasks)

        def submit(pool, task):
            if remaining is not None:
                task.limit = remaining
            return pool.submit(run, task)

        with ThreadPoolExecutor(max_workers=max_inflight) as pool:
            pending = {
                submit(pool, t) for t in islice(it, max_inflight)
            }
            try:
                while pending:
                    done, pending = wait(
                        pending, return_when=FIRST_COMPLETED
                    )
                    for fut in done:
                        try:
                            task, out = fut.result()
                        except AdmissionTimeout as e:
                            shed(f"admission timeout on osd.{e.osd_id} "
                                 f"after {e.waited_s * 1e3:.1f}ms queued")
                            return
                        completed += 1
                        if remaining is not None:
                            remaining -= len(out)
                        if (remaining is None or remaining > 0) \
                                and not over_deadline():
                            nxt = next(it, None)
                            if nxt is not None:
                                pending.add(submit(pool, nxt))
                        yield task, out
                        if remaining is not None and remaining <= 0:
                            return  # budget met: cancel queued work
                        if over_deadline() and completed < total:
                            shed(f"deadline expired with "
                                 f"{total - completed} tasks left")
                            return
            finally:
                for fut in pending:  # consumer stopped early / budget met
                    fut.cancel()
    finally:
        metrics.wall_s = time.perf_counter() - t0
        metrics.admission = _admission_delta(before, admission.stats())
        if ctx.registry is not None:
            ctx.registry.record(metrics)


def empty_table(schema, columns: Sequence[str] | None) -> Table:
    if schema is None:  # e.g. a mutable dataset with no appends yet
        from repro.aformat.schema import Schema

        return Table(Schema(()), [])
    names = list(columns) if columns is not None else schema.names
    sch = schema.select(names)
    return Table(
        sch,
        [
            Column(
                f,
                np.empty(0, object if f.type == "string" else f.numpy_dtype),
            )
            for f in sch
        ],
    )


# ---------------------------------------------------------------------------
# Joins: build-side hashing, semi-join pushdown, probe-side assembly
# ---------------------------------------------------------------------------

_JOIN_HOWS = ("inner", "left", "semi")
_INT_TYPES = {"int8", "int16", "int32", "int64"}


@dataclasses.dataclass
class _PostOps:
    """Filter/Project/Limit nodes sitting *above* a Join: they run on the
    assembled join output, client-side."""

    predicate: Expr | None
    project: tuple[str, ...] | None
    limit: int | None


def _split_join(root: PlanNode) -> tuple[_PostOps, Join, PlanNode]:
    """Split a join plan into (post-join ops, join node, probe subtree)."""
    predicate: Expr | None = None
    project: tuple[str, ...] | None = None
    limit: int | None = None
    node = root
    while not isinstance(node, Join):
        if isinstance(node, Limit):
            limit = node.n if limit is None else min(limit, node.n)
        elif isinstance(node, Project):
            if project is None:  # outermost projection wins
                project = tuple(node.columns)
        elif isinstance(node, Filter):
            predicate = (
                node.predicate
                if predicate is None
                else And(node.predicate, predicate)
            )
        else:
            raise ValueError(
                f"{type(node).__name__} above a join is not supported"
            )
        node = node.children()[0]
    return _PostOps(predicate, project, limit), node, node.input


def _join_fields(join: Join):
    """Output shape of a join: (probe output names, [(build column,
    renamed output Field)], all output Fields).

    Semi joins emit probe columns only.  Inner/left emit probe columns
    then build columns minus the build key (it duplicates the probe
    key); build names clashing with an already-used name get ``_right``
    suffixed until unique."""
    pspec = _decompose(_copy_plan(join.input))
    bspec = _decompose(_copy_plan(join.build_query._root))
    probe_ds, build_ds = pspec.scan.dataset, bspec.scan.dataset
    probe_names = (
        list(pspec.project)
        if pspec.project is not None
        else list(probe_ds.schema.names)
    )
    probe_fields = [probe_ds.schema.field(n) for n in probe_names]
    if join.how == "semi":
        return probe_names, [], probe_fields
    build_names = (
        list(bspec.project)
        if bspec.project is not None
        else list(build_ds.schema.names)
    )
    used = set(probe_names)
    pairs: list[tuple[str, Field]] = []
    for n in build_names:
        if n == join.on_right:
            continue
        f = build_ds.schema.field(n)
        out = n
        while out in used:
            out += "_right"
        used.add(out)
        # a left join's unmatched probe rows null the build columns
        pairs.append(
            (n, Field(out, f.type, f.nullable or join.how == "left"))
        )
    return probe_names, pairs, probe_fields + [f for _, f in pairs]


@dataclasses.dataclass
class JoinStrategy:
    """What the semi-join pass decided, for explain() and tests."""

    how: str
    on_left: str
    on_right: str
    build_rows: int
    distinct_keys: int
    pushdown: str  # "inlist" | "bloom" | "none"
    reason: str = ""  # why pushdown is "none"
    key_filter: Expr | None = None
    selectivity_hint: float | None = None


def _choose_strategy(
    join: Join, probe_limit: int | None, probe_rows: int,
    build_rows: int, distinct: np.ndarray,
) -> JoinStrategy:
    """The semi-join pushdown pass: inner/semi joins turn the build keys
    into a probe-side filter — an exact IN-list when small, a bloom
    filter when large.  Left joins keep every probe row, and a probe
    limit means "any n probe rows" *before* the join, which a pushed
    filter would silently change — both run unfiltered."""
    n = len(distinct)
    base = dict(how=join.how, on_left=join.on_left, on_right=join.on_right,
                build_rows=build_rows, distinct_keys=n)
    if join.how == "left":
        return JoinStrategy(
            **base, pushdown="none",
            reason="left join keeps every probe row")
    if probe_limit is not None:
        return JoinStrategy(
            **base, pushdown="none",
            reason="probe-side limit pins pre-join row selection")
    hint = min(1.0, max(n, 1) / max(1, probe_rows))
    if n <= IN_LIST_MAX:
        values = [
            v.item() if isinstance(v, np.generic) else v for v in distinct
        ]
        return JoinStrategy(
            **base, pushdown="inlist",
            key_filter=IsIn(join.on_left, values), selectivity_hint=hint)
    return JoinStrategy(
        **base, pushdown="bloom",
        key_filter=BloomIn.build(join.on_left, distinct),
        selectivity_hint=hint)


def _linear_root(
    spec: _QuerySpec,
    columns: Sequence[str] | None,
    extra_pred: Expr | None = None,
) -> PlanNode:
    """Rebuild a linear logical plan from a decomposed side of a join,
    with the pushed key filter (if any) conjoined into the predicate so
    ``prune_fragments`` and ``scan_op`` see one composed residual."""
    root: PlanNode = Scan(spec.scan.dataset)
    pred = spec.predicate
    if extra_pred is not None:
        pred = extra_pred if pred is None else And(pred, extra_pred)
    if pred is not None:
        root = Filter(root, pred)
    if columns is not None:
        root = Project(root, tuple(columns))
    if spec.limit is not None:
        root = Limit(root, spec.limit)
    return root


def _key_validity(col: Column) -> np.ndarray:
    """Join-key semantics: null keys never match, and neither do NaNs
    (SQL equality, matching the NumPy reference)."""
    valid = (
        np.ones(len(col.values), "?")
        if col.validity is None
        else col.validity.astype(bool)
    )
    if col.field.type in ("float32", "float64"):
        valid = valid & ~np.isnan(col.values)
    return valid


@dataclasses.dataclass
class _JoinContext:
    how: str
    on_left: str
    probe_names: list[str]
    build_pairs: list  # [(build column name, renamed output Field)]
    fields: list  # joined output Fields
    build_tbl: Table
    index: dict  # key -> [build row idx], build-row order
    distinct: np.ndarray  # exact distinct non-null build keys
    strategy: JoinStrategy


def _gather_build(ctx: _JoinContext, bi: np.ndarray) -> list[Column]:
    """Gather build-side output columns by row index; ``-1`` marks an
    unmatched probe row (left join): null, zero-filled storage."""
    matched = bi >= 0
    safe = np.where(matched, bi, 0)
    out: list[Column] = []
    for name, field in ctx.build_pairs:
        col = ctx.build_tbl.column(name)
        if len(col.values) == 0:
            vals = (
                np.array([""] * len(bi), object)
                if field.type == "string"
                else np.zeros(len(bi), field.numpy_dtype)
            )
            out.append(Column(field, vals, np.zeros(len(bi), "?")))
            continue
        vals = col.values[safe]
        valid = (
            np.ones(len(bi), "?")
            if col.validity is None
            else col.validity[safe].astype(bool)
        )
        if not matched.all():
            vals = vals.copy()
            vals[~matched] = "" if field.type == "string" else 0
            valid = valid & matched
        out.append(Column(field, vals, valid))
    return out


def _join_batch(tbl: Table, ctx: _JoinContext) -> Table:
    """Probe one batch against the built table.  Probe rows keep their
    scan order; a probe row's matches come out in build-row order —
    deterministic, so the differential harness can assert exact
    equality."""
    kcol = tbl.column(ctx.on_left)
    kvalid = _key_validity(kcol)
    kvals = kcol.values
    probe = tbl.select(ctx.probe_names)
    if ctx.how == "semi":
        mask = np.zeros(len(tbl), "?")
        if len(ctx.distinct):
            # exact membership: bloom false positives die here
            mask = np.isin(kvals, ctx.distinct) & kvalid
        return probe.filter(mask)
    pidx: list[int] = []
    bidx: list[int] = []
    for i in range(len(tbl)):
        rows = ctx.index.get(kvals[i]) if kvalid[i] else None
        if rows:
            pidx.extend([i] * len(rows))
            bidx.extend(rows)
        elif ctx.how == "left":
            pidx.append(i)
            bidx.append(-1)
    pi = np.asarray(pidx, np.int64)
    bi = np.asarray(bidx, np.int64)
    cols = list(probe.take(pi).columns) + _gather_build(ctx, bi)
    return Table(Schema(tuple(ctx.fields)), cols)


def _empty_join_table(ctx: _JoinContext) -> Table:
    return Table(
        Schema(tuple(ctx.fields)),
        [
            Column(
                f,
                np.empty(0, object if f.type == "string" else f.numpy_dtype),
            )
            for f in ctx.fields
        ],
    )


def _apply_post(tbl: Table, post: _PostOps) -> Table:
    if post.predicate is not None:
        tbl = tbl.filter(post.predicate.evaluate(tbl))
    if post.project is not None:
        tbl = tbl.select(list(post.project))
    if post.limit is not None:
        tbl = tbl.head(post.limit)
    return tbl


# ---------------------------------------------------------------------------
# The Query builder
# ---------------------------------------------------------------------------


class Query:
    """Lazy, composable query over a Dataset.

    Builder verbs (``select`` / ``filter`` / ``limit`` / ``aggregate`` /
    ``count``) only grow the logical plan; nothing touches storage until
    ``to_table`` / ``to_batches`` / ``to_scalar`` runs it through the
    optimizer and the shared streaming executor.  ``explain()`` shows
    what would run.  ``metrics`` holds the last execution's ScanMetrics
    (each run gets a fresh snapshot)."""

    def __init__(
        self,
        ds,
        *,
        format="pushdown",
        num_threads: int = 16,
        queue_depth: int = 4,
        decode_backend=None,
        tenant=None,
        _root: PlanNode | None = None,
        _scalar: bool = False,
    ):
        self.ds = ds
        self.fmt = resolve_format(format, decode_backend=decode_backend)
        self.num_threads = num_threads
        self.queue_depth = queue_depth
        self.ctx = as_task_context(tenant)
        self._root = _root if _root is not None else Scan(ds)
        self._scalar = _scalar
        self.metrics = ScanMetrics(discovery_bytes=ds.discovery_bytes)

    # -- builder -----------------------------------------------------------
    def _derive(self, root: PlanNode, *, scalar: bool | None = None):
        q = Query.__new__(Query)
        q.ds = self.ds
        q.fmt = self.fmt
        q.num_threads = self.num_threads
        q.queue_depth = self.queue_depth
        q.ctx = self.ctx
        q._root = root
        q._scalar = self._scalar if scalar is None else scalar
        q.metrics = ScanMetrics(discovery_bytes=self.ds.discovery_bytes)
        return q

    @property
    def _has_aggregate(self) -> bool:
        return any(
            isinstance(n, (Aggregate, Count)) for n in _walk(self._root)
        )

    def _join_node(self) -> Join | None:
        for n in _walk(self._root):
            if isinstance(n, Join):
                return n
        return None

    def _require_relational(self, verb: str):
        if self._has_aggregate:
            raise ValueError(
                f"{verb} cannot be applied after aggregate()/count()"
            )

    def _require_no_join(self, verb: str):
        if self._join_node() is not None:
            raise ValueError(f"{verb} over a join is not supported")

    def _require_unlimited(self, verb: str):
        # aggregating "any n rows" has no well-defined answer here: the
        # executor would have to fold a nondeterministic subset.  Refuse
        # rather than silently aggregate the whole input.  (limit() on
        # top of an aggregate — trimming the finalized group rows — is
        # fine and stays supported.)
        if any(isinstance(n, Limit) for n in _walk(self._root)):
            raise ValueError(f"{verb} over a limit()ed input is not supported")

    def select(self, *columns) -> "Query":
        """Project the output to ``columns`` (names; the last select
        wins).  Accepts either ``select("a", "b")`` or a single
        list/tuple."""
        self._require_relational("select()")
        if len(columns) == 1 and isinstance(columns[0], (list, tuple)):
            columns = tuple(columns[0])
        if not columns:
            raise ValueError("select() needs at least one column")
        for c in columns:
            if not isinstance(c, str):
                raise TypeError(
                    f"select() takes column names, got {type(c).__name__}"
                )
        join = self._join_node()
        if join is not None:
            # post-join projection: validate against the join's output
            # shape (probe columns + renamed build columns)
            names = {f.name for f in _join_fields(join)[2]}
            for c in columns:
                if c not in names:
                    raise KeyError(
                        f"select({c!r}): not a join output column "
                        f"(have {sorted(names)})"
                    )
            return self._derive(Project(self._root, tuple(columns)))
        if self.ds.schema is None:
            raise ValueError("select() on a dataset with no schema "
                             "(empty dataset)")
        for c in columns:
            self.ds.schema.field(c)  # validate early
        return self._derive(Project(self._root, tuple(columns)))

    def filter(self, predicate: Expr) -> "Query":
        """Keep rows matching ``predicate``; chained filters AND."""
        self._require_relational("filter()")
        if not isinstance(predicate, Expr):
            raise TypeError("filter() takes an Expr predicate")
        return self._derive(Filter(self._root, predicate))

    def limit(self, n: int) -> "Query":
        """At most ``n`` rows (any n rows: fragment completion order is
        nondeterministic, like SQL LIMIT without ORDER BY)."""
        if not isinstance(n, int) or n <= 0:
            raise ValueError(f"limit must be a positive int, got {n!r}")
        return self._derive(Limit(self._root, n))

    def aggregate(
        self,
        aggs,
        *,
        group_by: str | None = None,
        max_groups: int = DEFAULT_MAX_GROUPS,
    ) -> "Query":
        """SUM/MIN/MAX/MEAN/COUNT, optionally GROUP BY one key column."""
        self._require_relational("aggregate()")
        self._require_no_join("aggregate()")
        self._require_unlimited("aggregate()")
        specs = parse_aggs(aggs)
        if not specs:
            raise ValueError("aggregate() needs at least one aggregate")
        refs_columns = group_by is not None or any(
            s.column is not None for s in specs
        )
        if self.ds.schema is None and refs_columns:
            raise ValueError(
                "aggregate() referencing columns on a dataset with no "
                "schema (empty dataset); only COUNT(*) is answerable"
            )
        for s in specs:
            if s.column is not None:
                self.ds.schema.field(s.column)
        if group_by is not None:
            self.ds.schema.field(group_by)
        return self._derive(
            Aggregate(self._root, tuple(specs), group_by, max_groups)
        )

    def count(self) -> "Query":
        """COUNT(*): a scalar query (``to_scalar`` returns the int)."""
        self._require_relational("count()")
        self._require_no_join("count()")
        self._require_unlimited("count()")
        return self._derive(Count(self._root), scalar=True)

    def join(self, other: "Query", *, on, how: str = "inner") -> "Query":
        """Hash-join this query (the probe side) against ``other`` (the
        build side).  ``on`` is a key column name present on both sides,
        or a ``(left, right)`` pair; ``how`` is ``"inner"``, ``"left"``
        or ``"semi"`` (semi keeps probe rows with ≥1 match, emits probe
        columns only).

        Execution is storage-native for inner/semi joins: the build
        side runs first, its distinct keys become an IN-list (small) or
        bloom filter (large) conjoined into the probe scan's residual
        predicate, so storage nodes drop non-matching rows before IPC.
        Null and NaN keys never match.  A probe row's matches surface
        in build-row order, making results exactly reproducible."""
        self._require_relational("join()")
        self._require_no_join("join() (nested joins)")
        if not isinstance(other, Query):
            raise TypeError(
                f"join() takes a Query build side, got "
                f"{type(other).__name__}"
            )
        if how not in _JOIN_HOWS:
            raise ValueError(f"how must be one of {_JOIN_HOWS}, got {how!r}")
        if other._has_aggregate:
            raise ValueError(
                "join() build side cannot be an aggregate/count query"
            )
        if other._join_node() is not None:
            raise ValueError("join() build side cannot itself be a join")
        if any(isinstance(n, Limit) for n in _walk(other._root)):
            raise ValueError(
                "join() build side with limit() is not supported (the "
                "build keys would be a nondeterministic subset)"
            )
        if isinstance(on, str):
            on_left = on_right = on
        else:
            try:
                on_left, on_right = on
            except (TypeError, ValueError):
                raise ValueError(
                    "on must be a column name or a (left, right) pair"
                ) from None
        if self.ds.schema is None or other.ds.schema is None:
            raise ValueError(
                "join() needs a schema on both sides (empty dataset)"
            )
        lf = self.ds.schema.field(on_left)
        rf = other.ds.schema.field(on_right)
        compatible = lf.type == rf.type or (
            lf.type in _INT_TYPES and rf.type in _INT_TYPES
        )
        if not compatible:
            raise TypeError(
                f"join key types differ: {on_left} is {lf.type}, "
                f"{on_right} is {rf.type}"
            )
        return self._derive(
            Join(self._root, other, on_left, on_right, how)
        )

    # -- plan access -------------------------------------------------------
    def logical_plan(self) -> PlanNode:
        return self._root

    def physical_plan(self) -> PhysicalPlan:
        """Optimize + lower (no execution)."""
        return lower(_copy_plan(self._root))

    # -- execution ---------------------------------------------------------
    def _begin(self, plan: PhysicalPlan) -> ScanMetrics:
        """Fresh per-execution metrics snapshot; ``self.metrics`` always
        refers to the latest run."""
        m = ScanMetrics(
            discovery_bytes=self.ds.discovery_bytes,
            fragments_total=plan.fragments_total,
            fragments_pruned=plan.fragments_pruned,
            fragments_index_pruned=plan.fragments_index_pruned,
            metadata_answers=plan.metadata_answers,
            tenant=self.ctx.tenant,
            lane=self.ctx.lane,
        )
        self.metrics = m
        return m

    # -- join execution ----------------------------------------------------
    def _prepare_join(self):
        """Run the build side, pick the pushdown strategy, lower the
        probe side with the key filter conjoined in.  Returns
        (probe PhysicalPlan, _JoinContext, build Query, _PostOps)."""
        post, join, probe_root = _split_join(_copy_plan(self._root))
        pspec = _decompose(probe_root)
        bspec = _decompose(_copy_plan(join.build_query._root))
        probe_ds = pspec.scan.dataset

        bcols = None
        if bspec.project is not None:
            bcols = list(bspec.project)
            if join.on_right not in bcols:
                bcols.append(join.on_right)
        bq = join.build_query._derive(_linear_root(bspec, bcols))
        build_tbl = bq.to_table()

        probe_names, pairs, fields = _join_fields(join)
        kcol = build_tbl.column(join.on_right)
        valid = _key_validity(kcol)
        index: dict = {}
        for i in np.flatnonzero(valid):
            index.setdefault(kcol.values[i], []).append(int(i))
        distinct = (
            np.unique(kcol.values[valid])
            if valid.any()
            else kcol.values[:0]
        )

        strategy = _choose_strategy(
            join, pspec.limit, probe_ds.num_rows, len(build_tbl), distinct
        )
        pcols = None
        if pspec.project is not None:
            pcols = list(pspec.project)
            if join.on_left not in pcols:
                pcols.append(join.on_left)
        plan = lower(_linear_root(pspec, pcols, strategy.key_filter))
        if strategy.selectivity_hint is not None:
            for t in plan.tasks:
                t.selectivity_hint = strategy.selectivity_hint
        ctx = _JoinContext(
            join.how, join.on_left, probe_names, pairs, fields,
            build_tbl, index, distinct, strategy,
        )
        return plan, ctx, bq, post

    def _join_to_table(self) -> "Table | Shed":
        plan, ctx, bq, post = self._prepare_join()
        metrics = self._begin(plan)
        metrics.build = bq.metrics
        parts = sorted(
            stream_tasks(
                plan,
                self.fmt,
                metrics,
                max_inflight=self.num_threads,
                queue_depth=self.queue_depth,
                ctx=self.ctx,
            ),
            key=lambda p: p[0].index,
        )
        if metrics.shed is not None:
            # a shed join probe is never degraded: a partial probe side
            # would silently drop matches
            return metrics.shed
        if plan.limit is not None:
            # probe-side limit: trim the probe rows first (the budget is
            # on probe rows), then join once
            tables = [t for _, t in parts if len(t)]
            probe_tbl = (
                Table.concat(tables)
                if tables
                else empty_table(plan.dataset.schema, plan.columns)
            )
            joined = [_join_batch(probe_tbl.head(plan.limit), ctx)]
        else:
            joined = [_join_batch(t, ctx) for _, t in parts]
        tables = [t for t in joined if len(t)]
        result = (
            Table.concat(tables) if tables else _empty_join_table(ctx)
        )
        result = _apply_post(result, post)
        metrics.rows = len(result)
        return result

    def _join_batches(self, max_inflight: int | None) -> Iterator[Table]:
        plan, ctx, bq, post = self._prepare_join()
        metrics = self._begin(plan)
        metrics.build = bq.metrics

        def gen():
            if plan.limit is not None:
                # probe-side limit: materialized path (single batch out)
                parts = sorted(
                    stream_tasks(
                        plan,
                        self.fmt,
                        metrics,
                        max_inflight=max_inflight or self.num_threads,
                        queue_depth=self.queue_depth,
                        ctx=self.ctx,
                    ),
                    key=lambda p: p[0].index,
                )
                if metrics.shed is not None:
                    return
                tables = [t for _, t in parts if len(t)]
                probe_tbl = (
                    Table.concat(tables)
                    if tables
                    else empty_table(plan.dataset.schema, plan.columns)
                )
                result = _apply_post(
                    _join_batch(probe_tbl.head(plan.limit), ctx), post
                )
                metrics.rows = len(result)
                if len(result):
                    yield result
                return
            remaining = post.limit
            for _task, tbl in stream_tasks(
                plan,
                self.fmt,
                metrics,
                max_inflight=max_inflight or self.num_threads,
                queue_depth=self.queue_depth,
                ctx=self.ctx,
            ):
                part = _join_batch(tbl, ctx)
                if post.predicate is not None:
                    part = part.filter(post.predicate.evaluate(part))
                if post.project is not None:
                    part = part.select(list(post.project))
                if remaining is not None:
                    part = part.head(remaining)
                    remaining -= len(part)
                if len(part):
                    metrics.rows += len(part)
                    yield part
                if remaining is not None and remaining <= 0:
                    return  # post-limit met: cancel still-queued probes

        return gen()

    def to_batches(
        self, *, max_inflight: int | None = None
    ) -> Iterator[Table]:
        """Stream per-fragment Tables in completion order under the row
        budget; empty fragments are skipped.  Join queries stream the
        probe side against the built hash table (probe-side limits
        materialize first)."""
        if self._join_node() is not None:
            return self._join_batches(max_inflight)
        plan = lower(_copy_plan(self._root))
        if plan.kind != "scan":
            raise ValueError(
                "to_batches() streams scans; aggregate queries "
                "materialize via to_table()"
            )
        metrics = self._begin(plan)
        remaining = plan.limit

        def gen():
            nonlocal remaining
            for _task, tbl in stream_tasks(
                plan,
                self.fmt,
                metrics,
                max_inflight=max_inflight or self.num_threads,
                queue_depth=self.queue_depth,
                ctx=self.ctx,
            ):
                if remaining is not None:
                    tbl = tbl.head(remaining)
                    remaining -= len(tbl)
                if len(tbl):
                    metrics.rows += len(tbl)
                    yield tbl

        return gen()

    def to_table(self) -> "Table | Shed":
        """Materialize the result (scan plans reassemble fragments in
        plan order; aggregates finalize the merged partial state; joins
        assemble probe batches against the built hash table).

        A run that misses its ``TaskContext`` deadline returns a typed
        :class:`Shed` instead of a table; under
        ``shed_policy="degrade"`` a shed *scan* carries the fragments
        completed before the deadline as ``shed.partial``."""
        if self._join_node() is not None:
            return self._join_to_table()
        plan = lower(_copy_plan(self._root))
        metrics = self._begin(plan)
        if plan.kind == "aggregate":
            state = plan.metadata_state
            for _task, part in stream_tasks(
                plan,
                self.fmt,
                metrics,
                max_inflight=self.num_threads,
                queue_depth=self.queue_depth,
                ctx=self.ctx,
            ):
                state.merge(part)  # completion order
            if metrics.shed is not None:
                # a partial aggregate is a wrong answer, not a degraded
                # one — sheds of aggregate plans never carry a partial
                return metrics.shed
            metrics.rows = state.rows
            out = state.finalize(self.ds.schema)
            if plan.limit is not None:
                out = out.head(plan.limit)
            return out
        parts = sorted(
            stream_tasks(
                plan,
                self.fmt,
                metrics,
                max_inflight=self.num_threads,
                queue_depth=self.queue_depth,
                ctx=self.ctx,
            ),
            key=lambda p: p[0].index,
        )
        if metrics.shed is not None:
            if self.ctx.shed_policy == "degrade":
                tables = [t for _, t in parts if len(t)]
                metrics.shed.partial = (
                    Table.concat(tables)
                    if tables
                    else empty_table(self.ds.schema, plan.columns)
                )
            return metrics.shed
        tables = [t for _, t in parts if len(t)]
        result = (
            Table.concat(tables)
            if tables
            else empty_table(self.ds.schema, plan.columns)
        )
        if plan.limit is not None:
            result = result.head(plan.limit)
        metrics.rows = len(result)
        return result

    def to_scalar(self):
        """Run a single-cell query (e.g. ``count()``) to its scalar —
        or the :class:`Shed` if the run missed its deadline."""
        out = self.to_table()
        if isinstance(out, Shed):
            return out
        if len(out) != 1 or len(out.schema) != 1:
            raise ValueError(
                f"to_scalar() needs a 1x1 result, got "
                f"{len(out)}x{len(out.schema)}"
            )
        v = out.columns[0].values[0]
        return v.item() if isinstance(v, np.generic) else v

    # -- explain -----------------------------------------------------------
    def _physical_lines(
        self, plan: PhysicalPlan, max_fragments: int
    ) -> list[str]:
        lines = ["== physical plan =="]
        budget = (
            f", row_budget={plan.limit}" if plan.limit is not None else ""
        )
        qos = ""
        if self.ctx.tenant != "default" or self.ctx.deadline_s is not None:
            qos = f", tenant={self.ctx.tenant}/{self.ctx.lane}"
            if self.ctx.deadline_s is not None:
                qos += (f", deadline={self.ctx.deadline_s * 1e3:.0f}ms"
                        f"/{self.ctx.shed_policy}")
        lines.append(
            f"executor: streaming, format={self.fmt.name}, "
            f"max_inflight={self.num_threads}, "
            f"queue_depth={self.queue_depth}/OSD{budget}{qos}"
        )
        idx = (
            f" ({plan.fragments_index_pruned} by bloom index)"
            if plan.fragments_index_pruned
            else ""
        )
        lines.append(
            f"fragments: {plan.fragments_total} total, "
            f"{plan.fragments_pruned} pruned{idx}, "
            f"{plan.metadata_answers} metadata-answered, "
            f"{len(plan.tasks)} tasks"
        )
        shown = 0
        for task in plan.tasks:
            if shown >= max_fragments:
                lines.append(f"  ... (+{len(plan.tasks) - shown} more tasks)")
                break
            frag = task.fragment
            where = self.fmt.explain_task(self.ds.fs, task)
            lim = f" limit<={task.limit}" if task.limit is not None else ""
            lines.append(
                f"  [{task.index}] {task.kind} {frag.path}#{frag.obj_idx} "
                f"rows={frag.num_rows} pred={render_expr(task.predicate)}"
                f"{lim} | {where}"
            )
            shown += 1
        return lines

    def _explain_join(self, *, max_fragments: int) -> str:
        plan, ctx, _bq, _post = self._prepare_join()
        s = ctx.strategy
        lines = ["== logical plan =="]
        lines += render_plan(self._root)
        lines.append("== join ==")
        lines.append(
            f"- strategy: hash {s.how} join on {s.on_left} = {s.on_right}; "
            f"build side {s.build_rows} rows, {s.distinct_keys} distinct "
            "keys"
        )
        if s.pushdown == "inlist":
            lines.append(
                f"- semijoin-pushdown: IN-list({s.distinct_keys} keys) "
                f"conjoined into probe scan (selectivity hint "
                f"{s.selectivity_hint:.4f})"
            )
        elif s.pushdown == "bloom":
            bf = s.key_filter
            lines.append(
                f"- semijoin-pushdown: bloom({bf.num_bits} bits, "
                f"{bf.num_hashes} hashes, digest={bf.digest()}) conjoined "
                f"into probe scan (selectivity hint "
                f"{s.selectivity_hint:.4f})"
            )
        else:
            lines.append(f"- semijoin-pushdown: none ({s.reason})")
        lines.append("== optimizer ==")
        lines += [f"- {p}" for p in plan.passes]
        lines += self._physical_lines(plan, max_fragments)
        pruned = [d for d in plan.decisions if d.action == "pruned"]
        shown = 0
        for d in pruned:
            if shown >= max_fragments:
                lines.append(f"  ... (+{len(pruned) - shown} more pruned)")
                break
            lines.append(
                f"  [-] pruned {d.fragment.path}#{d.fragment.obj_idx} "
                f"({d.detail})"
            )
            shown += 1
        return "\n".join(lines)

    def explain(self, *, max_fragments: int = 12) -> str:
        """Render the logical plan, the optimizer passes, and the lowered
        physical tasks with per-fragment placement/cache/hedge state.

        Join plans add a ``== join ==`` section (strategy + pushdown
        decision); rendering it *runs the build side*, because the
        pushed filter is its keys."""
        if self._join_node() is not None:
            return self._explain_join(max_fragments=max_fragments)
        lines = ["== logical plan =="]
        lines += render_plan(self._root)
        plan = lower(_copy_plan(self._root))
        lines.append("== optimizer ==")
        lines += [f"- {p}" for p in plan.passes]
        lines += self._physical_lines(plan, max_fragments)
        pruned = [d for d in plan.decisions if d.action == "pruned"]
        shown = 0
        for d in pruned:
            if shown >= max_fragments:
                lines.append(f"  ... (+{len(pruned) - shown} more pruned)")
                break
            lines.append(
                f"  [-] pruned {d.fragment.path}#{d.fragment.obj_idx} "
                f"({d.detail})"
            )
            shown += 1
        return "\n".join(lines)


def _copy_plan(root: PlanNode) -> PlanNode:
    """Executions must not mutate the builder's logical plan (passes
    annotate Scan nodes, the executor refreshes task limits)."""
    if isinstance(root, Scan):
        return Scan(root.dataset, root.columns)
    kids = root.children()
    clone = dataclasses.replace(root)
    if kids:
        clone.input = _copy_plan(kids[0])  # type: ignore[attr-defined]
    return clone
