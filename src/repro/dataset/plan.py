"""Lazy query plans: one logical IR, one optimizer, one executor.

Every Scanner verb used to carry its own prune/fan-out body, so each new
optimization had to be written three times (``to_table``, ``aggregate``,
``count_rows``).  This module replaces those verb-private paths with a
declarative pipeline:

builder (``Dataset.query()``)
    ``ds.query().select(cols).filter(pred).limit(n)`` /
    ``.aggregate(aggs, group_by=...)`` / ``.count()`` construct a small
    logical-plan IR (Scan / Filter / Project / Aggregate / Limit nodes,
    plus Count sugar) without touching storage.

optimizer (``lower``)
    Named passes rewrite the logical plan and lower it to per-fragment
    physical tasks: ``rewrite_count`` (COUNT(*) is the degenerate
    ungrouped aggregate), ``pushdown_projection`` (decode only referenced
    columns), ``prune_fragments`` (footer-stats pruning; ALL-verdicts
    drop the residual predicate), ``rewrite_metadata_aggregate``
    (aggregates provable from footer stats never touch storage), and
    ``pushdown_limit`` (a row budget truncates the task list at plan time
    and rides into ``scan_op`` so storage nodes stop decoding early).

executor (``execute_scan`` / ``execute_aggregate``)
    One shared streaming engine (the backpressured, admission-bounded
    engine from the streaming-scan PR) runs the physical tasks for every
    verb and every placement via ``FileFormat.execute_task``.  A limit is
    a live row budget: once met, no further fragments are issued and
    still-queued work is cancelled.

``Query.explain()`` renders the logical plan, the optimizer's decisions,
and the per-fragment physical tasks with their placement/cache/hedge
state — the debugging and benchmarking surface for all of the above.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from itertools import islice
from typing import Any, Iterator, Sequence

import numpy as np

from repro.aformat.aggregate import (
    AggSpec,
    AggState,
    DEFAULT_MAX_GROUPS,
    needed_columns,
    parse_aggs,
    partial_from_stats,
)
from repro.aformat.expressions import ALL, And, Cmp, Expr, IsIn, NONE, Not, Or
from repro.aformat.table import Column, Table
from repro.dataset.admission import AdmissionController
from repro.dataset.format import TaskRecord, resolve_format
from repro.dataset.fragment import Fragment

# ---------------------------------------------------------------------------
# Logical plan IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlanNode:
    """Base logical-plan node.  The tree is linear (each node has one
    input); ``Scan`` is the leaf."""

    def children(self) -> list["PlanNode"]:
        return []


@dataclasses.dataclass
class Scan(PlanNode):
    """Leaf: read a Dataset's fragments.  ``columns`` is filled in by the
    projection-pushdown pass (None = every column)."""

    dataset: Any
    columns: tuple[str, ...] | None = None


@dataclasses.dataclass
class Filter(PlanNode):
    input: PlanNode
    predicate: Expr

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Project(PlanNode):
    input: PlanNode
    columns: tuple[str, ...]

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Aggregate(PlanNode):
    input: PlanNode
    specs: tuple[AggSpec, ...]
    group_by: str | None = None
    max_groups: int = DEFAULT_MAX_GROUPS

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Limit(PlanNode):
    input: PlanNode
    n: int

    def children(self):
        return [self.input]


@dataclasses.dataclass
class Count(PlanNode):
    """Builder sugar for ``.count()``; the ``rewrite_count`` pass lowers
    it to the degenerate ungrouped COUNT(*) Aggregate."""

    input: PlanNode

    def children(self):
        return [self.input]


def render_expr(e: Expr | None) -> str:
    if e is None:
        return "true"
    if isinstance(e, Cmp):
        return f"{e.column} {e.op} {e.value!r}"
    if isinstance(e, And):
        return f"({render_expr(e.lhs)} & {render_expr(e.rhs)})"
    if isinstance(e, Or):
        return f"({render_expr(e.lhs)} | {render_expr(e.rhs)})"
    if isinstance(e, Not):
        return f"~({render_expr(e.expr)})"
    if isinstance(e, IsIn):
        return f"{e.column} in {e.values!r}"
    return repr(e)


def render_plan(root: PlanNode) -> list[str]:
    """Indented one-node-per-line rendering of a logical plan."""

    def label(n: PlanNode) -> str:
        if isinstance(n, Scan):
            ds = n.dataset
            cols = "*" if n.columns is None else ", ".join(n.columns)
            return (
                f"Scan[{ds.layout}, fragments={len(ds._fragments)}, "
                f"rows={ds.num_rows}, columns={cols}]"
            )
        if isinstance(n, Filter):
            return f"Filter[{render_expr(n.predicate)}]"
        if isinstance(n, Project):
            return f"Project[{', '.join(n.columns)}]"
        if isinstance(n, Aggregate):
            aggs = ", ".join(s.name for s in n.specs)
            by = f", group_by={n.group_by}" if n.group_by else ""
            return f"Aggregate[{aggs}{by}]"
        if isinstance(n, Limit):
            return f"Limit[n={n.n}]"
        if isinstance(n, Count):
            return "Count[]"
        return type(n).__name__

    lines: list[str] = []
    node, depth = root, 0
    while node is not None:
        lines.append("  " * depth + label(node))
        kids = node.children()
        node = kids[0] if kids else None
        depth += 1
    return lines


# ---------------------------------------------------------------------------
# Optimizer passes (logical -> logical, then logical -> physical)
# ---------------------------------------------------------------------------


def rewrite_count(root: PlanNode) -> PlanNode:
    """COUNT(*) is the degenerate ungrouped aggregate: rewrite the Count
    sugar node so one aggregation path serves both verbs (and the
    metadata / ``rowcount_op`` fast paths apply automatically)."""
    if isinstance(root, Count):
        return Aggregate(root.input, (AggSpec("count"),), None)
    kids = root.children()
    if kids:
        root.input = rewrite_count(kids[0])  # type: ignore[attr-defined]
    return root


@dataclasses.dataclass
class _QuerySpec:
    """A validated, normalized view of the (linear) logical plan."""

    scan: Scan
    predicate: Expr | None
    project: tuple[str, ...] | None
    aggregate: Aggregate | None
    limit: int | None


def _decompose(root: PlanNode) -> _QuerySpec:
    predicate: Expr | None = None
    project: tuple[str, ...] | None = None
    aggregate: Aggregate | None = None
    limit: int | None = None
    seen_relational = False
    node = root
    while not isinstance(node, Scan):
        if isinstance(node, Limit):
            if aggregate is not None:
                # a Limit *below* the aggregate would mean "aggregate
                # any n rows" — refused at build time too (see
                # Query._require_unlimited)
                raise ValueError(
                    "aggregate()/count() over a limit()ed input is not "
                    "supported"
                )
            limit = node.n if limit is None else min(limit, node.n)
        elif isinstance(node, Aggregate):
            if aggregate is not None:
                raise ValueError("nested aggregates are not supported")
            if seen_relational:
                raise ValueError(
                    "filter()/select() above aggregate() is not supported"
                )
            aggregate = node
        elif isinstance(node, Project):
            seen_relational = True
            if project is None:  # outermost projection wins
                project = tuple(node.columns)
        elif isinstance(node, Filter):
            seen_relational = True
            predicate = (
                node.predicate
                if predicate is None
                else And(node.predicate, predicate)
            )
        elif isinstance(node, Count):
            raise ValueError("Count node left in plan: run rewrite_count")
        else:
            raise ValueError(f"unknown plan node {type(node).__name__}")
        node = node.children()[0]
    return _QuerySpec(node, predicate, project, aggregate, limit)


def pushdown_projection(
    spec: _QuerySpec, schema
) -> tuple[tuple[str, ...] | None, str]:
    """Columns the scan must decode: for a plain scan, the projected
    output columns (predicate columns are decoded transiently by
    ``scan_row_group`` itself); for an aggregate, exactly the columns the
    aggregate kernel references.  Returns (columns, explain note)."""
    if spec.aggregate is not None:
        if schema is None or len(schema) == 0:
            # an empty dataset (e.g. a mutable dataset before its first
            # append) has no columns to decode — and no tasks to decode
            # them in; only schema-free aggregates (COUNT(*)) get here,
            # the builder rejects column-referencing ones up front
            return None, "empty dataset: nothing to decode"
        cols = tuple(
            needed_columns(
                list(spec.aggregate.specs),
                spec.aggregate.group_by,
                schema,
                spec.predicate,
            )
        )
        return cols, f"aggregate references [{', '.join(cols)}]"
    if spec.project is not None:
        return spec.project, f"scan ships [{', '.join(spec.project)}]"
    return None, "no projection (all columns ship)"


@dataclasses.dataclass
class FragmentDecision:
    """One fragment's fate through the optimizer, for ``explain()``."""

    fragment: Fragment
    action: str  # "pruned" | "metadata" | "task" | "limit-dropped"
    detail: str = ""


def prune_fragments(
    fragments: Sequence[Fragment], predicate: Expr | None
) -> tuple[list[tuple[Fragment, Expr | None]], list[FragmentDecision]]:
    """Footer-stats pruning: NONE-verdict fragments are dropped, ALL
    verdicts drop the residual predicate (the fragment is taken whole).

    Snapshot tombstones (``Fragment.tombstone``) are folded in here —
    the one choke point every verb and placement lowers through: a
    fragment whose stats prove the tombstone deletes *every* row is
    dropped; one whose stats prove it deletes *none* scans clean; the
    rest carry ``NOT(tombstone)`` conjoined into their residual
    predicate, so deleted rows are filtered at whatever placement runs
    the scan.  Fragment stats are physical (pre-delete), which keeps
    both verdicts exact: NONE/ALL over a superset of the live rows still
    hold for the live rows.
    """
    survivors: list[tuple[Fragment, Expr | None]] = []
    decisions: list[FragmentDecision] = []
    for frag in fragments:
        pred = predicate
        tomb = frag.tombstone
        if tomb is not None and frag.stats:
            verdict = tomb.prune(frag.stats)
            if verdict == NONE:
                tomb = None  # stats prove no deleted rows live here
            elif verdict == ALL:
                decisions.append(
                    FragmentDecision(
                        frag, "pruned", "tombstone deletes every row"
                    )
                )
                continue
        if pred is not None and frag.stats:
            verdict = pred.prune(frag.stats)
            if verdict == NONE:
                decisions.append(
                    FragmentDecision(frag, "pruned", "stats prove NONE")
                )
                continue
            if verdict == ALL:
                pred = None
        if tomb is not None:
            anti = Not(tomb)
            pred = anti if pred is None else And(pred, anti)
        survivors.append((frag, pred))
    return survivors, decisions


def rewrite_metadata_aggregate(
    survivors: Sequence[tuple[Fragment, Expr | None]],
    specs: Sequence[AggSpec],
    group_by: str | None,
    schema,
) -> tuple[
    list[tuple[Fragment, Expr | None]], AggState, list[FragmentDecision]
]:
    """Zero-I/O rewrite: ungrouped aggregates over predicate-free
    fragments answerable from footer statistics merge straight into the
    seed state; only the rest become physical tasks."""
    state = AggState.empty(list(specs), group_by)
    remaining: list[tuple[Fragment, Expr | None]] = []
    decisions: list[FragmentDecision] = []
    for frag, pred in survivors:
        if pred is None and group_by is None:
            part = None
            if frag.stats:
                part = partial_from_stats(
                    list(specs), frag.stats, frag.num_rows, schema
                )
            elif all(s.op == "count" and s.column is None for s in specs):
                part = AggState(
                    list(specs),
                    None,
                    cells=[int(frag.num_rows) for _ in specs],
                    rows=frag.num_rows,
                )
            if part is not None:
                state.merge(part)
                decisions.append(
                    FragmentDecision(
                        frag, "metadata", f"footer answers {frag.num_rows} rows"
                    )
                )
                continue
        remaining.append((frag, pred))
    return remaining, state, decisions


def pushdown_limit(
    survivors: Sequence[tuple[Fragment, Expr | None]], limit: int | None
) -> tuple[
    list[tuple[Fragment, Expr | None]], list[FragmentDecision], int | None
]:
    """Plan-time limit truncation: walking plan order, once predicate-free
    fragments alone guarantee ``limit`` rows, every later fragment is
    dropped before any I/O is planned for it.  The returned budget is
    enforced again at run time (early exit) for the fragments that carry
    residual predicates."""
    if limit is None:
        return list(survivors), [], None
    kept: list[tuple[Fragment, Expr | None]] = []
    decisions: list[FragmentDecision] = []
    guaranteed = 0
    for frag, pred in survivors:
        if guaranteed >= limit:
            decisions.append(
                FragmentDecision(
                    frag, "limit-dropped", f"{guaranteed} rows already sure"
                )
            )
            continue
        kept.append((frag, pred))
        if pred is None:
            guaranteed += frag.num_rows
    return kept, decisions, limit


# ---------------------------------------------------------------------------
# Physical plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FragmentTask:
    """One unit of physical work: scan or partially aggregate one
    fragment at whatever placement the FileFormat picks.  ``limit`` is
    refreshed by the executor to the live remaining row budget just
    before the task is issued."""

    index: int
    kind: str  # "scan" | "aggregate"
    fragment: Fragment
    columns: Sequence[str] | None = None
    predicate: Expr | None = None
    specs: Sequence[AggSpec] | None = None
    group_by: str | None = None
    max_groups: int = DEFAULT_MAX_GROUPS
    schema: Any = None
    limit: int | None = None


@dataclasses.dataclass
class PhysicalPlan:
    """The optimized, lowered plan: per-fragment tasks plus everything
    the optimizer already answered without I/O."""

    kind: str  # "scan" | "aggregate"
    dataset: Any
    tasks: list[FragmentTask]
    decisions: list[FragmentDecision]
    passes: list[str]
    columns: list[str] | None = None  # scan output projection
    specs: list[AggSpec] | None = None
    group_by: str | None = None
    max_groups: int = DEFAULT_MAX_GROUPS
    limit: int | None = None
    metadata_state: AggState | None = None
    metadata_answers: int = 0
    fragments_total: int = 0
    fragments_pruned: int = 0


def lower(root: PlanNode) -> PhysicalPlan:
    """Run every optimizer pass and lower the logical plan to per-fragment
    physical tasks."""
    passes: list[str] = []
    had_count = isinstance(root, Count) or any(
        isinstance(n, Count) for n in _walk(root)
    )
    root = rewrite_count(root)
    if had_count:
        passes.append("count-as-aggregate: COUNT(*) lowered to Aggregate")
    spec = _decompose(root)
    ds = spec.scan.dataset
    schema = ds.schema

    scan_cols, note = pushdown_projection(spec, schema)
    spec.scan.columns = scan_cols
    passes.append(f"projection-pushdown: {note}")

    fragments = list(ds._fragments)
    survivors, prune_dec = prune_fragments(fragments, spec.predicate)
    n_all = sum(
        1
        for (f, p) in survivors
        if p is None and spec.predicate is not None
    )
    passes.append(
        f"stats-pruning: {len(prune_dec)} of {len(fragments)} fragments "
        f"pruned, {n_all} predicate-free after ALL verdicts"
    )

    decisions = list(prune_dec)
    meta_state: AggState | None = None
    meta_answers = 0
    if spec.aggregate is not None:
        agg = spec.aggregate
        survivors, meta_state, meta_dec = rewrite_metadata_aggregate(
            survivors, agg.specs, agg.group_by, schema
        )
        meta_answers = len(meta_dec)
        decisions.extend(meta_dec)
        passes.append(
            f"metadata-rewrite: {meta_answers} fragments answered from "
            "footer stats (zero I/O)"
        )
        tasks = [
            FragmentTask(
                i,
                "aggregate",
                frag,
                predicate=pred,
                specs=list(agg.specs),
                group_by=agg.group_by,
                max_groups=agg.max_groups,
                schema=schema,
            )
            for i, (frag, pred) in enumerate(survivors)
        ]
        limit = spec.limit  # applies to the finalized table client-side
    else:
        survivors, limit_dec, limit = pushdown_limit(survivors, spec.limit)
        if spec.limit is not None:
            passes.append(
                f"limit-pushdown: row budget {spec.limit}; plan truncated "
                f"to {len(survivors)} tasks ({len(limit_dec)} dropped), "
                "budget rides into scan_op"
            )
        decisions.extend(limit_dec)
        tasks = [
            FragmentTask(
                i,
                "scan",
                frag,
                columns=list(scan_cols) if scan_cols is not None else None,
                predicate=pred,
                limit=limit,
            )
            for i, (frag, pred) in enumerate(survivors)
        ]
    decisions.extend(
        FragmentDecision(t.fragment, "task", render_expr(t.predicate))
        for t in tasks
    )
    return PhysicalPlan(
        kind="scan" if spec.aggregate is None else "aggregate",
        dataset=ds,
        tasks=tasks,
        decisions=decisions,
        passes=passes,
        columns=list(scan_cols)
        if scan_cols is not None and spec.aggregate is None
        else None,
        specs=list(spec.aggregate.specs) if spec.aggregate else None,
        group_by=spec.aggregate.group_by if spec.aggregate else None,
        max_groups=spec.aggregate.max_groups
        if spec.aggregate
        else DEFAULT_MAX_GROUPS,
        limit=limit if spec.aggregate is None else spec.limit,
        metadata_state=meta_state,
        metadata_answers=meta_answers,
        fragments_total=len(fragments),
        fragments_pruned=len(prune_dec),
    )


def _walk(root: PlanNode) -> Iterator[PlanNode]:
    node: PlanNode | None = root
    while node is not None:
        yield node
        kids = node.children()
        node = kids[0] if kids else None


# ---------------------------------------------------------------------------
# Scan metrics (every verb records these uniformly)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScanMetrics:
    tasks: list[TaskRecord] = dataclasses.field(default_factory=list)
    fragments_total: int = 0
    fragments_pruned: int = 0
    metadata_answers: int = 0  # fragments answered from footer stats
    discovery_bytes: int = 0
    rows: int = 0
    wall_s: float = 0.0
    admission: dict = dataclasses.field(default_factory=dict)

    @property
    def client_cpu_s(self) -> float:
        return sum(t.client_cpu_s for t in self.tasks)

    @property
    def osd_cpu_s(self) -> float:
        return sum(t.cpu_s for t in self.tasks if t.where == "osd")

    @property
    def wire_bytes(self) -> int:
        return self.discovery_bytes + sum(t.wire_bytes for t in self.tasks)

    @property
    def cache_hits(self) -> int:
        return sum(1 for t in self.tasks if t.cached)

    @property
    def hedged_tasks(self) -> int:
        return sum(1 for t in self.tasks if t.hedged)

    def summary(self) -> dict:
        return {
            "fragments": self.fragments_total,
            "pruned": self.fragments_pruned,
            "metadata_answers": self.metadata_answers,
            "rows": self.rows,
            "wire_bytes": self.wire_bytes,
            "client_cpu_s": round(self.client_cpu_s, 4),
            "osd_cpu_s": round(self.osd_cpu_s, 4),
            "wall_s": round(self.wall_s, 4),
            "cache_hits": self.cache_hits,
            "hedged": self.hedged_tasks,
            "admission_waits": self.admission.get("waits", 0),
        }


# ---------------------------------------------------------------------------
# The shared streaming executor
# ---------------------------------------------------------------------------


def stream_tasks(
    plan: PhysicalPlan,
    fmt,
    metrics: ScanMetrics,
    *,
    max_inflight: int,
    queue_depth: int,
) -> Iterator[tuple[FragmentTask, Any]]:
    """Run the plan's fragment tasks through ``fmt.execute_task`` with at
    most ``max_inflight`` in flight, issuing new work only as finished
    work is consumed (backpressure) and per-OSD pressure bounded by one
    shared AdmissionController.

    Yields (task, Table | AggState) in completion order.  For scan plans
    with a limit, the live row budget stops issuance the moment it is
    met and cancels still-queued tasks — fragments past the budget are
    never scanned."""
    ds = plan.dataset
    admission = AdmissionController(ds.fs.store, queue_depth)
    lock = threading.Lock()
    remaining = plan.limit if plan.kind == "scan" else None

    def run(task: FragmentTask):
        out, rec = fmt.execute_task(ds.fs, task, admission=admission)
        with lock:
            metrics.tasks.append(rec)
        return task, out

    t0 = time.perf_counter()
    try:
        tasks = plan.tasks
        if max_inflight <= 1 or len(tasks) <= 1:
            for task in tasks:
                if remaining is not None:
                    if remaining <= 0:
                        return
                    task.limit = remaining
                task, out = run(task)
                if remaining is not None:
                    remaining -= len(out)
                yield task, out
            return
        it = iter(tasks)

        def submit(pool, task):
            if remaining is not None:
                task.limit = remaining
            return pool.submit(run, task)

        with ThreadPoolExecutor(max_workers=max_inflight) as pool:
            pending = {
                submit(pool, t) for t in islice(it, max_inflight)
            }
            try:
                while pending:
                    done, pending = wait(
                        pending, return_when=FIRST_COMPLETED
                    )
                    for fut in done:
                        task, out = fut.result()
                        if remaining is not None:
                            remaining -= len(out)
                        if remaining is None or remaining > 0:
                            nxt = next(it, None)
                            if nxt is not None:
                                pending.add(submit(pool, nxt))
                        yield task, out
                        if remaining is not None and remaining <= 0:
                            return  # budget met: cancel queued work
            finally:
                for fut in pending:  # consumer stopped early / budget met
                    fut.cancel()
    finally:
        metrics.wall_s = time.perf_counter() - t0
        metrics.admission = admission.stats()


def empty_table(schema, columns: Sequence[str] | None) -> Table:
    if schema is None:  # e.g. a mutable dataset with no appends yet
        from repro.aformat.schema import Schema

        return Table(Schema(()), [])
    names = list(columns) if columns is not None else schema.names
    sch = schema.select(names)
    return Table(
        sch,
        [
            Column(
                f,
                np.empty(0, object if f.type == "string" else f.numpy_dtype),
            )
            for f in sch
        ],
    )


# ---------------------------------------------------------------------------
# The Query builder
# ---------------------------------------------------------------------------


class Query:
    """Lazy, composable query over a Dataset.

    Builder verbs (``select`` / ``filter`` / ``limit`` / ``aggregate`` /
    ``count``) only grow the logical plan; nothing touches storage until
    ``to_table`` / ``to_batches`` / ``to_scalar`` runs it through the
    optimizer and the shared streaming executor.  ``explain()`` shows
    what would run.  ``metrics`` holds the last execution's ScanMetrics
    (each run gets a fresh snapshot)."""

    def __init__(
        self,
        ds,
        *,
        format="pushdown",
        num_threads: int = 16,
        queue_depth: int = 4,
        _root: PlanNode | None = None,
        _scalar: bool = False,
    ):
        self.ds = ds
        self.fmt = resolve_format(format)
        self.num_threads = num_threads
        self.queue_depth = queue_depth
        self._root = _root if _root is not None else Scan(ds)
        self._scalar = _scalar
        self.metrics = ScanMetrics(discovery_bytes=ds.discovery_bytes)

    # -- builder -----------------------------------------------------------
    def _derive(self, root: PlanNode, *, scalar: bool | None = None):
        q = Query.__new__(Query)
        q.ds = self.ds
        q.fmt = self.fmt
        q.num_threads = self.num_threads
        q.queue_depth = self.queue_depth
        q._root = root
        q._scalar = self._scalar if scalar is None else scalar
        q.metrics = ScanMetrics(discovery_bytes=self.ds.discovery_bytes)
        return q

    @property
    def _has_aggregate(self) -> bool:
        return any(
            isinstance(n, (Aggregate, Count)) for n in _walk(self._root)
        )

    def _require_relational(self, verb: str):
        if self._has_aggregate:
            raise ValueError(
                f"{verb} cannot be applied after aggregate()/count()"
            )

    def _require_unlimited(self, verb: str):
        # aggregating "any n rows" has no well-defined answer here: the
        # executor would have to fold a nondeterministic subset.  Refuse
        # rather than silently aggregate the whole input.  (limit() on
        # top of an aggregate — trimming the finalized group rows — is
        # fine and stays supported.)
        if any(isinstance(n, Limit) for n in _walk(self._root)):
            raise ValueError(f"{verb} over a limit()ed input is not supported")

    def select(self, *columns) -> "Query":
        """Project the output to ``columns`` (names; the last select
        wins).  Accepts either ``select("a", "b")`` or a single
        list/tuple."""
        self._require_relational("select()")
        if len(columns) == 1 and isinstance(columns[0], (list, tuple)):
            columns = tuple(columns[0])
        if not columns:
            raise ValueError("select() needs at least one column")
        if self.ds.schema is None:
            raise ValueError("select() on a dataset with no schema "
                             "(empty dataset)")
        for c in columns:
            if not isinstance(c, str):
                raise TypeError(
                    f"select() takes column names, got {type(c).__name__}"
                )
            self.ds.schema.field(c)  # validate early
        return self._derive(Project(self._root, tuple(columns)))

    def filter(self, predicate: Expr) -> "Query":
        """Keep rows matching ``predicate``; chained filters AND."""
        self._require_relational("filter()")
        if not isinstance(predicate, Expr):
            raise TypeError("filter() takes an Expr predicate")
        return self._derive(Filter(self._root, predicate))

    def limit(self, n: int) -> "Query":
        """At most ``n`` rows (any n rows: fragment completion order is
        nondeterministic, like SQL LIMIT without ORDER BY)."""
        if not isinstance(n, int) or n <= 0:
            raise ValueError(f"limit must be a positive int, got {n!r}")
        return self._derive(Limit(self._root, n))

    def aggregate(
        self,
        aggs,
        *,
        group_by: str | None = None,
        max_groups: int = DEFAULT_MAX_GROUPS,
    ) -> "Query":
        """SUM/MIN/MAX/MEAN/COUNT, optionally GROUP BY one key column."""
        self._require_relational("aggregate()")
        self._require_unlimited("aggregate()")
        specs = parse_aggs(aggs)
        if not specs:
            raise ValueError("aggregate() needs at least one aggregate")
        refs_columns = group_by is not None or any(
            s.column is not None for s in specs
        )
        if self.ds.schema is None and refs_columns:
            raise ValueError(
                "aggregate() referencing columns on a dataset with no "
                "schema (empty dataset); only COUNT(*) is answerable"
            )
        for s in specs:
            if s.column is not None:
                self.ds.schema.field(s.column)
        if group_by is not None:
            self.ds.schema.field(group_by)
        return self._derive(
            Aggregate(self._root, tuple(specs), group_by, max_groups)
        )

    def count(self) -> "Query":
        """COUNT(*): a scalar query (``to_scalar`` returns the int)."""
        self._require_relational("count()")
        self._require_unlimited("count()")
        return self._derive(Count(self._root), scalar=True)

    # -- plan access -------------------------------------------------------
    def logical_plan(self) -> PlanNode:
        return self._root

    def physical_plan(self) -> PhysicalPlan:
        """Optimize + lower (no execution)."""
        return lower(_copy_plan(self._root))

    # -- execution ---------------------------------------------------------
    def _begin(self, plan: PhysicalPlan) -> ScanMetrics:
        """Fresh per-execution metrics snapshot; ``self.metrics`` always
        refers to the latest run."""
        m = ScanMetrics(
            discovery_bytes=self.ds.discovery_bytes,
            fragments_total=plan.fragments_total,
            fragments_pruned=plan.fragments_pruned,
            metadata_answers=plan.metadata_answers,
        )
        self.metrics = m
        return m

    def to_batches(
        self, *, max_inflight: int | None = None
    ) -> Iterator[Table]:
        """Stream per-fragment Tables in completion order under the row
        budget; empty fragments are skipped."""
        plan = lower(_copy_plan(self._root))
        if plan.kind != "scan":
            raise ValueError(
                "to_batches() streams scans; aggregate queries "
                "materialize via to_table()"
            )
        metrics = self._begin(plan)
        remaining = plan.limit

        def gen():
            nonlocal remaining
            for _task, tbl in stream_tasks(
                plan,
                self.fmt,
                metrics,
                max_inflight=max_inflight or self.num_threads,
                queue_depth=self.queue_depth,
            ):
                if remaining is not None:
                    tbl = tbl.head(remaining)
                    remaining -= len(tbl)
                if len(tbl):
                    metrics.rows += len(tbl)
                    yield tbl

        return gen()

    def to_table(self) -> Table:
        """Materialize the result (scan plans reassemble fragments in
        plan order; aggregates finalize the merged partial state)."""
        plan = lower(_copy_plan(self._root))
        metrics = self._begin(plan)
        if plan.kind == "aggregate":
            state = plan.metadata_state
            for _task, part in stream_tasks(
                plan,
                self.fmt,
                metrics,
                max_inflight=self.num_threads,
                queue_depth=self.queue_depth,
            ):
                state.merge(part)  # completion order
            metrics.rows = state.rows
            out = state.finalize(self.ds.schema)
            if plan.limit is not None:
                out = out.head(plan.limit)
            return out
        parts = sorted(
            stream_tasks(
                plan,
                self.fmt,
                metrics,
                max_inflight=self.num_threads,
                queue_depth=self.queue_depth,
            ),
            key=lambda p: p[0].index,
        )
        tables = [t for _, t in parts if len(t)]
        result = (
            Table.concat(tables)
            if tables
            else empty_table(self.ds.schema, plan.columns)
        )
        if plan.limit is not None:
            result = result.head(plan.limit)
        metrics.rows = len(result)
        return result

    def to_scalar(self):
        """Run a single-cell query (e.g. ``count()``) to its scalar."""
        out = self.to_table()
        if len(out) != 1 or len(out.schema) != 1:
            raise ValueError(
                f"to_scalar() needs a 1x1 result, got "
                f"{len(out)}x{len(out.schema)}"
            )
        v = out.columns[0].values[0]
        return v.item() if isinstance(v, np.generic) else v

    # -- explain -----------------------------------------------------------
    def explain(self, *, max_fragments: int = 12) -> str:
        """Render the logical plan, the optimizer passes, and the lowered
        physical tasks with per-fragment placement/cache/hedge state."""
        lines = ["== logical plan =="]
        lines += render_plan(self._root)
        plan = lower(_copy_plan(self._root))
        lines.append("== optimizer ==")
        lines += [f"- {p}" for p in plan.passes]
        lines.append("== physical plan ==")
        budget = (
            f", row_budget={plan.limit}" if plan.limit is not None else ""
        )
        lines.append(
            f"executor: streaming, format={self.fmt.name}, "
            f"max_inflight={self.num_threads}, "
            f"queue_depth={self.queue_depth}/OSD{budget}"
        )
        lines.append(
            f"fragments: {plan.fragments_total} total, "
            f"{plan.fragments_pruned} pruned, "
            f"{plan.metadata_answers} metadata-answered, "
            f"{len(plan.tasks)} tasks"
        )
        shown = 0
        for task in plan.tasks:
            if shown >= max_fragments:
                lines.append(f"  ... (+{len(plan.tasks) - shown} more tasks)")
                break
            frag = task.fragment
            where = self.fmt.explain_task(self.ds.fs, task)
            lim = f" limit<={task.limit}" if task.limit is not None else ""
            lines.append(
                f"  [{task.index}] {task.kind} {frag.path}#{frag.obj_idx} "
                f"rows={frag.num_rows} pred={render_expr(task.predicate)}"
                f"{lim} | {where}"
            )
            shown += 1
        return "\n".join(lines)


def _copy_plan(root: PlanNode) -> PlanNode:
    """Executions must not mutate the builder's logical plan (passes
    annotate Scan nodes, the executor refreshes task limits)."""
    if isinstance(root, Scan):
        return Scan(root.dataset, root.columns)
    kids = root.children()
    clone = dataclasses.replace(root)
    if kids:
        clone.input = _copy_plan(kids[0])  # type: ignore[attr-defined]
    return clone
