"""Fragments: the self-contained scan units of a Dataset.

A Fragment is exactly the paper's unit of parallelism — one row group,
guaranteed (by the Striped / Split / Flat layouts) to live inside a single
RADOS object, so it can be scanned either by the client (reading bytes
through CephFS) or by the storage node itself (``scan_op`` via
DirectObjectAccess) without touching any other object.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.aformat import parquet
from repro.aformat.expressions import Expr
from repro.aformat.statistics import ColumnStats


@dataclasses.dataclass
class Fragment:
    """One row group, self-contained in one object.

    path         CephFS path whose object holds the row group (for split
                 layout this is the per-row-group file, for striped/flat the
                 parent file).
    obj_idx      index of the object within the file's striping sequence.
    rg_in_object index of the row group within the footer that ``scan_op``
                 will see for that object (0 for split/striped fragments).
    num_rows     row count (pre-filter).
    stats        per-column min/max/null stats for client-side pruning.
    footer       FileMeta to hand to ``scan_op`` (striped layout passes the
                 rebased parent footer; None = object carries its own).
    """

    path: str
    obj_idx: int
    rg_in_object: int
    num_rows: int
    stats: Mapping[str, ColumnStats] | None = None
    footer: parquet.FileMeta | None = None
    # client-scan path: where the row group lives inside `path`
    client_meta: parquet.FileMeta | None = None
    client_rg_index: int = 0
    # snapshot layer (repro.dataset.snapshot): rows matching this
    # predicate are deleted in the fragment's snapshot; the optimizer
    # conjoins NOT(tombstone) into the fragment's residual predicate so
    # deleted rows never resurface at any placement.  num_rows/stats
    # stay the *physical* (pre-delete) values — correct for pruning,
    # excluded from metadata-only answers while a tombstone is live.
    tombstone: Expr | None = None

    def describe(self) -> dict[str, Any]:
        return {"path": self.path, "obj_idx": self.obj_idx,
                "rows": self.num_rows}
