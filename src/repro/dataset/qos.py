"""Multi-tenant QoS: the TaskContext boundary, tenant registry, and the
typed ``Shed`` result.

One dataclass — :class:`TaskContext` — is how *every* task option
reaches the execution stack.  It collapses the kwarg tail that used to
grow on ``FileFormat.scan_fragment`` / ``aggregate_fragment`` /
``execute_task`` (``admission=``, ``limit=``, ``selectivity_hint=``, and
now tenant / lane / deadline) into one argument with one signature
across all three formats, the adaptive scheduler, and the streaming
executor.

:class:`TenantRegistry` is the control plane: it holds each tenant's
:class:`TenantSpec` (weight, priority lane, deadline, cache budget),
hands out one shared
:class:`~repro.dataset.admission.AdmissionController` per cluster so
every tenant's scans are arbitrated by the same weighted-fair slot
allocator, and rolls completed runs up into ``by_tenant()``.

A query that cannot meet its deadline returns a :class:`Shed` — a typed
result carrying tenant, lane, reason, and progress — instead of raising
from a worker thread; under ``shed_policy="degrade"`` a scan's ``Shed``
also carries the partial table assembled before the deadline hit.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Any

from repro.dataset.admission import (AdmissionController, DEFAULT_LANE,
                                     LANES)

__all__ = ["INGEST_TENANT", "LANES", "Shed", "TaskContext", "TenantRegistry",
           "TenantSpec", "as_task_context", "ingest_context",
           "resolve_context"]

_UNSET = object()

#: The tenant name training ingest runs as by default — a bulk-lane
#: large-batch reader that weighted-fair admission arbitrates against
#: interactive scanners (see :func:`ingest_context`).
INGEST_TENANT = "ingest"


@dataclasses.dataclass
class TaskContext:
    """Everything a fragment task runs *as*: identity (tenant, lane,
    weight), obligations (deadline, shed policy), and the per-task
    options the executor threads through (admission controller, live row
    budget, selectivity hint).  ``TaskContext()`` is the default tenant
    and reproduces the historic single-tenant behavior exactly."""

    tenant: str = "default"
    lane: str = DEFAULT_LANE
    weight: float = 1.0
    deadline_s: float | None = None
    shed_policy: str = "reject"          # "reject" | "degrade"
    admission: AdmissionController | None = None
    limit: int | None = None
    selectivity_hint: float | None = None
    registry: "TenantRegistry | None" = None
    started_at: float | None = None      # perf_counter at execution start

    def elapsed_s(self) -> float:
        if self.started_at is None:
            return 0.0
        return time.perf_counter() - self.started_at

    def remaining_s(self) -> float | None:
        """Seconds left on the deadline (None = no deadline armed)."""
        if self.deadline_s is None or self.started_at is None:
            return None
        return self.deadline_s - self.elapsed_s()


@dataclasses.dataclass
class Shed:
    """A query rejected (or degraded) because it could not meet its
    deadline at current load — returned by the run verbs in place of a
    table, never raised.  ``partial`` carries the fragments completed
    before the shed under ``shed_policy="degrade"`` (scans only)."""

    tenant: str
    lane: str
    reason: str
    deadline_s: float
    elapsed_s: float
    completed_tasks: int
    total_tasks: int
    partial: Any = None

    def __str__(self):
        return (f"Shed(tenant={self.tenant!r}, lane={self.lane}, "
                f"{self.completed_tasks}/{self.total_tasks} tasks in "
                f"{self.elapsed_s * 1e3:.1f}ms of {self.deadline_s * 1e3:.1f}"
                f"ms: {self.reason})")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's registered QoS contract."""

    name: str
    weight: float = 1.0
    lane: str = DEFAULT_LANE
    deadline_s: float | None = None
    cache_bytes: int | None = None       # per-tenant result-cache budget
    shed_policy: str = "reject"


class TenantRegistry:
    """The tenants sharing a cluster and the machinery they share.

    ``register()`` declares a tenant; ``context()`` mints the
    :class:`TaskContext` its queries run under; ``controller()`` returns
    the one :class:`AdmissionController` per cluster through which every
    registered tenant's storage work is arbitrated (the whole point —
    per-scan controllers cannot see each other's load).  Completed runs
    are recorded automatically by the executor; ``by_tenant()`` merges
    those rollups with the controllers' live admission stats."""

    def __init__(self, *, slots_per_osd: int = 4, preempt_slack: int = 1):
        self.slots_per_osd = slots_per_osd
        self.preempt_slack = preempt_slack
        self._specs: dict[str, TenantSpec] = {
            "default": TenantSpec("default")}
        self._controllers: dict[int, AdmissionController] = {}
        self._rollup: dict[str, dict] = {}
        self._lock = threading.Lock()

    def register(self, name: str, *, weight: float = 1.0,
                 lane: str = DEFAULT_LANE,
                 deadline_s: float | None = None,
                 cache_bytes: int | None = None,
                 shed_policy: str = "reject") -> TenantSpec:
        if lane not in LANES:
            raise ValueError(f"lane must be one of {LANES}, got {lane!r}")
        if not weight > 0:
            raise ValueError(f"weight must be > 0, got {weight!r}")
        if shed_policy not in ("reject", "degrade"):
            raise ValueError(
                f"shed_policy must be 'reject' or 'degrade', "
                f"got {shed_policy!r}")
        spec = TenantSpec(name, weight, lane, deadline_s, cache_bytes,
                          shed_policy)
        with self._lock:
            self._specs[name] = spec
        return spec

    def ensure(self, name: str, **kwargs) -> TenantSpec:
        """``register()`` if the tenant is not yet known, else the
        existing spec unchanged — idempotent registration for callers
        (like the ingest reader) that may race or restart."""
        with self._lock:
            spec = self._specs.get(name)
        if spec is not None:
            return spec
        return self.register(name, **kwargs)

    def spec(self, name: str) -> TenantSpec:
        """The registered spec, or an unweighted bulk default for an
        unknown tenant (unregistered traffic is assumed analytics)."""
        with self._lock:
            spec = self._specs.get(name)
        return spec if spec is not None else TenantSpec(name)

    def context(self, name: str, *, deadline_s=_UNSET) -> TaskContext:
        """A TaskContext running as tenant ``name`` under its registered
        contract; ``deadline_s=`` overrides the spec's per-query."""
        s = self.spec(name)
        return TaskContext(
            tenant=s.name, lane=s.lane, weight=s.weight,
            deadline_s=s.deadline_s if deadline_s is _UNSET else deadline_s,
            shed_policy=s.shed_policy, registry=self)

    def controller(self, store) -> AdmissionController:
        """The shared per-cluster admission controller (created on first
        use, one per ObjectStore)."""
        with self._lock:
            ctrl = self._controllers.get(id(store))
            if ctrl is None:
                ctrl = AdmissionController(
                    store, self.slots_per_osd,
                    preempt_slack=self.preempt_slack)
                self._controllers[id(store)] = ctrl
            return ctrl

    def record(self, metrics) -> None:
        """Fold one completed run's ScanMetrics into the per-tenant
        rollup (called by the streaming executor)."""
        with self._lock:
            r = self._rollup.setdefault(metrics.tenant, {
                "runs": 0, "rows": 0, "wire_bytes": 0, "wall_s": 0.0,
                "cache_hits": 0, "sheds": 0})
            r["runs"] += 1
            # recorded from the executor's finally, before the run verb
            # trims/sets metrics.rows — sum the per-task counts instead
            r["rows"] += metrics.rows or sum(t.rows_out
                                             for t in metrics.tasks)
            r["wire_bytes"] += metrics.wire_bytes
            r["wall_s"] += metrics.wall_s
            r["cache_hits"] += metrics.cache_hits
            r["sheds"] += 1 if metrics.shed is not None else 0

    def by_tenant(self) -> dict:
        """Per-tenant QoS report: run rollups merged with the live
        admission stats of every controller this registry owns."""
        out: dict[str, dict] = {}
        with self._lock:
            for tenant, r in self._rollup.items():
                d = dict(r)
                d["wall_s"] = round(d["wall_s"], 6)
                out[tenant] = d
            controllers = list(self._controllers.values())
        for ctrl in controllers:
            for tenant, st in ctrl.stats()["by_tenant"].items():
                d = out.setdefault(tenant, {})
                adm = d.setdefault("admission", {
                    "admitted": 0, "waits": 0, "wait_s": 0.0,
                    "preemptions": 0, "sheds": 0})
                for k, v in st.items():
                    adm[k] = round(adm[k] + v, 6) if k == "wait_s" \
                        else adm[k] + v
        return out


def ingest_context(registry: TenantRegistry | None = None, *,
                   tenant: str = INGEST_TENANT,
                   weight: float = 1.0) -> TaskContext:
    """The TaskContext a training reader scans under: a ``bulk``-lane
    tenant.  With a registry, the tenant is (idempotently) registered
    and the context carries the registry, so ingest admission goes
    through the cluster's shared weighted-fair controller and interactive
    tenants keep their priority-lane edge.  Without one, a standalone
    bulk context (run-private admission, historic behavior)."""
    if registry is None:
        return TaskContext(tenant=tenant, lane="bulk", weight=weight)
    registry.ensure(tenant, weight=weight, lane="bulk")
    return registry.context(tenant)


def as_task_context(value) -> TaskContext:
    """Normalize the ``tenant=`` argument of ``Dataset.query`` /
    ``Scanner``: None (default tenant), a tenant name, or a full
    TaskContext."""
    if value is None:
        return TaskContext()
    if isinstance(value, TaskContext):
        return value
    if isinstance(value, str):
        return TaskContext(tenant=value)
    raise TypeError(
        f"tenant= takes a TaskContext, a tenant name, or None; "
        f"got {type(value).__name__}")


def resolve_context(ctx=None, legacy: dict | None = None) -> TaskContext:
    """The one-release compatibility shim behind every format entry
    point: normalizes ``ctx`` to a TaskContext and adapts the old kwarg
    tail (``admission=`` / ``limit=`` / ``selectivity_hint=``) — or an
    AdmissionController passed positionally where ``ctx`` now lives —
    with a DeprecationWarning."""
    if ctx is not None and not isinstance(ctx, TaskContext):
        if hasattr(ctx, "admit_object"):   # old positional admission=
            warnings.warn(
                "passing an AdmissionController positionally is "
                "deprecated; pass a TaskContext (TaskContext(admission=...))",
                DeprecationWarning, stacklevel=3)
            ctx = TaskContext(admission=ctx)
        else:
            raise TypeError(
                f"ctx must be a TaskContext or None, "
                f"got {type(ctx).__name__}")
    if legacy:
        unknown = set(legacy) - {"admission", "limit", "selectivity_hint"}
        if unknown:
            raise TypeError(
                f"unexpected keyword arguments {sorted(unknown)}; task "
                f"options travel on TaskContext")
        warnings.warn(
            "the admission=/limit=/selectivity_hint= kwarg tail is "
            "deprecated; pass one TaskContext instead "
            "(repro.dataset.qos.TaskContext)",
            DeprecationWarning, stacklevel=3)
        ctx = dataclasses.replace(
            ctx if ctx is not None else TaskContext(),
            **{k: v for k, v in legacy.items() if v is not None})
    return ctx if ctx is not None else TaskContext()
