"""Adaptive scan scheduling: per-fragment placement, hedging, result cache.

The paper's limitation (§4, Fig. 5/6) is that the offload decision is
*static*: ``PushdownParquetFormat`` always scans on the storage node,
``ParquetFormat`` always on the client — but pushdown only wins while the
storage-side CPUs have headroom.  Once OSDs saturate (many clients, or a
straggling node), shipping raw bytes and decoding locally is faster.

:class:`ScanScheduler` turns that decision into a feedback loop, per
fragment, at scan time:

placement
    Price both placements per fragment as amortized cost on the
    bottleneck resource (the same k-server view as
    ``storage.perfmodel``), and run the scan wherever the estimate is
    lower:

    * ``est_storage = max(decode_s * pressure / storage_threads,
      ipc_out_bytes / net_bw)`` — storage CPU is shared by every tenant
      (pressure scales with their in-flight queue depth), the client NIC
      carries only the filtered result;
    * ``est_client = max(raw_in_bytes / net_bw, decode_s /
      client_threads)`` — private client resources, but the NIC carries
      the raw bytes and the client burns the decode itself.

    ``pressure`` comes from :meth:`ObjectStore.load_of` — straggle factor
    scaled by in-flight queue depth — minimized over the fragment's up
    replicas (hedging can reach the fastest one).  Decode rates are
    estimated *per side*: the storage nodes always run the host (numpy)
    decode path, while the client runs whatever ``decode_backend`` its
    format carries (the Pallas engine is ~an order of magnitude faster
    on an accelerator), so one shared EWMA would average two different
    regimes into a number that prices both sides wrong.  Each side's
    EWMA is seeded with its backend's ``decode_rate_prior``; a completed
    scan updates its own side's estimate, and also the other side's when
    the client runs the host (numpy) engine — the same code the OSD
    runs, so observations transfer.  The output-size ratio is a property
    of the data, not the backend, so it stays shared.

hedging
    Storage-side scans carry a deadline of ``hedge_multiplier`` x the
    rolling *median per-byte* storage-scan latency, scaled by the
    fragment's size (size-normalized so big fragments aren't mistaken
    for stragglers; median rather than a high quantile because a
    straggler serving >5% of scans would drag a p95/p99 deadline above
    its own latency and never get hedged).  A call exceeding the
    deadline is re-issued to a replica and the faster result wins
    (``DirectObjectAccess.call_hedged``).  If the storage path fails
    outright (all replicas down mid-scan) the fragment falls back to the
    client-side path.

result cache
    Decoded results are kept as Arrow-IPC bytes in an LRU
    (:class:`ResultCache`) keyed by
    ``(object, version, footer_hash, row_group, columns, predicate_json)``.
    Repeat scans — the common case for dashboard / training-epoch
    workloads — are served without touching the storage tier at all.
    Overwrites bump the object version (``ObjectStore.version_of``) so a
    stale result can never be served.

The scheduler is exposed through ``AdaptiveFormat`` (``format="adaptive"``
on :meth:`Dataset.scanner`), so it drops into the existing Dataset API the
same way the two static placements do.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import threading
import time
from collections import OrderedDict, deque
from typing import Sequence

from repro.aformat.aggregate import (AggState, DEFAULT_MAX_GROUPS,
                                     needed_columns, partial_aggregate)
from repro.aformat.expressions import Expr
from repro.aformat.table import Table
from repro.dataset.admission import LANE_PRIORITY
from repro.dataset.format import (ParquetFormat, TaskRecord, agg_payload,
                                  count_state, is_degenerate_count,
                                  parse_agg_reply, scan_payload)
from repro.dataset.fragment import Fragment
from repro.dataset.qos import TaskContext, resolve_context
from repro.storage.cephfs import CephFS, DirectObjectAccess
from repro.storage.objstore import ObjectNotFound, OSDDownError

GBE10 = 10e9 / 8                 # modeled client NIC (paper testbed)
DEFAULT_DECODE_RATE = 150e6      # storage-side (host/numpy) bytes/s prior
                                 # until the EWMA warms up; the client
                                 # side is seeded from its decode
                                 # backend's own decode_rate_prior
DEFAULT_OUT_RATIO = 1.0          # decoded-IPC-bytes per stored-byte prior:
                                 # neutral, so the cold-start estimates tie
                                 # and the tie-break prefers storage-side
                                 # (no exploration penalty on an idle
                                 # cluster; the first scan teaches the
                                 # real ratio either way)


def modeled_latency(t: TaskRecord, net_bw: float = GBE10) -> float:
    """Per-fragment scan latency under the paper's cluster model: measured
    CPU seconds plus modeled wire time (storage-device time is not modeled,
    matching ``storage.perfmodel``)."""
    if t.cached:
        return t.client_cpu_s
    if t.where == "client":
        return t.wire_bytes / net_bw + t.cpu_s
    return t.cpu_s + t.wire_bytes / net_bw + t.client_cpu_s


class _Ewma:
    """Exponentially weighted running estimate with a cold-start prior."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._v: float | None = None

    def update(self, x: float):
        self._v = x if self._v is None else \
            self.alpha * x + (1 - self.alpha) * self._v

    def value(self, default: float) -> float:
        return default if self._v is None else self._v


class _CacheShard:
    __slots__ = ("od", "nbytes", "budget")

    def __init__(self, budget: int):
        self.od: OrderedDict[tuple, bytes] = OrderedDict()
        self.nbytes = 0
        self.budget = budget


class ResultCache:
    """Byte-bounded LRU of decoded scan results (Arrow IPC bytes), with
    per-tenant budgets.

    Keys carry the object version, so an overwrite invalidates implicitly:
    the new scan misses, and the stale entry ages out of the LRU.

    Each tenant's entries live in their own LRU shard bounded by that
    tenant's registered ``cache_bytes`` budget (default: the full
    capacity), and eviction under a tenant's budget only recycles *that
    tenant's* entries — a bulk scanner churning through cold data cannot
    evict the interactive working set.  ``capacity_bytes`` stays the
    global backstop: if the shards together outgrow it, the shard using
    the largest fraction of its own budget shrinks first.  A single
    (default) tenant therefore behaves exactly like the historic
    one-LRU cache."""

    def __init__(self, capacity_bytes: int = 256 << 20):
        self.capacity_bytes = capacity_bytes
        self._shards: dict[str, _CacheShard] = {}
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: tuple, tenant: str = "default") -> bytes | None:
        with self._lock:
            sh = self._shards.get(tenant)
            data = sh.od.get(key) if sh is not None else None
            if data is None:
                self.misses += 1
                return None
            sh.od.move_to_end(key)
            self.hits += 1
            return data

    def _evict_one(self, sh: _CacheShard):
        _, ev = sh.od.popitem(last=False)
        sh.nbytes -= len(ev)
        self._bytes -= len(ev)
        self.evictions += 1

    def put(self, key: tuple, data: bytes, tenant: str = "default",
            budget: int | None = None):
        with self._lock:
            sh = self._shards.get(tenant)
            if sh is None:
                sh = _CacheShard(self.capacity_bytes)
                self._shards[tenant] = sh
            if budget is not None:
                sh.budget = min(budget, self.capacity_bytes)
            if len(data) > sh.budget:
                return
            old = sh.od.pop(key, None)
            if old is not None:
                sh.nbytes -= len(old)
                self._bytes -= len(old)
            sh.od[key] = data
            sh.nbytes += len(data)
            self._bytes += len(data)
            while sh.nbytes > sh.budget and sh.od:
                self._evict_one(sh)
            while self._bytes > self.capacity_bytes:
                pool = [s for s in self._shards.values() if s.od]
                if not pool:
                    break
                self._evict_one(max(pool, key=lambda s:
                                    (s.nbytes / max(1, s.budget), s.nbytes)))

    def contains(self, key: tuple, tenant: str | None = None) -> bool:
        """Membership probe that neither recences the entry nor perturbs
        the hit/miss counters — ``explain()`` uses it.  Without a tenant
        it answers "cached for anyone?"."""
        with self._lock:
            if tenant is not None:
                sh = self._shards.get(tenant)
                return sh is not None and key in sh.od
            return any(key in s.od for s in self._shards.values())

    def __len__(self):
        return sum(len(s.od) for s in self._shards.values())

    @property
    def nbytes(self) -> int:
        return self._bytes

    def stats(self) -> dict:
        with self._lock:
            return {"entries": sum(len(s.od)
                                   for s in self._shards.values()),
                    "bytes": self._bytes,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}

    def by_tenant(self) -> dict:
        """Per-tenant shard occupancy (entries / bytes / budget)."""
        with self._lock:
            return {t: {"entries": len(s.od), "bytes": s.nbytes,
                        "budget": s.budget}
                    for t, s in self._shards.items()}


@dataclasses.dataclass
class PlacementEstimate:
    """One placement decision with the estimates that produced it."""

    where: str                   # "osd" or "client"
    est_osd_s: float
    est_client_s: float
    in_bytes: int
    pressure: float


class ScanScheduler:
    """Feedback-controlled fragment placement over one cluster (CephFS).

    Thread-safe; intended to be shared across scans so the latency
    history, rate estimators, and result cache persist (a Scanner is
    per-query, the scheduler is per-cluster).
    """

    def __init__(self, fs: CephFS, *, net_bw: float = GBE10,
                 client_threads: int = 16,
                 cache_bytes: int = 256 << 20,
                 hedge_multiplier: float = 3.0,
                 hedge_min_s: float = 1e-3,
                 history: int = 256,
                 decode_backend=None):
        self.fs = fs
        self.store = fs.store
        self.doa = DirectObjectAccess(fs)
        self.net_bw = net_bw
        self.client_threads = client_threads
        self.cache = ResultCache(cache_bytes)
        self.hedge_multiplier = hedge_multiplier
        self.hedge_min_s = hedge_min_s
        # the client side scans through this decode engine; the storage
        # side always runs the host path (scan_op on the OSD), so the
        # two sides' decode rates are estimated separately, each seeded
        # with its own backend's prior
        self._client_fmt = ParquetFormat(decode_backend=decode_backend)
        self._decode_rate_osd = _Ewma()      # bytes/s, storage-side host
        self._decode_rate_client = _Ewma()   # bytes/s, client backend
        self._client_rate_prior = \
            self._client_fmt.decode_backend.decode_rate_prior
        self._out_ratio = _Ewma()            # ipc-out bytes per in byte
        self._osd_lat: deque[float] = deque(maxlen=history)  # s per byte
        self._lock = threading.Lock()
        self.decisions = {"osd": 0, "client": 0, "cache": 0}
        self.hedges = 0
        self.fallbacks = 0
        self.spills = 0         # agg_op group-cardinality spill-to-scan

    # -- signals & estimates ---------------------------------------------------
    def _object_name(self, frag: Fragment) -> str:
        return self.fs.object_names(frag.path)[frag.obj_idx]

    def _frag_bytes(self, frag: Fragment) -> int:
        """Stored bytes this fragment's scan must touch."""
        if frag.footer is not None:                  # striped: rebased meta
            return frag.footer.row_groups[0].total_bytes
        if frag.client_meta is not None:             # flat: parent footer
            return frag.client_meta.row_groups[frag.client_rg_index] \
                .total_bytes
        # split: the object *is* the row group (plus a small footer)
        return self.store.stat(self._object_name(frag))

    def pressure_of(self, frag: Fragment,
                    ctx: TaskContext | None = None) -> float:
        """Min pressure over the fragment's up replicas: hedging lets the
        storage path reach the fastest copy, so the optimistic replica is
        the one the estimate should price.  With a QoS context the
        pressure is *lane-visible* (see :meth:`_tenant_pressure`)."""
        name = self._object_name(frag)
        loads = [self.store.load_of(o) for o in self.store.acting_set(name)
                 if not o.down]
        if not loads:
            return float("inf")
        return min(self._tenant_pressure(l, ctx) for l in loads)

    @staticmethod
    def _tenant_pressure(load, ctx: TaskContext | None) -> float:
        """Per-tenant placement pressure: a tenant prices an OSD by the
        in-flight work that can actually delay it — its own lane and
        higher-priority lanes.  Admission arbitration keeps lower lanes
        from queuing ahead of it, so one bulk tenant's flood on a hot
        OSD must not flip everyone's pushdown-vs-client crossover.
        Unattributed external load (``OSD.background_load``) is assumed
        bulk.  Without a QoS registry on the context the classic
        every-tenant pressure returns unchanged."""
        if ctx is None or ctx.registry is None \
                or load.by_tenant is None or load.down:
            return load.pressure
        rank = LANE_PRIORITY.get(ctx.lane, 1)
        visible = sum(n for (_t, lane), n in load.by_tenant.items()
                      if LANE_PRIORITY.get(lane, 1) <= rank)
        if rank >= 1:                       # bulk and background lanes
            visible += load.external
        qd = visible / max(1, load.threads)
        return load.straggle_factor * (1.0 + qd)

    def storage_threads(self) -> int:
        """Aggregate scan-thread capacity of the up part of the cluster."""
        return sum(o.threads for o in self.store.osds if not o.down) or 1

    def estimate(self, frag: Fragment, *,
                 out_bytes: float | None = None,
                 selectivity_hint: float | None = None,
                 ctx: TaskContext | None = None) -> PlacementEstimate:
        """Price both placements for this fragment from live load and the
        learned decode-rate / selectivity estimates.

        Costs are amortized over the parallelism each side offers
        (k-server view, as in ``storage.perfmodel``): storage decode
        spreads over the cluster's threads but is inflated by multi-tenant
        pressure; client decode spreads over the client's private threads
        but its NIC must carry the raw bytes.  ``out_bytes`` overrides the
        learned selectivity estimate when the caller knows the result size
        (an aggregate ships back a constant few bytes);
        ``selectivity_hint`` scales the learned output ratio instead when
        the caller knows the surviving-row fraction (a semi-join filter
        pushed into the scan), so the reduced reply bytes price in before
        any EWMA history exists.

        Each side is priced with its *own* decode rate: the storage side
        with the host-path estimate, the client side with its decode
        backend's — a Pallas-equipped client prices its decode ~an order
        of magnitude cheaper, so the crossover to client placement moves
        earlier, before a single observation lands."""
        in_bytes = self._frag_bytes(frag)
        rate_osd = self._decode_rate_osd.value(DEFAULT_DECODE_RATE)
        rate_client = self._decode_rate_client.value(
            self._client_rate_prior)
        decode_osd_s = in_bytes / max(rate_osd, 1.0)
        decode_client_s = in_bytes / max(rate_client, 1.0)
        if out_bytes is None:
            out_bytes = in_bytes * self._out_ratio.value(DEFAULT_OUT_RATIO)
            if selectivity_hint is not None:
                out_bytes *= min(1.0, max(0.0, selectivity_hint))
        pressure = self.pressure_of(frag, ctx)
        est_osd = max(decode_osd_s * pressure / self.storage_threads(),
                      out_bytes / self.net_bw)
        est_client = max(in_bytes / self.net_bw,
                         decode_client_s / max(1, self.client_threads))
        where = "osd" if est_osd <= est_client else "client"
        return PlacementEstimate(where, est_osd, est_client, in_bytes,
                                 pressure)

    def _observe(self, side: str, in_bytes: int, decode_s: float,
                 out_bytes: int):
        """Feed one completed scan into ``side``'s decode-rate EWMA and
        the shared output-ratio EWMA (a property of the data).  When the
        client runs the host (numpy) engine — the same code the OSD
        runs — the observation teaches *both* estimators; with an
        accelerator backend the engines differ, so observations stay on
        their own side."""
        if decode_s > 0 and in_bytes > 0:
            rate = in_bytes / decode_s
            host_client = self._client_fmt.decode_backend.name == "numpy"
            with self._lock:
                if host_client or side == "osd":
                    self._decode_rate_osd.update(rate)
                if host_client or side == "client":
                    self._decode_rate_client.update(rate)
                self._out_ratio.update(out_bytes / in_bytes)

    def _hedge_deadline(self, in_bytes: int) -> float | None:
        """``hedge_multiplier`` x the median recent *per-byte* storage-scan
        latency, scaled by this fragment's size — size-normalized so a
        legitimately large fragment is not mistaken for a straggler, and
        median-based so stragglers polluting the history cannot raise the
        bar above themselves.  None while the history is too cold."""
        with self._lock:
            if len(self._osd_lat) < 8:
                return None
            rate = sorted(self._osd_lat)[len(self._osd_lat) // 2]
            return max(self.hedge_min_s,
                       self.hedge_multiplier * rate * max(1, in_bytes))

    # -- cache keys -------------------------------------------------------------
    def cache_key(self, frag: Fragment, columns: Sequence[str] | None,
                  predicate: Expr | None,
                  limit: int | None = None) -> tuple:
        name = self._object_name(frag)
        version = self.store.version_of(name)
        footer_hash = ""
        if frag.footer is not None:
            footer_hash = hashlib.blake2s(frag.footer.serialize(),
                                          digest_size=8).hexdigest()
        cols = tuple(columns) if columns is not None else None
        pred_json = json.dumps(predicate.to_json(), sort_keys=True) \
            if predicate is not None else ""
        if len(pred_json) > 160:
            # semi-join key filters (IN-lists, bloom bit arrays) can be
            # kilobytes of JSON; key on a content digest instead so cache
            # entries stay cheap while different filters never collide
            pred_json = "digest:" + hashlib.blake2s(
                pred_json.encode(), digest_size=16).hexdigest()
        # limit is part of the identity: a truncated result must never be
        # served to an unbounded scan (or to a larger budget)
        return (name, version, footer_hash, frag.rg_in_object, cols,
                pred_json, limit)

    def agg_cache_key(self, frag: Fragment, specs, group_by,
                      max_groups: int, predicate: Expr | None) -> tuple:
        spec_key = ("__agg__",
                    json.dumps([s.to_json() for s in specs]
                               + [group_by, max_groups], sort_keys=True))
        return self.cache_key(frag, spec_key, predicate)

    # -- the scan ---------------------------------------------------------------
    def scan_fragment(self, frag: Fragment,
                      columns: Sequence[str] | None,
                      predicate: Expr | None,
                      ctx: TaskContext | None = None,
                      **legacy) -> tuple[Table, TaskRecord]:
        """Cache lookup -> placement decision -> (hedged) execution.

        Returns the same (Table, TaskRecord) contract as a FileFormat, so
        ``AdaptiveFormat`` is a drop-in placement.  ``ctx`` carries every
        task option: its admission controller bounds in-flight work per
        OSD (a cache hit never takes a slot), its ``limit`` rides into
        ``scan_op`` (the node stops decoding at the budget) and keys the
        result cache, and its ``selectivity_hint`` (a semi-join filter's
        expected surviving fraction) prices the placement only — results
        are identical either way, so it stays out of the cache key.  The
        tenant identity keys the cache shard and tags the storage call
        for per-tenant load accounting."""
        ctx = resolve_context(ctx, legacy)
        key = self.cache_key(frag, columns, predicate, ctx.limit)
        ipc = self.cache.get(key, tenant=ctx.tenant)
        if ipc is not None:
            t0 = time.perf_counter()
            tbl = Table.from_ipc(ipc)
            cpu = time.perf_counter() - t0
            with self._lock:
                self.decisions["cache"] += 1
            rec = TaskRecord("client", -1, cpu, 0, cpu, len(tbl),
                             cached=True)
            return tbl, rec

        est = self.estimate(frag, selectivity_hint=ctx.selectivity_hint,
                            ctx=ctx)
        with self._admit(frag, ctx):
            if est.where == "osd":
                try:
                    tbl, rec, ipc = self._scan_osd(frag, columns,
                                                   predicate, est, ctx)
                except (OSDDownError, ObjectNotFound):
                    # storage path unavailable (e.g. every replica died
                    # after the estimate): client-side reads via failover
                    with self._lock:
                        self.fallbacks += 1
                    tbl, rec, ipc = self._scan_client(frag, columns,
                                                      predicate, ctx)
            else:
                tbl, rec, ipc = self._scan_client(frag, columns, predicate,
                                                  ctx)
        self._cache_put(key, ipc, ctx)
        return tbl, rec

    def _admit(self, frag: Fragment, ctx: TaskContext):
        if ctx.admission is None:
            return contextlib.nullcontext()
        return ctx.admission.admit_object(self._object_name(frag), ctx)

    def _cache_put(self, key: tuple, data: bytes, ctx: TaskContext):
        budget = None
        if ctx.registry is not None:
            budget = ctx.registry.spec(ctx.tenant).cache_bytes
        self.cache.put(key, data, tenant=ctx.tenant, budget=budget)

    def _scan_osd(self, frag, columns, predicate, est,
                  ctx: TaskContext | None = None):
        ctx = ctx if ctx is not None else TaskContext()
        limit = ctx.limit
        payload = scan_payload(frag, columns, predicate, limit)
        deadline = self._hedge_deadline(est.in_bytes)
        if deadline is None:
            result, osd_id, el = self.doa.call(frag.path, frag.obj_idx,
                                               "scan_op", payload,
                                               tenant=ctx.tenant,
                                               lane=ctx.lane)
            hedged = False
        else:
            result, osd_id, el, hedged = self.doa.call_hedged(
                frag.path, frag.obj_idx, "scan_op", payload,
                hedge_threshold_s=deadline, tenant=ctx.tenant,
                lane=ctx.lane)
        t0 = time.perf_counter()
        tbl = Table.from_ipc(result)
        client_cpu = time.perf_counter() - t0
        sf = self.store.osds[osd_id].straggle_factor
        with self._lock:
            self.decisions["osd"] += 1
            if hedged:
                self.hedges += 1
            if limit is None:
                self._osd_lat.append(el / max(1, est.in_bytes))
        # el is straggle-inflated; divide it out so the decode-rate
        # estimate stays a property of the data, not of the slow node.
        # limit-truncated scans skip the estimators: their early-exit
        # decode time and clipped output would teach the EWMAs that
        # fragments are cheaper/smaller than they are.
        if limit is None:
            self._observe("osd", est.in_bytes, el / max(sf, 1e-9),
                          len(result))
        rec = TaskRecord("osd", osd_id, el, len(result), client_cpu,
                         len(tbl), hedged=hedged)
        return tbl, rec, result

    def _scan_client(self, frag, columns, predicate,
                     ctx: TaskContext | None = None):
        ctx = ctx if ctx is not None else TaskContext()
        # the scheduler already holds this fragment's admission slot:
        # strip the controller so the client format cannot deadlock
        # re-admitting against the same OSD
        tbl, rec = self._client_fmt.scan_fragment(
            self.fs, frag, columns, predicate,
            dataclasses.replace(ctx, admission=None))
        ipc = tbl.to_ipc()
        with self._lock:
            self.decisions["client"] += 1
        # both paths feed the estimators in the *same units*: stored
        # fragment bytes in, Arrow-IPC bytes out — but each side updates
        # only its own decode-rate EWMA (the client may run an
        # accelerator decode backend the storage nodes don't have);
        # truncated scans are excluded for the same reason as in
        # _scan_osd
        if ctx.limit is None:
            self._observe("client", self._frag_bytes(frag), rec.cpu_s,
                          len(ipc))
        return tbl, rec, ipc

    # -- aggregate pushdown -----------------------------------------------------
    _ROWCOUNT_COLS = ("__rowcount__",)   # cache-key column sentinel: a
                                         # count shares nothing with a scan

    def count_cache_key(self, frag: Fragment,
                        predicate: Expr | None) -> tuple:
        return self.cache_key(frag, self._ROWCOUNT_COLS, predicate)

    def count_fragment(self, frag: Fragment, predicate: Expr | None,
                       ctx: TaskContext | None = None,
                       **legacy) -> tuple[int, TaskRecord]:
        """COUNT(*) for one fragment with the same placement machinery as
        a scan: priced (with the aggregate's tiny result size), hedged,
        and result-cached — so ``count_rows`` under ``format="adaptive"``
        ships integers, not materialized tables.

        Returns (row count, TaskRecord)."""
        ctx = resolve_context(ctx, legacy)
        if predicate is None:       # metadata answers; no I/O at all
            return frag.num_rows, TaskRecord("client", -1, 0.0, 0, 0.0,
                                             frag.num_rows, cached=True)
        key = self.count_cache_key(frag, predicate)
        cached = self.cache.get(key, tenant=ctx.tenant)
        if cached is not None:
            n = int(json.loads(cached)["rows"])
            with self._lock:
                self.decisions["cache"] += 1
            return n, TaskRecord("client", -1, 0.0, 0, 0.0, n, cached=True)

        # an aggregate returns a constant-size payload: the storage-side
        # estimate carries ~no wire cost, so pushdown wins unless the
        # nodes are badly saturated
        est = self.estimate(frag, out_bytes=32, ctx=ctx)
        with self._admit(frag, ctx):
            if est.where == "osd":
                try:
                    n, rec, raw = self._count_osd(frag, predicate, est,
                                                  ctx)
                except (OSDDownError, ObjectNotFound):
                    with self._lock:
                        self.fallbacks += 1
                    n, rec, raw = self._count_client(frag, predicate, ctx)
            else:
                n, rec, raw = self._count_client(frag, predicate, ctx)
        self._cache_put(key, raw, ctx)
        return n, rec

    def _count_osd(self, frag, predicate, est,
                   ctx: TaskContext | None = None):
        ctx = ctx if ctx is not None else TaskContext()
        payload: dict = {
            "predicate": predicate.to_json()
            if predicate is not None else None,
            "row_groups": [frag.rg_in_object],
        }
        if frag.footer is not None:
            payload["footer"] = frag.footer.serialize()
        deadline = self._hedge_deadline(est.in_bytes)
        if deadline is None:
            raw, osd_id, el = self.doa.call(frag.path, frag.obj_idx,
                                            "rowcount_op", payload,
                                            tenant=ctx.tenant,
                                            lane=ctx.lane)
            hedged = False
        else:
            raw, osd_id, el, hedged = self.doa.call_hedged(
                frag.path, frag.obj_idx, "rowcount_op", payload,
                hedge_threshold_s=deadline, tenant=ctx.tenant,
                lane=ctx.lane)
        n = int(json.loads(raw)["rows"])
        with self._lock:
            self.decisions["osd"] += 1
            if hedged:
                self.hedges += 1
        # counts decode a single column: their latency is not a full-scan
        # observation, so neither the hedge history nor the decode-rate
        # EWMA is updated here
        rec = TaskRecord("osd", osd_id, el, len(raw), 0.0, n,
                         hedged=hedged)
        return n, rec, raw

    def aggregate_fragment(self, frag: Fragment, specs, group_by,
                           predicate, *, schema,
                           max_groups: int = DEFAULT_MAX_GROUPS,
                           ctx: TaskContext | None = None,
                           **legacy) -> "tuple[AggState, TaskRecord]":
        """Partial aggregation with the full placement machinery: priced
        with the aggregate's few-byte result size (so pushdown wins
        unless storage is badly saturated), hedged past the straggler
        deadline, and result-cached under the version-keyed LRU keyed by
        the aggregate spec.  Returns (AggState, TaskRecord)."""
        ctx = resolve_context(ctx, legacy)
        if is_degenerate_count(specs, group_by):
            # the unified executor lowers count_rows to this degenerate
            # aggregate; keep the integer-on-the-wire rowcount machinery
            # (placement-priced, hedged, result-cached)
            n, rec = self.count_fragment(frag, predicate, ctx)
            return count_state(n), rec
        key = self.agg_cache_key(frag, specs, group_by, max_groups,
                                 predicate)
        cached = self.cache.get(key, tenant=ctx.tenant)
        if cached is not None:
            state = AggState.deserialize(cached)
            with self._lock:
                self.decisions["cache"] += 1
            return state, TaskRecord("client", -1, 0.0, 0, 0.0,
                                     state.rows, cached=True)

        # an aggregate's reply is a partial state — never the decoded
        # columns: ~64B of JSON envelope plus ~48B per group, with the
        # group count capped by the cardinality bound (assume a few dozen
        # when the true cardinality is unknown)
        groups_est = min(max_groups, 64) if group_by else 0
        est = self.estimate(frag, out_bytes=64 + 48 * groups_est, ctx=ctx)
        with self._admit(frag, ctx):
            if est.where == "osd":
                try:
                    state, rec = self._agg_osd(frag, specs, group_by,
                                               predicate, est, schema,
                                               max_groups, ctx)
                except (OSDDownError, ObjectNotFound):
                    with self._lock:
                        self.fallbacks += 1
                    state, rec = self._agg_client(frag, specs, group_by,
                                                  predicate, schema, ctx)
            else:
                state, rec = self._agg_client(frag, specs, group_by,
                                              predicate, schema, ctx)
        self._cache_put(key, state.serialize(), ctx)
        return state, rec

    def _agg_osd(self, frag, specs, group_by, predicate, est, schema,
                 max_groups, ctx: TaskContext):
        payload = agg_payload(frag, specs, group_by, predicate, max_groups)
        deadline = self._hedge_deadline(est.in_bytes)
        if deadline is None:
            raw, osd_id, el = self.doa.call(frag.path, frag.obj_idx,
                                            "agg_op", payload,
                                            tenant=ctx.tenant,
                                            lane=ctx.lane)
            hedged = False
        else:
            raw, osd_id, el, hedged = self.doa.call_hedged(
                frag.path, frag.obj_idx, "agg_op", payload,
                hedge_threshold_s=deadline, tenant=ctx.tenant,
                lane=ctx.lane)
        state = parse_agg_reply(raw)
        with self._lock:
            if hedged:
                self.hedges += 1
            if state is not None:
                self.decisions["osd"] += 1
        if state is None:
            # cardinality spill -> the storage-side *scan*: scan_op still
            # filters and projects on the OSD (only the needed columns'
            # matching rows ship) and the client folds them unbounded.
            # _scan_osd books the placement decision; the refused agg_op
            # reply bytes still crossed the wire (its decode time lands
            # in the node's busy_s like any other cls call).
            with self._lock:
                self.spills += 1
            cols = needed_columns(specs, group_by, schema, predicate)
            tbl, rec, _ = self._scan_osd(frag, cols, predicate, est,
                                         dataclasses.replace(ctx,
                                                             limit=None))
            t0 = time.perf_counter()
            state = partial_aggregate(tbl, specs, group_by)
            fold = time.perf_counter() - t0
            rec = dataclasses.replace(
                rec, wire_bytes=rec.wire_bytes + len(raw),
                client_cpu_s=rec.client_cpu_s + fold,
                rows_out=state.rows, hedged=rec.hedged or hedged)
            return state, rec
        # like counts, aggregates decode a column subset: not a full-scan
        # observation, so the hedge history / decode-rate EWMAs stay put
        rec = TaskRecord("osd", osd_id, el, len(raw), 0.0, state.rows,
                         hedged=hedged)
        return state, rec

    def _agg_client(self, frag, specs, group_by, predicate, schema,
                    ctx: TaskContext):
        cols = needed_columns(specs, group_by, schema, predicate)
        tbl, rec = self._client_fmt.scan_fragment(
            self.fs, frag, cols, predicate,
            dataclasses.replace(ctx, admission=None, limit=None))
        t0 = time.perf_counter()
        state = partial_aggregate(tbl, specs, group_by)
        fold = time.perf_counter() - t0
        with self._lock:
            self.decisions["client"] += 1
        return state, TaskRecord("client", -1, rec.cpu_s + fold,
                                 rec.wire_bytes,
                                 rec.client_cpu_s + fold, state.rows)

    def _count_client(self, frag, predicate, ctx: TaskContext | None = None):
        """Fallback count: client-side decode of just the (first)
        predicate column (``count_fragment`` answered the predicate-less
        case from metadata already)."""
        ctx = ctx if ctx is not None else TaskContext()
        cols = sorted(predicate.columns())[:1]
        tbl, rec = self._client_fmt.scan_fragment(
            self.fs, frag, cols, predicate,
            dataclasses.replace(ctx, admission=None, limit=None))
        n = len(tbl)
        with self._lock:
            self.decisions["client"] += 1
        raw = json.dumps({"rows": n}).encode()
        return n, TaskRecord("client", -1, rec.cpu_s, rec.wire_bytes,
                             rec.client_cpu_s, n), raw

    # -- reporting ---------------------------------------------------------------
    def stats(self) -> dict:
        return {"decisions": dict(self.decisions), "hedges": self.hedges,
                "fallbacks": self.fallbacks, "spills": self.spills,
                "cache": self.cache.stats()}
