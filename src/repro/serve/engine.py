"""Batched serving engine: prefill + decode over the model API.

Wave-batched continuous serving: requests queue up; the engine admits up to
``max_batch`` of them per wave, right-pads prompts to a common length,
prefllls once, then decodes greedily until every sequence in the wave hits
EOS or its token budget.  Per-request prompts are *fetched through the
adaptive scan path* (prompt tokens stored columnar in the object store;
``ingest_prompts`` / ``ServeEngine.ingest``): the scheduler decides per
fragment whether to decode on the storage nodes or the serving host, and
repeat ingests of hot prompt shards hit its columnar result cache — the
serving-side mirror of the training ingest pipeline.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.aformat.table import Table
from repro.configs.base import ModelConfig
from repro.dataset import (AdaptiveFormat, Dataset, MutableDataset,
                           TaskContext)
from repro.models import api as model_api
from repro.models import lm
from repro.sharding import ShardingCtx


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                    # -1 = never


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray                  # generated tokens
    prefill_s: float
    decode_s: float
    steps: int


def _pin(ds):
    """Snapshot-pin a mutable prompt store: resolve it to the immutable
    Dataset of the snapshot current *now*, so one ingest never mixes rows
    from two commits (writers appending mid-scan stay invisible)."""
    return ds.as_of() if isinstance(ds, MutableDataset) else ds


def append_prompts(store: MutableDataset, requests, *,
                   uid_col: str = "uid", pos_col: str = "pos",
                   token_col: str = "token") -> int:
    """Append serving Requests to a columnar prompt store through the
    transactional path: one row per prompt token (uid, pos, token), one
    snapshot commit for the batch.  Readers pinned to earlier snapshots
    never see the new prompts; the returned snapshot id replays exactly
    this ingest boundary via ``store.as_of(sid)``.  Continuous ingest
    produces many small appended files — ``store.compact()`` merges them
    back into right-sized row groups on the storage nodes."""
    if not requests:
        raise ValueError("append_prompts() with no requests")
    uids = np.concatenate([
        np.full(len(r.prompt), r.uid, np.int64) for r in requests])
    pos = np.concatenate([
        np.arange(len(r.prompt), dtype=np.int32) for r in requests])
    toks = np.concatenate([
        np.asarray(r.prompt, np.int32) for r in requests])
    tbl = Table.from_pydict({uid_col: uids, pos_col: pos,
                             token_col: toks})
    return store.append(tbl)


def prompt_lengths(ds: "Dataset | MutableDataset", *, format="adaptive",
                   predicate=None, uid_col: str = "uid",
                   pos_col: str = "pos", num_threads: int = 8,
                   tenant=None):
    """Per-uid prompt lengths via grouped COUNT pushdown — the wave
    planner's sizing query.  Where ``ingest_prompts`` must ship token
    columns, this ships only per-uid partial counts (``agg_op``), so an
    admission planner can size batches / padding before paying for a
    single token byte.  A :class:`MutableDataset` is snapshot-pinned
    first; an empty store (no prompts appended yet) sizes to zero waves.
    Returns ({uid: n_tokens}, ScanMetrics)."""
    pinned = _pin(ds)
    if not pinned.fragments():       # nothing committed yet
        from repro.dataset.plan import ScanMetrics
        return {}, ScanMetrics()
    q = pinned.query(format=format, num_threads=num_threads,
                     tenant=tenant)
    if predicate is not None:
        q = q.filter(predicate)
    q = q.aggregate([("count", pos_col)], group_by=uid_col)
    out = q.to_table()
    uids = out.column(uid_col).values
    counts = out.column(f"count_{pos_col}").values
    return {int(u): int(n) for u, n in zip(uids, counts)}, q.metrics


def ingest_prompts(ds: "Dataset | MutableDataset", *, format="adaptive",
                   predicate=None, uid_col: str = "uid",
                   pos_col: str = "pos", token_col: str = "token",
                   max_new_tokens: int = 32, eos_id: int = -1,
                   num_threads: int = 8, decode_backend=None,
                   tenant=None):
    """Scan a columnar prompt store into serving Requests.

    The dataset holds one row per prompt token: (uid, pos, token).  The
    scan runs through whatever placement ``format`` names.  Pass an
    ``AdaptiveFormat`` *instance* (as ``ServeEngine.ingest`` does) so the
    scheduler routes each fragment by live OSD load and repeat ingests
    hit its result cache — the "adaptive" string builds a fresh scheduler
    per call, which routes adaptively but cannot cache across calls.

    The scan *streams* through the lazy query plan's ``to_batches`` —
    fragments are grouped into per-uid buffers as they land, so peak
    memory is the grouped output plus O(in-flight fragments), never a
    materialized whole-dataset Table.  A :class:`MutableDataset` prompt
    store is snapshot-pinned up front: prompts appended (or compacted)
    while the stream runs are invisible to this ingest and land in the
    next one.  Returns (requests, scan_metrics).

    ``decode_backend`` picks the client-side decode engine for the
    ingest scan ("pallas" routes dictionary decode / filtering through
    the accelerator kernels — a serving host *has* the accelerator, so
    ingest is the natural consumer); it applies when ``format`` is a
    name, not an already-built instance.
    """
    q = _pin(ds).query(format=format, num_threads=num_threads,
                       decode_backend=decode_backend, tenant=tenant)
    if predicate is not None:
        q = q.filter(predicate)
    q = q.select(uid_col, pos_col, token_col)
    # per-uid accumulation, one batch at a time: each fragment is grouped
    # (sort by (uid, pos), split at uid boundaries) and immediately folded
    # into its uid's buffer list
    groups: dict[int, list[tuple[np.ndarray, np.ndarray]]] = {}
    for tbl in q.to_batches():
        uids = tbl.column(uid_col).values
        pos = tbl.column(pos_col).values
        toks = tbl.column(token_col).values
        order = np.lexsort((pos, uids))
        uids, pos = uids[order], pos[order]
        toks = toks[order].astype(np.int32)
        bounds = np.flatnonzero(np.diff(uids)) + 1
        for g_uids, g_pos, g_toks in zip(np.split(uids, bounds),
                                         np.split(pos, bounds),
                                         np.split(toks, bounds)):
            if len(g_uids):
                groups.setdefault(int(g_uids[0]), []).append(
                    (g_pos, g_toks))
    reqs = []
    for uid in sorted(groups):
        parts = groups[uid]
        pos = np.concatenate([p for p, _ in parts])
        toks = np.concatenate([t for _, t in parts])
        reqs.append(Request(uid, toks[np.argsort(pos, kind="stable")],
                            max_new_tokens=max_new_tokens, eos_id=eos_id))
    return reqs, q.metrics


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, rules, params, *,
                 max_batch: int = 8, pad_id: int = 0,
                 decode_backend=None, tenant=None):
        self.cfg = cfg
        self.ctx = ShardingCtx(mesh, rules)
        self.params = params
        self.max_batch = max_batch
        self.pad_id = pad_id
        # serving is the latency-sensitive workload: ingest scans run as
        # an interactive-lane tenant (pass ``TenantRegistry.context(...)``
        # to arbitrate against other tenants on the shared controller)
        self.tenant = (tenant if tenant is not None
                       else TaskContext(tenant="serve", lane="interactive"))
        self._queue: list[Request] = []
        self.last_ingest_metrics = None     # ScanMetrics of the last ingest
        # one format for the engine's lifetime: its scheduler's result
        # cache and learned rates persist across ingests, so repeat
        # ingests of hot prompt shards skip the storage tier.
        # ``decode_backend`` is the *ingest scan's* decode engine (the
        # serving host owns the accelerator, so "pallas" makes the
        # client-side leg of adaptive ingest cheap); it is unrelated to
        # the token-decode step below.
        self._ingest_format = AdaptiveFormat(decode_backend=decode_backend)

        cfg_ = cfg
        ctx = self.ctx

        @jax.jit
        def _prefill(params, tokens):
            return model_api.prefill(cfg_, ctx, params, {"tokens": tokens})

        @jax.jit
        def _decode(params, cache, tokens, pos):
            return model_api.decode_step(cfg_, ctx, params, cache, tokens,
                                         pos)

        self._prefill = _prefill
        self._decode = _decode

    # -- queue -----------------------------------------------------------------
    def submit(self, req: Request):
        self._queue.append(req)

    def pending(self) -> int:
        return len(self._queue)

    def ingest(self, ds: Dataset, **kwargs) -> int:
        """Pull prompts from a columnar dataset through the adaptive scan
        scheduler and enqueue them; scan accounting lands in
        ``self.last_ingest_metrics``.  Returns the number admitted."""
        kwargs.setdefault("format", self._ingest_format)
        kwargs.setdefault("tenant", self.tenant)
        reqs, metrics = ingest_prompts(ds, **kwargs)
        self.last_ingest_metrics = metrics
        for r in reqs:
            self.submit(r)
        return len(reqs)

    # -- one wave -----------------------------------------------------------------
    def _admit(self) -> list[Request]:
        wave = self._queue[: self.max_batch]
        del self._queue[: len(wave)]
        return wave

    def step_wave(self) -> list[Completion]:
        """Admit up to max_batch requests, prefill, decode to completion."""
        wave = self._admit()
        if not wave:
            return []
        b = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.full((b, plen), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        budget = max(r.max_new_tokens for r in wave)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        if self.cfg.sliding_window == 0 and not self.cfg.local_global_ratio:
            # full-attention caches get budget slots of decode headroom;
            # ring caches keep window-sized buffers (slot = pos % window)
            cache = model_api.pad_cache(cache, budget)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(next_tok)
        prefill_s = time.perf_counter() - t0

        out = np.zeros((b, budget), np.int32)
        done = np.zeros(b, bool)
        t1 = time.perf_counter()
        steps = 0
        for j in range(budget):
            out[:, j] = np.asarray(next_tok)
            for i, r in enumerate(wave):
                if not done[i] and (out[i, j] == r.eos_id
                                    or j + 1 >= r.max_new_tokens):
                    done[i] = True
            steps += 1
            if done.all():
                break
            logits, cache = self._decode(self.params, cache,
                                         next_tok[:, None],
                                         jnp.asarray(plen + j, jnp.int32))
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(next_tok)
        decode_s = time.perf_counter() - t1

        comps = []
        for i, r in enumerate(wave):
            gen = out[i, : min(r.max_new_tokens, steps)]
            if r.eos_id >= 0 and (gen == r.eos_id).any():
                gen = gen[: int(np.argmax(gen == r.eos_id)) + 1]
            comps.append(Completion(r.uid, gen, prefill_s, decode_s, steps))
        return comps

    def run(self) -> list[Completion]:
        """Drain the queue in waves."""
        done: list[Completion] = []
        while self._queue:
            done.extend(self.step_wave())
        return done


def init_serve_params(cfg: ModelConfig, seed: int = 0):
    """Concrete bf16 params for a (small) serving config."""
    params, specs = lm.init_params(cfg, jax.random.key(seed))
    dt = jnp.dtype(cfg.compute_dtype)
    params = jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, params)
    return params, specs
