from repro.serve.engine import Completion, Request, ServeEngine, init_serve_params

__all__ = ["Completion", "Request", "ServeEngine", "init_serve_params"]
