from repro.serve.engine import (
    Completion,
    Request,
    ServeEngine,
    append_prompts,
    ingest_prompts,
    init_serve_params,
    prompt_lengths,
)

__all__ = [
    "Completion",
    "Request",
    "ServeEngine",
    "append_prompts",
    "ingest_prompts",
    "init_serve_params",
    "prompt_lengths",
]
