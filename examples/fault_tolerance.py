"""Fault-tolerance tour: failover, hedging, checkpoint restore, elastic.

Walks the four recovery mechanisms end to end on the simulated cluster:
  1. OSD failure mid-workload -> replicas serve reads and scan_ops;
  2. a straggling OSD -> hedged scan beats the tail;
  3. training state restored from object-store checkpoints after a crash;
  4. elastic downsize: lose half the fleet, re-mesh, keep training
     (runs in a subprocess with 8 simulated devices).

    PYTHONPATH=src python examples/fault_tolerance.py
"""

import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.aformat.expressions import field
from repro.core import dataset, make_cluster
from repro.data import synth_corpus, write_corpus
from repro.dataset import PushdownParquetFormat
from repro.distrib import CheckpointManager, HealthMonitor


def demo_failover():
    print("=== 1. OSD failure: replicas serve the scan ===")
    fs = make_cluster(8)
    corpus = synth_corpus(200, mean_doc_len=200, vocab_size=500, seed=1)
    write_corpus(fs, "/c", corpus, num_shards=4)
    ds = dataset(fs, "/c")
    want = ds.scanner(format="pushdown", columns=["token"]).to_table()
    fs.store.fail_osd(0)
    fs.store.fail_osd(5)
    got = ds.scanner(format="pushdown", columns=["token"]).to_table()
    assert len(got) == len(want)
    print(f"  2/8 OSDs down, scan still returned {len(got)} rows\n")


def demo_hedging():
    print("=== 2. Straggler: hedged scan_op beats the tail ===")
    fs = make_cluster(8)
    corpus = synth_corpus(100, mean_doc_len=200, vocab_size=500, seed=2)
    write_corpus(fs, "/c", corpus, num_shards=4, row_group_rows=2048)
    ds = dataset(fs, "/c")
    # straggle the primary OSD of the first fragment
    frag = ds.fragments()[0]
    victim = fs.store.primary_of(fs.object_names(frag.path)[frag.obj_idx])
    victim.straggle_factor = 200.0
    sc = ds.scanner(format=PushdownParquetFormat(hedge_threshold_s=0.005),
                    columns=["token"])
    sc.to_table()
    hedged = sum(1 for t in sc.metrics.tasks if t.hedged)
    worst = max(t.cpu_s for t in sc.metrics.tasks)
    print(f"  {hedged} fragment(s) hedged to replicas; worst winning "
          f"task {worst * 1e3:.1f} ms\n")


def demo_checkpoint_restore():
    print("=== 3. Crash + restore from object-store checkpoint ===")
    fs = make_cluster(6)
    cm = CheckpointManager(fs, "/ckpt")
    state = {"params": {"w": jnp.arange(1e4).reshape(100, 100)},
             "step": jnp.array(41, jnp.int32)}
    cm.save(state, 41)
    hm = HealthMonitor(range(6), timeout_s=5.0)
    hm.mark_down(2)                                # "the node died"
    fs.store.fail_osd(2)
    structs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = cm.restore(structs)
    assert int(restored["step"]) == 41
    print(f"  dead hosts per heartbeat: {hm.dead_hosts()}; "
          f"state restored at step {int(restored['step'])} "
          "through degraded store\n")


def demo_elastic():
    print("=== 4. Elastic downsize: 8 devices -> lose 4 -> re-mesh ===")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distrib import elastic_downsize
        from repro.sharding import default_rules, tree_shardings
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = default_rules()
        state = {"w": jnp.arange(4096.0).reshape(64, 64)}
        specs = {"w": ("embed", "mlp")}
        state = jax.device_put(state, tree_shardings(mesh, rules, state, specs))
        new_mesh, new_state, plan = elastic_downsize(
            state, specs, mesh, rules, list(jax.devices())[:4])
        assert np.array_equal(np.asarray(new_state["w"]),
                              np.arange(4096.0).reshape(64, 64))
        print(f"  mesh {plan.old_shape} -> {plan.new_shape}, "
              f"state bitwise intact on {plan.devices_kept} devices")
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                         cwd=".")
    print(out.stdout or out.stderr)


if __name__ == "__main__":
    demo_failover()
    demo_hedging()
    demo_checkpoint_restore()
    demo_elastic()
    print("all fault-tolerance demos passed")
