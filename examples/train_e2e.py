"""End-to-end training driver: storage -> pushdown ingest -> train -> ckpt.

Trains a small LM for a few hundred steps on a Zipf-structured corpus
served out of the simulated Ceph cluster with storage-side quality
filtering, checkpointing into the same object store, and verifies the loss
actually falls below the unigram-entropy start.  This is deliverable (b)'s
"train a model for a few hundred steps" driver at CPU scale; the same code
path scales up via repro.launch.train (remove --smoke, pick a mesh).

    PYTHONPATH=src python examples/train_e2e.py --steps 200
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.aformat.expressions import field
from repro.configs import smoke_config
from repro.core import dataset, make_cluster
from repro.data import synth_corpus, write_corpus
from repro.distrib import CheckpointManager
from repro.ingest import ReaderConfig, ShardedReader
from repro.launch.mesh import make_local_mesh
from repro.launch.train import build_training
from repro.sharding import default_rules
from repro.train import optim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=2048)
    args = ap.parse_args()

    # -- corpus in the object store, Zipf unigrams (learnable) ---------------
    fs = make_cluster(8)
    corpus = synth_corpus(1000, mean_doc_len=400, vocab_size=args.vocab,
                          seed=0, distribution="zipf")
    write_corpus(fs, "/corpus", corpus, num_shards=8, row_group_rows=16384)
    ds = dataset(fs, "/corpus")
    reader = ShardedReader(ds, ReaderConfig(
        seq_len=args.seq, local_batch=args.batch,
        predicate=field("quality") > 0.3, format="pushdown",
        num_threads=2))

    # -- ~1M-param model, AdamW ----------------------------------------------
    cfg = smoke_config("starcoder2-7b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128, d_ff=256,
                              num_heads=4, num_kv_heads=4, head_dim=32,
                              vocab_size=args.vocab, remat=False)
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    opt = optim.OptConfig(peak_lr=3e-3, warmup_steps=20,
                          decay_steps=args.steps)
    state, _, fn = build_training(cfg, mesh, rules, opt)
    cm = CheckpointManager(fs, "/ckpt", keep=2)

    losses = []
    t0 = time.perf_counter()
    for step in range(1, args.steps + 1):
        batch = next(reader)
        state, mets = fn(state, {k: jnp.asarray(v)
                                 for k, v in batch.items()})
        losses.append(float(mets["loss"]))
        if step % 25 == 0 or step == 1:
            toks = step * args.seq * args.batch
            print(f"step {step:4d} loss {losses[-1]:7.4f} "
                  f"tok/s {toks / (time.perf_counter() - t0):8.0f}",
                  flush=True)
        if step % 100 == 0:
            # model and reader cut land in one commit (see --resume in
            # repro.launch.train for restoring both)
            cm.save_async({"model": state,
                           "reader": reader.checkpoint().to_arrays()},
                          step)
    cm.wait()
    reader.close()

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"(uniform entropy would be {np.log(args.vocab):.3f})")
    print(f"checkpoints in object store: {cm.steps()}")
    print("ingest:", reader.stats())
    assert last < first - 0.5, "model failed to learn the Zipf unigrams"
    print("OK: loss fell well below the initial cross-entropy")


if __name__ == "__main__":
    main()
