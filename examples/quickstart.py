"""Quickstart: the paper's API in ~70 lines.

Build a simulated Ceph cluster, write a columnar dataset in the split
layout, and run the same query three ways — decoding on the client
(ParquetFormat), pushed down into the storage nodes
(PushdownParquetFormat), and with the adaptive scheduler picking the
placement per fragment at runtime (AdaptiveFormat).  Same results; the
CPU moves.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import AdaptiveFormat, make_cluster, write_split, dataset


def main():
    # -- a Ceph-like cluster: 8 OSDs, 3-way replication, scan_op loaded ----
    fs = make_cluster(num_osds=8)

    # -- write a table in the split layout (one row group per object) ------
    rng = np.random.default_rng(0)
    n = 100_000
    table = Table.from_pydict({
        "trip_id": np.arange(n, dtype=np.int64),
        "passenger_count": rng.integers(1, 7, n).astype(np.int32),
        "fare_amount": rng.gamma(2.0, 7.5, n).astype(np.float64),
    })
    for i in range(4):
        write_split(fs, f"/taxi/part{i}.arw", table.slice(i * 25_000, 25_000),
                    row_group_rows=4_096)

    # -- discover + query ---------------------------------------------------
    ds = dataset(fs, "/taxi")          # finds the .index files
    print(f"dataset: {ds.num_rows} rows, {len(ds.fragments())} fragments, "
          f"layout={ds.layout}")
    predicate = (field("fare_amount") > 40.0) & \
        (field("passenger_count") >= 5)

    adaptive = AdaptiveFormat()        # keep one instance: its result
                                       # cache persists across scans
    for fmt in ("parquet", "pushdown", adaptive, adaptive):
        scanner = ds.scanner(format=fmt,
                             columns=["trip_id", "fare_amount"],
                             predicate=predicate)
        result = scanner.to_table()
        m = scanner.metrics
        name = fmt if isinstance(fmt, str) else "adaptive"
        print(f"\n[{name}] rows={len(result)} "
              f"pruned={m.fragments_pruned}/{m.fragments_total} fragments")
        print(f"  client cpu  {m.client_cpu_s * 1e3:8.2f} ms")
        print(f"  storage cpu {m.osd_cpu_s * 1e3:8.2f} ms")
        print(f"  wire        {m.wire_bytes / 1e6:8.2f} MB")
        if m.cache_hits:
            print(f"  result cache hits: {m.cache_hits} "
                  "(repeat scan, no storage I/O)")

    print("\nSwitching the format argument moved decode+filter into the "
          "storage layer — the paper's contribution.  The adaptive "
          "scheduler makes that choice per fragment from live OSD load, "
          "and its second scan was served from the columnar result cache.")

    # -- aggregate pushdown: ship partial states, not columns ---------------
    sc = ds.scanner(format="pushdown", predicate=predicate)
    stats = sc.aggregate(["count", ("sum", "fare_amount"),
                          ("mean", "fare_amount"),
                          ("max", "fare_amount")],
                         group_by="passenger_count")
    wire = sum(t.wire_bytes for t in sc.metrics.tasks)
    print(f"\nGROUP BY passenger_count via agg_op "
          f"({wire / 1e3:.1f} KB on the wire):")
    for i in range(len(stats)):
        print(f"  passengers={stats.column('passenger_count').values[i]} "
              f"count={stats.column('count').values[i]} "
              f"mean_fare={stats.column('mean_fare_amount').values[i]:.2f} "
              f"max_fare={stats.column('max_fare_amount').values[i]:.2f}")
    print("Each OSD folded its fragments into a partial aggregate state; "
          "only those few dozen bytes per fragment crossed the wire.")


if __name__ == "__main__":
    main()
