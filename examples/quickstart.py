"""Quickstart: the paper's API in 60 lines.

Build a simulated Ceph cluster, write a columnar dataset in the split
layout, and run the same query twice — once decoding on the client
(ParquetFormat) and once pushed down into the storage nodes
(PushdownParquetFormat).  Same results; the CPU moves.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import make_cluster, write_split, dataset


def main():
    # -- a Ceph-like cluster: 8 OSDs, 3-way replication, scan_op loaded ----
    fs = make_cluster(num_osds=8)

    # -- write a table in the split layout (one row group per object) ------
    rng = np.random.default_rng(0)
    n = 100_000
    table = Table.from_pydict({
        "trip_id": np.arange(n, dtype=np.int64),
        "passenger_count": rng.integers(1, 7, n).astype(np.int32),
        "fare_amount": rng.gamma(2.0, 7.5, n).astype(np.float64),
    })
    for i in range(4):
        write_split(fs, f"/taxi/part{i}.arw", table.slice(i * 25_000, 25_000),
                    row_group_rows=4_096)

    # -- discover + query ---------------------------------------------------
    ds = dataset(fs, "/taxi")          # finds the .index files
    print(f"dataset: {ds.num_rows} rows, {len(ds.fragments())} fragments, "
          f"layout={ds.layout}")
    predicate = (field("fare_amount") > 40.0) & \
        (field("passenger_count") >= 5)

    for fmt in ("parquet", "pushdown"):
        scanner = ds.scanner(format=fmt,
                             columns=["trip_id", "fare_amount"],
                             predicate=predicate)
        result = scanner.to_table()
        m = scanner.metrics
        print(f"\n[{fmt}] rows={len(result)} "
              f"pruned={m.fragments_pruned}/{m.fragments_total} fragments")
        print(f"  client cpu  {m.client_cpu_s * 1e3:8.2f} ms")
        print(f"  storage cpu {m.osd_cpu_s * 1e3:8.2f} ms")
        print(f"  wire        {m.wire_bytes / 1e6:8.2f} MB")

    print("\nSwitching the format argument moved decode+filter into the "
          "storage layer — the paper's contribution.")


if __name__ == "__main__":
    main()
