"""Batched serving demo: prompts out of the object store, waves of decode.

Stores prompt token streams columnar in the cluster, fetches them via
pushdown scans (projection = token column, predicate = prompt id), and
serves them through the wave-batching engine — the inference-side mirror
of the training ingest path.

    PYTHONPATH=src python examples/serve_batched.py
"""

import dataclasses
import time

import numpy as np

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.configs import smoke_config
from repro.core import dataset, make_cluster, write_flat
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine, init_serve_params
from repro.sharding import default_rules

VOCAB = 1024
N_PROMPTS = 12


def main():
    # -- prompts as a columnar table in the store ---------------------------
    fs = make_cluster(4)
    rng = np.random.default_rng(0)
    rows = {"prompt_id": [], "pos": [], "token": []}
    for pid in range(N_PROMPTS):
        n = int(rng.integers(4, 20))
        rows["prompt_id"] += [pid] * n
        rows["pos"] += list(range(n))
        rows["token"] += rng.integers(1, VOCAB, n).tolist()
    tbl = Table.from_pydict({
        "prompt_id": np.asarray(rows["prompt_id"], np.int64),
        "pos": np.asarray(rows["pos"], np.int32),
        "token": np.asarray(rows["token"], np.int32),
    })
    write_flat(fs, "/prompts/batch0.arw", tbl, row_group_rows=4096)
    ds = dataset(fs, "/prompts")

    # -- tiny model + engine ---------------------------------------------------
    cfg = smoke_config("starcoder2-7b")
    cfg = dataclasses.replace(cfg, num_layers=2, vocab_size=VOCAB,
                              remat=False)
    params, _ = init_serve_params(cfg, seed=0)
    engine = ServeEngine(cfg, make_local_mesh(1, 1), default_rules(),
                         params, max_batch=4)

    # -- fetch each prompt by pushdown scan, submit, run waves ----------------
    t0 = time.perf_counter()
    for pid in range(N_PROMPTS):
        out = ds.scanner(format="pushdown", columns=["token"],
                         predicate=field("prompt_id") == pid).to_table()
        engine.submit(Request(pid, out.column("token").values.astype(
            np.int32), max_new_tokens=12))
    comps = engine.run()
    dt = time.perf_counter() - t0

    total = sum(len(c.tokens) for c in comps)
    print(f"served {len(comps)} requests in waves of "
          f"{engine.max_batch}: {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on 1 CPU core)")
    for c in comps[:4]:
        print(f"  req {c.uid}: {len(c.tokens)} tokens, "
              f"prefill {c.prefill_s * 1e3:.0f} ms, "
              f"decode {c.decode_s * 1e3:.0f} ms")
    assert len(comps) == N_PROMPTS
    print("OK")


if __name__ == "__main__":
    main()
