"""Decode-backend benchmark: NumPy vs Pallas client decode plane.

On this CPU container the Pallas kernels run ``interpret=True``, so —
exactly as in ``kernel_bench.py`` — the Pallas *wall time here is
meaningless* (it is an un-jitted Python interpreter of the kernel IR).
What a real run can honestly establish:

  (a) the two backends are byte-identical on real scans (measured, the
      correctness contract the placement work rests on);
  (b) the accelerator decode rate that matters for placement comes from
      the HBM roofline (analytic, as in kernel_bench), and it clears the
      *measured* host decode rate by well over an order of magnitude —
      which is why ``PallasBackend.decode_rate_prior`` (1.5 GB/s of
      stored bytes) is conservative;
  (c) feeding that prior into the scheduler's per-side estimators moves
      the placement crossover: a Pallas-equipped client flips to
      client-side decode at a fraction of the storage pressure a NumPy
      client needs (deterministic from the priors — no EWMA history);
  (d) ``explain()`` names the chosen backend and the flipped placement.

    PYTHONPATH=src:. python benchmarks/decode_backend.py
"""

from __future__ import annotations

import time

from benchmarks.common import (build_cluster, save_result,
                               selectivity_predicate, taxi_like_table)
from repro.aformat.decode import NumPyBackend, PallasBackend
from repro.dataset import AdaptiveFormat, dataset
from repro.dataset.scheduler import ScanScheduler
from repro.launch.mesh import HBM_BW

ROWS = 100_000
ROWS_PER_FILE = 4_096
SELECTIVITY = 0.1
NODES = 8
#: single client decode thread: makes the client side decode-bound for
#: the host backend (the regime where the backend prior decides placement)
CLIENT_THREADS = 1
#: per-OSD background tenants swept for the crossover claim
TENANT_SWEEP = (0, 1, 3, 7, 15, 31, 63, 127)

# Roofline for the kernel decode path (stored bytes -> decoded values on
# an accelerator): per stored DICT code the gather reads 4 B (code) +
# writes 4 B (value) and the fused predicate + pack re-read ~8 B more —
# call it 4x HBM traffic per stored byte.  v5e HBM at ``HBM_BW`` then
# sustains HBM_BW/4 stored bytes per second; the shipped prior is ~50x
# under that (kernel-launch, padding, and host-staging slack).
MODELED_PALLAS_RATE = HBM_BW / 4


def _identical(a, b) -> bool:
    """Bit-exact table equality (stricter than Table.equals)."""
    if a.schema.names != b.schema.names or len(a) != len(b):
        return False
    for ca, cb in zip(a.columns, b.columns):
        if ca.field.type == "string":
            if list(map(str, ca.values)) != list(map(str, cb.values)):
                return False
        elif (ca.values.dtype != cb.values.dtype
              or ca.values.tobytes() != cb.values.tobytes()):
            return False
    return True


def _timed_scan(ds, backend, predicate):
    sc = ds.scanner(format="parquet", predicate=predicate,
                    decode_backend=backend, num_threads=4)
    t0 = time.perf_counter()
    tbl = sc.to_table()
    wall = time.perf_counter() - t0
    in_bytes = sum(t.wire_bytes for t in sc.metrics.tasks)
    cpu = sum(t.cpu_s for t in sc.metrics.tasks)
    return tbl, wall, in_bytes, cpu


def run() -> dict:
    table = taxi_like_table(ROWS)
    fs = build_cluster(NODES, table, rows_per_file=ROWS_PER_FILE)
    ds = dataset(fs, "/taxi")
    pred = selectivity_predicate(table, SELECTIVITY)

    # (a) byte-identity on real scans, filtered and unfiltered
    out: dict = {"rows": ROWS, "fragments": len(ds.fragments()),
                 "selectivity": SELECTIVITY}
    cells = []
    identical = True
    for name, p in (("full", None), ("selective", pred)):
        t_np, w_np, in_bytes, cpu_np = _timed_scan(ds, "numpy", p)
        t_pl, w_pl, _, _ = _timed_scan(ds, "pallas", p)
        same = _identical(t_np, t_pl)
        identical &= same
        cells.append({"scan": name, "rows_out": len(t_np),
                      "identical": same,
                      "numpy_wall_s": round(w_np, 4),
                      "pallas_interpret_wall_s": round(w_pl, 4),
                      "host_measured_Bps": round(in_bytes / max(cpu_np,
                                                                1e-9))})
        host_rate = in_bytes / max(cpu_np, 1e-9)
    out["scans"] = cells
    out["identical"] = identical

    # (b) rates: measured host vs modeled accelerator vs shipped priors
    out["rates"] = {
        "host_measured_Bps": round(host_rate),
        "numpy_prior_Bps": NumPyBackend.decode_rate_prior,
        "pallas_prior_Bps": PallasBackend.decode_rate_prior,
        "pallas_modeled_roofline_Bps": round(MODELED_PALLAS_RATE),
        "note": "pallas wall above is interpret mode (meaningless); the "
                "roofline is the accelerator-side estimate, and the "
                "shipped prior sits far under it",
    }

    # (c) crossover sweep: pressure at which each backend's scheduler
    # first prefers client placement, from priors alone (fresh schedulers,
    # no observations)
    frag = ds.fragments()[0]
    sweep = []
    flips = {}
    for backend in ("numpy", "pallas"):
        flips[backend] = None
    for tenants in TENANT_SWEEP:
        for osd in fs.store.osds:
            osd.background_load = tenants * osd.threads
        cell = {"tenants": tenants}
        for backend in ("numpy", "pallas"):
            est = ScanScheduler(fs, client_threads=CLIENT_THREADS,
                                decode_backend=backend).estimate(frag)
            cell[backend] = est.where
            cell[f"{backend}_est_client_ms"] = round(
                est.est_client_s * 1e3, 4)
            cell[f"{backend}_est_osd_ms"] = round(est.est_osd_s * 1e3, 4)
            if est.where == "client" and flips[backend] is None:
                flips[backend] = tenants
        sweep.append(cell)
    out["crossover"] = {"sweep": sweep, "first_client_flip": flips}

    # (d) explain() under the pressure where only the Pallas client flips
    mid = next((c["tenants"] for c in sweep
                if c["pallas"] == "client" and c["numpy"] == "osd"), None)
    out["crossover"]["split_tenants"] = mid
    if mid is not None:
        for osd in fs.store.osds:
            osd.background_load = mid * osd.threads
        plans = {}
        for backend in ("numpy", "pallas"):
            fmt = AdaptiveFormat(decode_backend=backend,
                                 client_threads=CLIENT_THREADS)
            plan = ds.query(format=fmt).filter(pred).explain()
            task_line = next(l for l in plan.splitlines()
                             if "placement=" in l)
            plans[backend] = task_line.strip()
        out["explain"] = plans
    for osd in fs.store.osds:
        osd.background_load = 0
    return out


def check_claims(out: dict) -> list[str]:
    flips = out["crossover"]["first_client_flip"]
    explain = out.get("explain", {})
    claims = [
        ("backends byte-identical on real scans",
         out["identical"]),
        ("modeled accelerator decode rate clears measured host rate 10x+",
         out["rates"]["pallas_modeled_roofline_Bps"]
         > 10 * out["rates"]["host_measured_Bps"]),
        ("shipped pallas prior is conservative vs the roofline",
         out["rates"]["pallas_prior_Bps"]
         < out["rates"]["pallas_modeled_roofline_Bps"]),
        ("pallas client flips to client placement at lower pressure",
         flips["pallas"] is not None
         and (flips["numpy"] is None
              or flips["pallas"] < flips["numpy"])),
        ("explain() names the backend and the flipped placement",
         "backend[client]=pallas[" in explain.get("pallas", "")
         and "placement=client" in explain.get("pallas", "")
         and "placement=osd" in explain.get("numpy", "")),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    out = run()
    out["claims"] = check_claims(out)
    save_result("decode_backend", out)
    print(f"# decode_backend: {out['rows']} rows, {out['fragments']} "
          f"fragments")
    for c in out["scans"]:
        print(f"scan={c['scan']} rows_out={c['rows_out']} "
              f"identical={c['identical']} numpy={c['numpy_wall_s']}s "
              f"pallas(interpret)={c['pallas_interpret_wall_s']}s")
    r = out["rates"]
    print(f"host measured {r['host_measured_Bps'] / 1e6:.0f} MB/s | "
          f"pallas roofline {r['pallas_modeled_roofline_Bps'] / 1e9:.0f} "
          f"GB/s | prior {r['pallas_prior_Bps'] / 1e9:.1f} GB/s")
    print("tenants," + ",".join(f"{b}" for b in ("numpy", "pallas")))
    for c in out["crossover"]["sweep"]:
        print(f"{c['tenants']},{c['numpy']},{c['pallas']}")
    for line in out["claims"]:
        print(line)


if __name__ == "__main__":
    main()
