"""Training-ingest end-to-end: pushdown vs client scan feeding train_step.

The TPU-fleet adaptation of the paper (DESIGN.md §2): a training host must
keep an accelerator fed from columnar shards under a quality-filter
predicate.  We train a real (tiny) model for a few steps per placement and
account (a) host CPU burned on ingest, (b) wire bytes into the host,
(c) ingest stall time per step with the double-buffered prefetcher.

Claim (the paper's, transposed): pushdown moves filter/decode CPU off the
training host, and under selective predicates cuts wire bytes — the host
stops being the input bottleneck.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.aformat.expressions import field
from repro.configs import smoke_config
from repro.core import dataset, make_cluster
from repro.data import PipelineConfig, TokenPipeline, synth_corpus, \
    write_corpus
from repro.launch.mesh import make_local_mesh
from repro.sharding import default_rules
from repro.train import optim, step as step_mod

STEPS = 12
SEQ, BATCH = 128, 8


def _model():
    cfg = smoke_config("starcoder2-7b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128, d_ff=256,
                              num_heads=4, num_kv_heads=4, head_dim=32,
                              vocab_size=4096, remat=False)
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    opt = optim.OptConfig(peak_lr=1e-3)
    state, _ = step_mod.init_state(cfg, opt, jax.random.key(0))
    fn = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt),
                 donate_argnums=(0,))
    return cfg, state, fn


def run() -> dict:
    fs = make_cluster(8)
    corpus = synth_corpus(800, mean_doc_len=400, vocab_size=4096, seed=0)
    write_corpus(fs, "/corpus", corpus, num_shards=8,
                 row_group_rows=16384)
    ds = dataset(fs, "/corpus")
    pred = field("quality") > 0.7          # ~30% of documents survive
    out: dict = {"steps": STEPS, "seq": SEQ, "batch": BATCH,
                 "corpus_rows": ds.num_rows, "formats": {}}

    for fmt in ("parquet", "pushdown"):
        cfg, state, fn = _model()
        pcfg = PipelineConfig(seq_len=SEQ, local_batch=BATCH,
                              predicate=pred, format=fmt, num_threads=1,
                              prefetch=2, seed=7)
        pipe = TokenPipeline(ds, pcfg)
        it = iter(pipe)
        stall_s = 0.0
        t_start = time.perf_counter()
        loss = None
        for _ in range(STEPS):
            t0 = time.perf_counter()
            batch = next(it)
            stall_s += time.perf_counter() - t0
            state, mets = fn(state, {k: jnp.asarray(v)
                                     for k, v in batch.items()})
        loss = float(mets["loss"])
        wall = time.perf_counter() - t_start
        st = pipe.stats()
        out["formats"][fmt] = {
            "host_ingest_cpu_s": st["client_cpu_s"],
            "storage_cpu_s": st["osd_cpu_s"],
            "wire_mb": round(st["wire_bytes"] / 1e6, 3),
            "ingest_stall_s": round(stall_s, 4),
            "wall_s": round(wall, 3),
            "final_loss": round(loss, 4),
            "tokens_trained": STEPS * SEQ * BATCH,
        }
    pq, pd = out["formats"]["parquet"], out["formats"]["pushdown"]
    out["claims"] = [
        f"{'PASS' if pd['host_ingest_cpu_s'] < pq['host_ingest_cpu_s'] * 0.5 else 'FAIL'}"
        "  pushdown cuts host ingest CPU by >2x",
        f"{'PASS' if pd['wire_mb'] < pq['wire_mb'] else 'FAIL'}"
        "  selective pushdown ships fewer bytes to the host",
        f"{'PASS' if abs(pd['final_loss'] - pq['final_loss']) < 0.2 else 'FAIL'}"
        "  both placements train identically (same data order)",
    ]
    return out


def main():
    out = run()
    save_result("ingest_train", out)
    print(f"# ingest_train: {STEPS} steps of {BATCH}x{SEQ} from "
          f"{out['corpus_rows']} corpus rows, quality>0.7 pushdown")
    for fmt, r in out["formats"].items():
        print(f"{fmt:9s} host_cpu={r['host_ingest_cpu_s']}s "
              f"storage_cpu={r['storage_cpu_s']}s wire={r['wire_mb']}MB "
              f"stall={r['ingest_stall_s']}s loss={r['final_loss']}")
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
