"""Training-ingest end-to-end: the sharded reader feeding train_step.

The TPU-fleet adaptation of the paper (DESIGN.md §2): a training host
must keep an accelerator fed from columnar shards under a quality-filter
predicate.  We train a real (tiny) model for a few steps per placement
through ``repro.ingest.ShardedReader`` — every scan goes through the
query plan, the shared streaming executor, and a registered bulk-lane
ingest tenant — and account (a) host CPU burned on ingest, (b) wire
bytes into the host, (c) ingest stall time per step with the
double-buffered prefetcher.

Claims (the paper's, transposed, plus the reader's own contracts):
pushdown moves filter/decode CPU off the training host and under a
selective predicate ships a fraction of the client-scan wire bytes; both
placements train identically (same deterministic batch stream); a reader
restored from its checkpointed ``ReaderState`` continues byte-for-byte;
and ingest-as-tenant coexists with an interactive scanner without
shedding it.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.configs import smoke_config
from repro.core import dataset, make_cluster
from repro.data import synth_corpus, write_corpus
from repro.dataset.qos import TenantRegistry
from repro.ingest import ReaderConfig, ReaderState, ShardedReader
from repro.launch.mesh import make_local_mesh
from repro.sharding import default_rules
from repro.train import optim, step as step_mod

STEPS = 12
DOCS = 800
SEQ, BATCH = 128, 8
RESUME_BATCHES = 8          # length of the resume-exactness probe
RESUME_CUT = 4              # checkpoint/kill after this many
QOS_QUERIES = 4             # interactive queries raced against ingest


def _model():
    cfg = smoke_config("starcoder2-7b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128, d_ff=256,
                              num_heads=4, num_kv_heads=4, head_dim=32,
                              vocab_size=4096, remat=False)
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    opt = optim.OptConfig(peak_lr=1e-3)
    state, _ = step_mod.init_state(cfg, opt, jax.random.key(0))
    fn = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt),
                 donate_argnums=(0,))
    return cfg, state, fn


def _reader_cfg(fmt: str, pred, **kw) -> ReaderConfig:
    return ReaderConfig(seq_len=SEQ, local_batch=BATCH, predicate=pred,
                        format=fmt, num_threads=1, prefetch=2, seed=7,
                        **kw)


def _resume_arm(ds, pred) -> dict:
    """Cut the stream at RESUME_CUT, round-trip the state through its
    array encoding (what CheckpointManager stores), restore, and compare
    the continuation byte-for-byte against an uninterrupted run."""
    cfg = _reader_cfg("pushdown", pred)
    ref = ShardedReader(ds, cfg)
    full = [next(ref) for _ in range(RESUME_BATCHES)]
    ref.close()

    a = ShardedReader(ds, cfg)
    head = [next(a) for _ in range(RESUME_CUT)]
    arrays = a.checkpoint().to_arrays()
    a.close()  # the kill: prefetched-but-undelivered batches are lost

    b = ShardedReader(ds, cfg,
                      state=ReaderState.from_arrays(arrays))
    tail = [next(b) for _ in range(RESUME_BATCHES - RESUME_CUT)]
    b.close()

    resumed = head + tail
    exact = all(
        np.array_equal(x["tokens"], y["tokens"])
        and np.array_equal(x["labels"], y["labels"])
        for x, y in zip(resumed, full))
    return {"batches": RESUME_BATCHES, "cut_at": RESUME_CUT,
            "byte_identical": bool(exact)}


def _qos_arm(ds, pred) -> dict:
    """Train through a registered bulk ingest tenant while an
    interactive tenant runs deadline-carrying scans on the same
    cluster; count sheds (target: zero)."""
    import threading

    registry = TenantRegistry(slots_per_osd=2)
    registry.register("dash", weight=4.0, lane="interactive",
                      deadline_s=5.0)
    reader = ShardedReader(ds, _reader_cfg("pushdown", pred,
                                           registry=registry))
    stop = threading.Event()

    def churn():
        try:
            while not stop.is_set():
                next(reader)
        except StopIteration:   # reader.close() ends the stream
            pass

    t = threading.Thread(target=churn, daemon=True)
    t.start()
    completed = sheds = 0
    lat = []
    try:
        for _ in range(QOS_QUERIES):
            t0 = time.perf_counter()
            out = ds.query(tenant=registry.context("dash"),
                           num_threads=2).filter(
                field("quality") > 0.5).select("token").to_table()
            lat.append(time.perf_counter() - t0)
            if isinstance(out, Table):
                completed += 1
            else:
                sheds += 1
    finally:
        stop.set()
        reader.close()
        t.join(timeout=10.0)
    ing = reader.stats()
    return {"interactive_queries": QOS_QUERIES,
            "interactive_completed": completed,
            "interactive_sheds": sheds,
            "interactive_p_max_ms": round(max(lat) * 1e3, 1),
            "ingest_batches": ing["batches"],
            "ingest_rows": ing["rows"],
            "tenants_seen": sorted(registry.by_tenant())}


def run() -> dict:
    fs = make_cluster(8)
    corpus = synth_corpus(DOCS, mean_doc_len=400, vocab_size=4096, seed=0)
    write_corpus(fs, "/corpus", corpus, num_shards=8,
                 row_group_rows=16384)
    ds = dataset(fs, "/corpus")
    pred = field("quality") > 0.7          # ~30% of documents survive
    out: dict = {"steps": STEPS, "seq": SEQ, "batch": BATCH,
                 "corpus_rows": ds.num_rows, "formats": {}}

    for fmt in ("parquet", "pushdown"):
        cfg, state, fn = _model()
        reader = ShardedReader(ds, _reader_cfg(fmt, pred))
        stall_s = 0.0
        t_start = time.perf_counter()
        loss = None
        for _ in range(STEPS):
            t0 = time.perf_counter()
            batch = next(reader)
            stall_s += time.perf_counter() - t0
            state, mets = fn(state, {k: jnp.asarray(v)
                                     for k, v in batch.items()})
        loss = float(mets["loss"])
        wall = time.perf_counter() - t_start
        st = reader.stats()
        reader.close()
        out["formats"][fmt] = {
            "host_ingest_cpu_s": st["client_cpu_s"],
            "storage_cpu_s": st["osd_cpu_s"],
            "wire_mb": round(st["wire_bytes"] / 1e6, 3),
            "ingest_stall_s": round(stall_s, 4),
            "wall_s": round(wall, 3),
            "final_loss": round(loss, 4),
            "tokens_trained": STEPS * SEQ * BATCH,
        }

    out["resume"] = _resume_arm(ds, pred)
    out["qos"] = _qos_arm(ds, pred)
    out["claims"] = check_claims(out)
    return out


def check_claims(out: dict) -> list[str]:
    pq, pd = out["formats"]["parquet"], out["formats"]["pushdown"]
    rs, qos = out["resume"], out["qos"]
    return [
        f"{'PASS' if pd['host_ingest_cpu_s'] < pq['host_ingest_cpu_s'] * 0.5 else 'FAIL'}"
        "  pushdown cuts host ingest CPU by >2x",
        f"{'PASS' if pd['wire_mb'] < pq['wire_mb'] * 0.5 else 'FAIL'}"
        "  selective pushdown ships <0.5x the client-scan wire bytes",
        f"{'PASS' if abs(pd['final_loss'] - pq['final_loss']) < 0.2 else 'FAIL'}"
        "  both placements train identically (same data order)",
        f"{'PASS' if rs['byte_identical'] else 'FAIL'}"
        f"  restored reader replays batches {rs['cut_at'] + 1}.."
        f"{rs['batches']} byte-identically (resume exactness)",
        f"{'PASS' if qos['interactive_sheds'] == 0 and qos['interactive_completed'] == qos['interactive_queries'] else 'FAIL'}"
        "  ingest-as-tenant sheds no interactive queries",
    ]


def main():
    out = run()
    save_result("ingest_train", out)
    print(f"# ingest_train: {STEPS} steps of {BATCH}x{SEQ} from "
          f"{out['corpus_rows']} corpus rows, quality>0.7 pushdown")
    for fmt, r in out["formats"].items():
        print(f"{fmt:9s} host_cpu={r['host_ingest_cpu_s']}s "
              f"storage_cpu={r['storage_cpu_s']}s wire={r['wire_mb']}MB "
              f"stall={r['ingest_stall_s']}s loss={r['final_loss']}")
    print(f"resume    cut@{out['resume']['cut_at']} "
          f"byte_identical={out['resume']['byte_identical']}")
    print(f"qos       interactive {out['qos']['interactive_completed']}/"
          f"{out['qos']['interactive_queries']} completed, "
          f"{out['qos']['interactive_sheds']} shed, ingest streamed "
          f"{out['qos']['ingest_batches']} batches")
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
