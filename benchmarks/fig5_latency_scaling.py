"""Fig. 5 reproduction: query latency vs storage nodes x selectivity.

Paper setup: 4/8/16 storage nodes, one client, selectivities 100%/10%/1%,
Parquet (client scan) vs RADOS Parquet (pushdown).  Claims to reproduce:
  (a) pushdown wins at 10% and 1% and keeps improving with node count;
  (b) client scan is CPU-bound on the client - node count barely matters;
  (c) at 100% selectivity pushdown is network-bound (Arrow IPC wire >
      compressed Parquet wire) and does NOT win.
"""

from __future__ import annotations

from benchmarks.common import (build_cluster, save_result,
                               selectivity_predicate, taxi_like_table)
from repro.dataset import dataset
from repro.storage.perfmodel import (ClusterSpec, rebalance_nodes,
                                     simulate_scan)

ROWS = 600_000
ROWS_PER_FILE = 4_096     # ~150 fragments: >> node thread capacity, so the
                          # replay sees real queueing (paper: ~2400 objects)
PROJECT = ["trip_id", "fare_amount", "tip_amount", "duration_s"]
NODE_COUNTS = (4, 8, 16)
SELECTIVITIES = (1.0, 0.1, 0.01)
CLIENT_CORES = 8    # m510: 8 physical cores; the paper's 16 scan threads
                    # share them (SMT), so 8 core-equivalents of decode


def run(rows: int = ROWS) -> dict:
    table = taxi_like_table(rows)
    fs = build_cluster(16, table, rows_per_file=ROWS_PER_FILE)
    ds = dataset(fs, "/taxi")
    out: dict = {"rows": rows, "fragments": len(ds.fragments()),
                 "cells": []}
    # warmup: first-touch costs (allocator, zlib tables) out of the timings
    ds.scanner(format="pushdown", columns=PROJECT, num_threads=1).to_table()
    for sel in SELECTIVITIES:
        pred = selectivity_predicate(table, sel)
        for fmt in ("parquet", "pushdown"):
            # num_threads=1: tasks are *measured* sequentially on this
            # 1-core host (clean per-task costs); parallelism is applied in
            # the ClusterSpec replay, not here
            sc = ds.scanner(format=fmt, columns=PROJECT, predicate=pred,
                            num_threads=1)
            result = sc.to_table()
            tasks = sc.metrics.tasks
            for nodes in NODE_COUNTS:
                replay = simulate_scan(
                    rebalance_nodes(tasks, nodes),
                    ClusterSpec(nodes=nodes, client_threads=CLIENT_CORES))
                out["cells"].append({
                    "selectivity": sel, "format": fmt, "nodes": nodes,
                    "rows_out": len(result),
                    "latency_s": round(replay.makespan_s, 4),
                    "bottleneck": replay.bottleneck,
                    "wire_mb": round(sc.metrics.wire_bytes / 1e6, 2),
                })
    return out


def check_claims(out: dict) -> list[str]:
    """Validate the paper's three Fig.-5 claims against the replay."""
    cells = {(c["selectivity"], c["format"], c["nodes"]): c
             for c in out["cells"]}
    claims = []

    def lat(sel, fmt, n):
        return cells[(sel, fmt, n)]["latency_s"]

    ok_a = all(lat(s, "pushdown", 16) < lat(s, "parquet", 16)
               for s in (0.1, 0.01)) and \
        all(lat(s, "pushdown", 16) < lat(s, "pushdown", 4)
            for s in (0.1, 0.01))
    claims.append(("pushdown wins at 10%/1% and scales with nodes", ok_a))
    ok_b = all(abs(lat(s, "parquet", 4) - lat(s, "parquet", 16))
               < 0.15 * lat(s, "parquet", 4) for s in SELECTIVITIES)
    claims.append(("client scan does not scale with storage nodes", ok_b))
    c100 = cells[(1.0, "pushdown", 16)]
    ok_c = c100["bottleneck"] == "network" and \
        lat(1.0, "pushdown", 16) >= 0.9 * lat(1.0, "parquet", 16)
    claims.append(("100% selectivity: pushdown network-bound, no win", ok_c))
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    out = run()
    out["claims"] = check_claims(out)
    save_result("fig5_latency_scaling", out)
    print(f"# fig5: {out['rows']} rows, {out['fragments']} fragments")
    print("selectivity,format,nodes,latency_s,bottleneck,wire_mb")
    for c in out["cells"]:
        print(f"{c['selectivity']},{c['format']},{c['nodes']},"
              f"{c['latency_s']},{c['bottleneck']},{c['wire_mb']}")
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
