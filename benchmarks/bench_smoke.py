"""CI bench-smoke driver: run the per-push benchmark lane, emit BENCH_*.json.

Runs ``hedged_straggler`` at its full (still CI-sized) configuration, a
small-config ``adaptive_scan`` sweep, and a small ``aggregate_pushdown``
grid; each result lands in ``results/bench/BENCH_<name>.json`` with a
top-level ``wall_s`` the regression gate (``check_regression.py``)
compares against the checked-in ``benchmarks/bench_baseline.json``.

Claims inside each benchmark are recorded in the JSON (and surfaced in
the job log) but only the wall-time gate fails the lane: CI machines are
noisy, and the correctness claims are pinned by the test suite instead.

    PYTHONPATH=src:. python benchmarks/bench_smoke.py
"""

from __future__ import annotations

import json
import os
import time

from benchmarks.common import RESULTS_DIR


def _emit(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"bench-smoke: wrote {path} (wall {payload['wall_s']:.3f}s)")


def run_hedged_straggler() -> dict:
    from benchmarks import hedged_straggler
    t0 = time.perf_counter()
    out = hedged_straggler.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = hedged_straggler.check_claims(out)
    return out


def run_adaptive_scan_small() -> dict:
    from benchmarks import adaptive_scan
    # small config: same shape, a third of the rows, half the sweep —
    # enough to exercise every code path per push; the full sweep stays a
    # manual / nightly benchmark
    adaptive_scan.ROWS = 60_000
    adaptive_scan.CLIENTS = (1, 4, 32)
    t0 = time.perf_counter()
    out = adaptive_scan.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = adaptive_scan.check_claims(out)
    out["small_config"] = True
    return out


def run_aggregate_pushdown_small() -> dict:
    from benchmarks import aggregate_pushdown
    aggregate_pushdown.ROWS = 60_000
    t0 = time.perf_counter()
    out = aggregate_pushdown.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = aggregate_pushdown.check_claims(out)
    out["small_config"] = True
    return out


def run_limit_pushdown_small() -> dict:
    from benchmarks import limit_pushdown
    limit_pushdown.ROWS = 80_000
    t0 = time.perf_counter()
    out = limit_pushdown.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = limit_pushdown.check_claims(out)
    out["small_config"] = True
    return out


def run_compaction_small() -> dict:
    from benchmarks import compaction
    compaction.APPENDS = 24
    compaction.ROWS_PER_APPEND = 800
    t0 = time.perf_counter()
    out = compaction.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = compaction.check_claims(out)
    out["small_config"] = True
    return out


def run_semi_join_small() -> dict:
    from benchmarks import semi_join
    semi_join.ROWS = 60_000
    t0 = time.perf_counter()
    out = semi_join.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = semi_join.check_claims(out)
    out["small_config"] = True
    return out


def run_decode_backend_small() -> dict:
    from benchmarks import decode_backend
    # small config: the interpret-mode Pallas scans dominate the wall;
    # 40k rows still cover every kernel/fallback route and the sweep
    decode_backend.ROWS = 40_000
    t0 = time.perf_counter()
    out = decode_backend.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = decode_backend.check_claims(out)
    out["small_config"] = True
    return out


def run_multi_tenant_small() -> dict:
    from benchmarks import multi_tenant
    # small config: fewer latency samples; the arms, the hostile
    # 8-scanner fleet, and the p99 claims are unchanged
    multi_tenant.SAMPLES = 30
    t0 = time.perf_counter()
    out = multi_tenant.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = multi_tenant.check_claims(out)
    out["small_config"] = True
    return out


def run_ingest_train_small() -> dict:
    from benchmarks import ingest_train
    # small config: fewer train steps and a smaller corpus; all five
    # arms (both placements, resume-exactness, QoS coexistence) run
    ingest_train.STEPS = 6
    ingest_train.DOCS = 300
    ingest_train.QOS_QUERIES = 2
    t0 = time.perf_counter()
    out = ingest_train.run()
    out["wall_s"] = time.perf_counter() - t0
    out["small_config"] = True
    return out


def run_encoding_advisor_small() -> dict:
    from benchmarks import encoding_advisor
    # small config: fewer rows/lookups; both arms, all five claims run
    encoding_advisor.ROWS = 16_000
    encoding_advisor.LOOKUPS = 4
    encoding_advisor.COMPACT_ROWS = 8_000
    t0 = time.perf_counter()
    out = encoding_advisor.run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = encoding_advisor.check_claims(out)
    out["small_config"] = True
    return out


def run_kernels() -> dict:
    from benchmarks import kernel_bench
    t0 = time.perf_counter()
    out = {
        "predicate_fused": kernel_bench.bench_predicate(),
        "dict_decode": kernel_bench.bench_dict(),
        "token_pack": kernel_bench.bench_pack(),
    }
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = [
        f"{'PASS' if v['allclose'] else 'FAIL'}  {k} matches its oracle"
        for k, v in out.items() if isinstance(v, dict)]
    return out


BENCHES = {
    "hedged_straggler": run_hedged_straggler,
    "adaptive_scan": run_adaptive_scan_small,
    "aggregate_pushdown": run_aggregate_pushdown_small,
    "limit_pushdown": run_limit_pushdown_small,
    "compaction": run_compaction_small,
    "semi_join": run_semi_join_small,
    "decode_backend": run_decode_backend_small,
    "multi_tenant": run_multi_tenant_small,
    "ingest_train": run_ingest_train_small,
    "encoding_advisor": run_encoding_advisor_small,
    "kernels": run_kernels,
}


def main():
    for name, fn in BENCHES.items():
        print(f"== bench-smoke: {name}")
        out = fn()
        _emit(name, out)
        for line in out.get("claims", []):
            print(f"  {line}")


if __name__ == "__main__":
    main()
