"""Physical-design benchmark: bloom-index point lookups + the advisor.

Two arms, matching the PR's acceptance bars:

  (1) Point lookup over a high-cardinality key, bloom-indexed vs
      stats-only.  Zone stats cannot refute an equality probe when every
      row group's [min, max] spans the key space, so the stats-only arm
      reads almost every row group; the per-row-group bloom blocks
      refute all but the true one.  Claim: the indexed lookup ships
      <=10% of the stats-only wire bytes with identical results.

  (2) Compaction with the measured encoding advisor vs the one-shot
      heuristic, over a taxi-like table whose quantized floats, bounded
      ints, and jittered timestamps the heuristic mis-encodes.  Claim:
      the advisor arm stores >=25% fewer bytes than the fragmented
      input, and never more than the heuristic arm.

    PYTHONPATH=src:. python benchmarks/encoding_advisor.py
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save_result
from repro.aformat import parquet
from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import MutableDataset, dataset, make_cluster, write_flat

ROWS = 40_000
ROW_GROUP_ROWS = 500
LOOKUPS = 8
NODES = 4
COMPACT_ROWS = 16_000
PIECE_ROWS = 800


def _keyed_table(n: int, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    return Table.from_pydict({
        "id": rng.permutation(np.arange(n, dtype=np.int64) * 13),
        "val": rng.normal(size=n).astype(np.float64),
        "tag": np.asarray([f"u{i:07d}" for i in range(n)], object),
    })


def _advisor_table(n: int, seed: int = 11) -> Table:
    """The taxi-like shape where the heuristic leaves bytes behind
    (quantized fares, bounded odometer, jittered timestamps)."""
    rng = np.random.default_rng(seed)
    return Table.from_pydict({
        "fare_amount": np.round(
            np.clip(rng.gamma(2.0, 7.5, n), 0, 74.75) * 4) / 4,
        "odometer": rng.integers(0, 1 << 17, n).astype(np.int64),
        "vendor": rng.integers(1, 3, n).astype(np.int64),
        "passenger_count": rng.integers(1, 7, n).astype(np.int32),
        "payment_type": rng.choice(["card", "cash", "disp"], n),
        "pickup_ts": (10 ** 9 + np.arange(n) * 7
                      + rng.integers(-10, 11, n)).astype(np.int64),
    })


def _point_lookup_arm() -> dict:
    t = _keyed_table(ROWS)
    ids = t.column("id").values
    fs_idx, fs_plain = make_cluster(NODES), make_cluster(NODES)
    write_flat(fs_idx, "/d/t.arw", t, row_group_rows=ROW_GROUP_ROWS)
    data = parquet.write_table(t, row_group_rows=ROW_GROUP_ROWS,
                               build_indexes=False)
    su = max(4096, -(-len(data) // 4096) * 4096)
    fs_plain.write_file("/d/t.arw", data, stripe_unit=su,
                        xattrs={"layout": "flat"})
    cells = {}
    rng = np.random.default_rng(1)
    targets = [int(v) for v in rng.choice(ids, LOOKUPS, replace=False)]
    for name, fs in (("indexed", fs_idx), ("stats_only", fs_plain)):
        wire = pruned = 0
        t0 = time.perf_counter()
        for target in targets:
            ds = dataset(fs, "/d")
            sc = ds.scanner(format="parquet",
                            predicate=(field("id") == target),
                            num_threads=2)
            out = sc.to_table()
            assert len(out) == 1 and out.column("id").values[0] == target
            wire += sc.metrics.wire_bytes - sc.metrics.discovery_bytes
            pruned += sc.metrics.fragments_index_pruned
        cells[name] = {
            "wall_s": time.perf_counter() - t0,
            "wire_bytes": wire,
            "index_pruned_fragments": pruned,
            "lookups": LOOKUPS,
        }
    cells["wire_ratio"] = (cells["indexed"]["wire_bytes"]
                           / cells["stats_only"]["wire_bytes"])
    return cells


def _compaction_arm() -> dict:
    t = _advisor_table(COMPACT_ROWS)
    cells = {}
    for name, advisor in (("advisor", True), ("heuristic", False)):
        fs = make_cluster(NODES)
        md = MutableDataset.create(fs, "/adv")
        for start in range(0, len(t), PIECE_ROWS):
            md.append(t.slice(start, PIECE_ROWS),
                      row_group_rows=PIECE_ROWS)
        t0 = time.perf_counter()
        report = md.compact(target_rows=COMPACT_ROWS, advisor=advisor)
        cells[name] = {
            "wall_s": time.perf_counter() - t0,
            "bytes_before": report.bytes_before,
            "bytes_after": report.bytes_after,
            "encodings": dict(report.encodings),
        }
        # both arms stay lossless
        out = md.query(format="pushdown", num_threads=2).to_table()
        cells[name]["exact"] = (
            sorted(out.column("odometer").values.tolist())
            == sorted(t.column("odometer").values.tolist()))
    adv = cells["advisor"]
    adv["bytes_cut_frac"] = 1 - adv["bytes_after"] / adv["bytes_before"]
    return cells


def run() -> dict:
    return {
        "rows": ROWS,
        "row_group_rows": ROW_GROUP_ROWS,
        "compact_rows": COMPACT_ROWS,
        "point_lookup": _point_lookup_arm(),
        "compaction": _compaction_arm(),
    }


def check_claims(out: dict) -> list[str]:
    pl = out["point_lookup"]
    co = out["compaction"]
    claims = [
        (
            "bloom-indexed point lookup ships <=10% of stats-only wire",
            pl["wire_ratio"] <= 0.10,
        ),
        (
            "index pruning refutes row groups stats cannot",
            pl["indexed"]["index_pruned_fragments"]
            > pl["stats_only"]["index_pruned_fragments"],
        ),
        (
            "advisor compaction cuts >=25% of stored bytes",
            co["advisor"]["bytes_cut_frac"] >= 0.25,
        ),
        (
            "advisor arm never stores more than the heuristic arm",
            co["advisor"]["bytes_after"] <= co["heuristic"]["bytes_after"],
        ),
        (
            "both compaction arms stay lossless",
            co["advisor"]["exact"] and co["heuristic"]["exact"],
        ),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    t0 = time.perf_counter()
    out = run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = check_claims(out)
    save_result("encoding_advisor", out)
    pl = out["point_lookup"]
    print(f"# encoding_advisor: {out['rows']} rows, "
          f"rg={out['row_group_rows']}, {LOOKUPS} point lookups")
    print("arm,wall_ms,wire_B,index_pruned")
    for name in ("indexed", "stats_only"):
        c = pl[name]
        print(f"{name},{c['wall_s'] * 1e3:.1f},{c['wire_bytes']},"
              f"{c['index_pruned_fragments']}")
    print(f"point-lookup wire ratio: {pl['wire_ratio']:.4f}")
    co = out["compaction"]
    print("arm,wall_ms,bytes_before,bytes_after")
    for name in ("advisor", "heuristic"):
        c = co[name]
        print(f"{name},{c['wall_s'] * 1e3:.1f},{c['bytes_before']},"
              f"{c['bytes_after']}")
    print(f"advisor bytes cut: {co['advisor']['bytes_cut_frac']:.1%}")
    print("advisor encodings: " + ", ".join(
        f"{k}={v}" for k, v in sorted(co["advisor"]["encodings"].items())))
    for line in out["claims"]:
        print(line)


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    main()
