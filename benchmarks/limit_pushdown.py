"""Limit-pushdown benchmark: LIMIT 10 vs a full scan on a striped store.

The lazy query plan pushes a row budget all the way down: the optimizer
truncates the task list where predicate-free fragments already guarantee
the budget, the executor stops issuing fragments the moment the budget is
met (cancelling still-queued work), and ``scan_op`` ships at most the
budgeted rows — so storage nodes stop decoding early and almost nothing
crosses the wire.

Measured here over a large striped dataset, static pushdown placement:

  (1) ``query().limit(10)``                    — plan-time truncation;
  (2) ``query().filter(pred).limit(10)``       — runtime early exit (the
      predicate is selective-but-unprovable, so pruning cannot help);
  (3) the full scan / full filtered scan       — the wire baseline.

Claims (emitted in the JSON report):
  (a) both limited queries return exactly 10 valid rows;
  (b) limit-10 ships <10% of the full-scan wire bytes (plan truncation);
  (c) the filtered limit-10 ships <10% of the filtered full-scan wire;
  (d) the executor scanned fewer fragments than the plan holds (early
      exit is visible in the task records).

    PYTHONPATH=src:. python benchmarks/limit_pushdown.py
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import save_result, taxi_like_table
from repro.aformat.expressions import field
from repro.core import dataset, make_cluster, write_striped

ROWS = int(os.environ.get("LIMIT_BENCH_ROWS", 200_000))
ROWS_PER_GROUP = 4_096
NODES = 8
NUM_THREADS = 8
LIMIT = 10


def build_striped_cluster(table):
    fs = make_cluster(NODES)
    n = len(table)
    per_file = ROWS_PER_GROUP * 4
    for i, start in enumerate(range(0, n, per_file)):
        part = table.slice(start, min(per_file, n - start))
        write_striped(
            fs, f"/taxi/part{i:05d}.arw", part, row_group_rows=ROWS_PER_GROUP
        )
    return fs


def _task_wire(metrics) -> int:
    return sum(t.wire_bytes for t in metrics.tasks)


def _run_query(q):
    t0 = time.perf_counter()
    out = q.to_table()
    wall = time.perf_counter() - t0
    return out, {
        "wall_s": wall,
        "wire_bytes": _task_wire(q.metrics),
        "tasks": len(q.metrics.tasks),
        "fragments_total": q.metrics.fragments_total,
        "rows": len(out),
    }


def run() -> dict:
    table = taxi_like_table(ROWS)
    fs = build_striped_cluster(table)
    ds = dataset(fs, "/taxi")
    # selective but not stats-provable: fare straddles every row group
    thr = float(np.quantile(table.column("fare_amount").values, 0.5))
    pred = field("fare_amount") > thr
    valid = set(
        table.column("trip_id")
        .values[table.column("fare_amount").values > thr]
        .tolist()
    )

    # warmup (allocator, zlib tables, footer caches)
    ds.query(format="pushdown").select("fare_amount").to_table()

    out: dict = {"rows": ROWS, "fragments": len(ds.fragments()), "cells": {}}

    full, cell = _run_query(ds.query(format="pushdown", num_threads=NUM_THREADS))
    out["cells"]["full_scan"] = cell

    lim, cell = _run_query(
        ds.query(format="pushdown", num_threads=NUM_THREADS).limit(LIMIT)
    )
    cell["rows_ok"] = len(lim) == LIMIT
    out["cells"]["limit"] = cell

    full_f, cell = _run_query(
        ds.query(format="pushdown", num_threads=NUM_THREADS).filter(pred)
    )
    out["cells"]["full_filtered"] = cell

    lim_f, cell = _run_query(
        ds.query(format="pushdown", num_threads=NUM_THREADS)
        .filter(pred)
        .limit(LIMIT)
    )
    cell["rows_ok"] = (
        len(lim_f) == LIMIT
        and set(lim_f.column("trip_id").values.tolist()) <= valid
    )
    out["cells"]["limit_filtered"] = cell
    return out


def check_claims(out: dict) -> list[str]:
    c = out["cells"]
    claims = [
        (
            "both limited queries return exactly LIMIT valid rows",
            c["limit"]["rows_ok"] and c["limit_filtered"]["rows_ok"],
        ),
        (
            "limit-10 ships <10% of the full-scan wire bytes",
            c["limit"]["wire_bytes"] < 0.10 * c["full_scan"]["wire_bytes"],
        ),
        (
            "filtered limit-10 ships <10% of the filtered-scan wire bytes",
            c["limit_filtered"]["wire_bytes"]
            < 0.10 * c["full_filtered"]["wire_bytes"],
        ),
        (
            "early exit: fewer fragments scanned than planned",
            c["limit"]["tasks"] < c["limit"]["fragments_total"]
            and c["limit_filtered"]["tasks"]
            < c["limit_filtered"]["fragments_total"],
        ),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    t0 = time.perf_counter()
    out = run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = check_claims(out)
    save_result("limit_pushdown", out)
    print(f"# limit_pushdown: {out['rows']} rows, {out['fragments']} fragments")
    print("query,wall_ms,wire_B,tasks/total")
    for name, cell in out["cells"].items():
        print(
            f"{name},{cell['wall_s'] * 1e3:.1f},{cell['wire_bytes']},"
            f"{cell['tasks']}/{cell['fragments_total']}"
        )
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
