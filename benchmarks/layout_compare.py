"""§2.3 comparison: Striped vs Split vs Flat layouts.

Measures what the paper discusses qualitatively: storage amplification
(striped pads row groups to a common aligned size; split duplicates footer
metadata in the index), discovery cost (split reads only .index files;
striped reads last objects), and scan latency parity (all layouts feed the
same scan_op, so query results and scan costs must match).
"""

from __future__ import annotations

from benchmarks.common import save_result, selectivity_predicate, \
    taxi_like_table
from repro.core import (dataset, make_cluster, write_flat, write_split,
                        write_striped)
from repro.storage.perfmodel import ClusterSpec, rebalance_nodes, \
    simulate_scan

ROWS = 200_000
FILES = 8
RG_ROWS = 4_096

WRITERS = {"flat": write_flat, "striped": write_striped,
           "split": write_split}


def run() -> dict:
    table = taxi_like_table(ROWS)
    raw_bytes = table.nbytes()
    out: dict = {"rows": ROWS, "in_memory_mb": round(raw_bytes / 1e6, 2),
                 "layouts": {}}
    pred = selectivity_predicate(table, 0.1)
    for layout, writer in WRITERS.items():
        fs = make_cluster(8)
        import time
        t0 = time.perf_counter()
        for i in range(FILES):
            part = table.slice(i * (ROWS // FILES), ROWS // FILES)
            writer(fs, f"/d/p{i}.arw", part, row_group_rows=RG_ROWS)
        write_s = time.perf_counter() - t0
        stored = sum(o.stats.bytes_stored for o in fs.store.osds) \
            / fs.store.replication
        t0 = time.perf_counter()
        ds = dataset(fs, "/d")
        discover_s = time.perf_counter() - t0
        sc = ds.scanner(format="pushdown", columns=["trip_id"],
                        predicate=pred, num_threads=1)
        res = sc.to_table()
        replay = simulate_scan(rebalance_nodes(sc.metrics.tasks, 8),
                               ClusterSpec(nodes=8))
        out["layouts"][layout] = {
            "stored_mb": round(stored / 1e6, 2),
            "amplification": round(stored / raw_bytes, 3),
            "write_s": round(write_s, 3),
            "discovery_bytes": ds.discovery_bytes,
            "discover_s": round(discover_s, 4),
            "fragments": len(ds.fragments()),
            "objects": len(fs.store.list_objects()),
            "scan_latency_s": round(replay.makespan_s, 4),
            "rows_out": len(res),
        }
    rows_out = {l: v["rows_out"] for l, v in out["layouts"].items()}
    out["all_layouts_agree"] = len(set(rows_out.values())) == 1
    return out


def main():
    out = run()
    save_result("layout_compare", out)
    print(f"# layout_compare: {out['rows']} rows, "
          f"{out['in_memory_mb']} MB in-memory")
    cols = ["stored_mb", "amplification", "discovery_bytes", "fragments",
            "objects", "scan_latency_s", "rows_out"]
    print("layout," + ",".join(cols))
    for layout, r in out["layouts"].items():
        print(layout + "," + ",".join(str(r[c]) for c in cols))
    print("all layouts agree on results:", out["all_layouts_agree"])
    return out


if __name__ == "__main__":
    main()
