"""Scan hot-path kernels: Pallas (interpret) vs jnp oracle vs numpy host.

On this CPU container the Pallas kernels run in interpret mode, so their
*wall time is meaningless*; what this harness reports per kernel is
  (a) allclose agreement with the oracle across a shape sweep,
  (b) the work/bytes roofline terms of the kernel on the v5e target
      (analytic: elements, flops, VMEM traffic per tile),
so the TPU-side picture lives next to the host-side numpy baseline that a
storage node would run (the paper's placement).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save_result
from repro.kernels.dict_decode.ops import decode_dictionary
from repro.kernels.predicate_fused.ops import build_program, fused_predicate
from repro.kernels.token_pack.ops import pack_tokens

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _time(fn, *a, reps=3):
    fn(*a)                           # warmup / trace
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*a)
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / reps


def bench_predicate(n=1 << 20):
    rng = np.random.default_rng(0)
    cols = [rng.normal(size=n).astype(np.float32),
            rng.integers(0, 10, n).astype(np.int32)]
    prog = build_program([(0, "gt", 0.5), (1, "ne", 3)], "and")
    got = np.asarray(fused_predicate(cols, prog))
    exp = (cols[0] > 0.5) & (cols[1] != 3)
    host_s = _time(lambda: (cols[0] > 0.5) & (cols[1] != 3))
    # roofline: 2 compares + 1 and over 2 f32 cols -> 8 B/elem, 3 ops/elem
    tpu_mem_s = n * 9 / HBM_BW         # 8B in + 1B mask out
    return {"n": n, "allclose": bool((got == exp).all()),
            "host_numpy_s": round(host_s, 5),
            "tpu_memory_bound_s": round(tpu_mem_s, 7),
            "arithmetic_intensity_flops_per_byte": round(3 / 9, 3)}


def bench_dict(n=1 << 20, d=1024):
    rng = np.random.default_rng(1)
    dic = rng.normal(size=d).astype(np.float32)
    codes = rng.integers(0, d, n).astype(np.int32)
    got = np.asarray(decode_dictionary(codes, dic))
    exp = dic[codes]
    host_s = _time(lambda: dic[codes])
    # one-hot matmul path: 2*TILE*D flops per TILE elems
    flops = 2.0 * n * d
    tpu_compute_s = flops / PEAK_FLOPS_BF16
    tpu_mem_s = n * 8 / HBM_BW
    return {"n": n, "dict": d,
            "allclose": bool(np.allclose(got, exp)),
            "host_numpy_s": round(host_s, 5),
            "tpu_onehot_compute_s": round(tpu_compute_s, 7),
            "tpu_memory_bound_s": round(tpu_mem_s, 7),
            "mxu_beats_gather_below_d": 2048}


def bench_pack(n=1 << 20, density=0.1):
    rng = np.random.default_rng(2)
    vals = rng.integers(0, 1 << 20, n).astype(np.int32)
    mask = rng.random(n) < density
    cap = max(1024, int(n * density * 1.2))
    got, cnt = pack_tokens(vals, mask, cap)
    exp = vals[mask][:cap]
    ok = bool(np.array_equal(np.asarray(got)[: int(cnt)], exp))
    host_s = _time(lambda: vals[mask])
    # per tile: TILE^2 one-hot + 2*TILE^2 matmul flops
    from repro.kernels.token_pack.token_pack import TILE
    flops = (n // TILE + 1) * 3 * TILE * TILE
    return {"n": n, "density": density, "allclose": ok,
            "host_numpy_s": round(host_s, 5),
            "tpu_matmul_compute_s": round(flops / PEAK_FLOPS_BF16, 7),
            "tpu_memory_bound_s": round(n * 9 / HBM_BW, 7)}


def main():
    out = {
        "predicate_fused": bench_predicate(),
        "dict_decode": bench_dict(),
        "token_pack": bench_pack(),
    }
    save_result("kernel_bench", out)
    for k, v in out.items():
        print(f"{k}: {v}")
    assert all(v["allclose"] for v in out.values()), "kernel mismatch!"
    return out


if __name__ == "__main__":
    main()
