"""Multi-tenant QoS benchmark: interactive p99 under hostile bulk load.

Eight bulk scanners hammer the cluster with full-table scans while an
interactive tenant issues small stats-pruned point queries.  Three arms,
same cluster, same queries:

  unloaded   interactive alone — the reference p99
  qos        bulk + interactive share one TenantRegistry: a single
             weighted-fair admission controller (one bulk slot per OSD),
             priority lanes, and interactive preemption slack
  blind      the tenant-blind baseline — every scan brings its own
             private admission controller, so nobody sees anybody
             else's load and the OSD execution slots queue FIFO

The storage nodes are made service-time-dominated (``straggle_factor``
injects real, bounded sleep into every object-class call, held inside
the OSD's execution slots) so queueing behaves like a real cluster
rather than a GIL contest: under QoS the bulk fleet's excess work waits
*in the admission queue* (off-CPU) and an interactive arrival preempts
straight into an OSD slot; tenant-blind, the same arrival waits behind
the whole bulk queue.

Claims (emitted in the JSON report):
  (a) QoS interactive p99 <= 1.25x the unloaded p99;
  (b) tenant-blind interactive p99 >= 3x the QoS p99 — the tax the
      registry removes;
  (c) every bulk scanner kept making progress under QoS (weighted-fair
      slots, not starvation);
  (d) every interactive query returned the correct rows in every arm.

    PYTHONPATH=src:. python benchmarks/multi_tenant.py
"""

from __future__ import annotations

import sys
import threading
import time

import numpy as np

from benchmarks.common import (save_result, selectivity_predicate,
                               taxi_like_table)
from repro.aformat.expressions import field
from repro.core import TenantRegistry, dataset, make_cluster, write_flat

ROWS = 48_000
ROWS_PER_FILE = 2_048
NODES = 4
THREADS_PER_OSD = 2      # OSD execution slots: 1 for bulk + 1 of slack
STRAGGLE = 200.0         # with the cap below: constant-ish service time
STRAGGLE_CAP_S = 0.03    # every cls call sleeps ~this, raw jitter aside
BULK_SCANNERS = 8
BULK_THREADS = 4
BULK_QUEUE_DEPTH = 2     # the blind arm's per-scan controller depth
SLOTS_PER_OSD = 1        # the registry's shared bulk slot budget
PREEMPT_SLACK = 1
SAMPLES = 60
WARMUP_SAMPLES = 5
GAP_S = 0.02
POINT_ROWS = 1_024       # interactive point query: trip_id < POINT_ROWS
PROJECT = ["trip_id", "fare_amount"]


def _build():
    fs = make_cluster(NODES, threads_per_osd=THREADS_PER_OSD)
    table = taxi_like_table(ROWS)
    for i, start in enumerate(range(0, ROWS, ROWS_PER_FILE)):
        write_flat(fs, f"/taxi/part{i:05d}.arw",
                   table.slice(start, min(ROWS_PER_FILE, ROWS - start)),
                   row_group_rows=ROWS_PER_FILE)
    ds = dataset(fs, "/taxi")
    for osd in fs.store.osds:
        osd.straggle_factor = STRAGGLE
        osd.max_straggle_delay_s = STRAGGLE_CAP_S
    # the bulk fleet's scan: every fragment is storage-side work, but only
    # ~10% of rows ship, so the hostile load saturates the OSDs rather
    # than this host's decode path
    bulk_pred = selectivity_predicate(table, 0.1)
    return fs, ds, bulk_pred


def _interactive_once(ds, tenant) -> tuple[float, int]:
    pred = field("trip_id") < POINT_ROWS   # stats-pruned to one fragment
    q = (ds.query(format="pushdown", num_threads=1, tenant=tenant)
         .filter(pred).select(PROJECT))
    t0 = time.perf_counter()
    out = q.to_table()
    return time.perf_counter() - t0, len(out)


def _sample_interactive(ds, make_ctx, n: int) -> tuple[list[float], bool]:
    lats, rows_ok = [], True
    for i in range(n + WARMUP_SAMPLES):
        dt, rows = _interactive_once(ds, make_ctx())
        rows_ok &= rows == POINT_ROWS
        if i >= WARMUP_SAMPLES:
            lats.append(dt)
        time.sleep(GAP_S)
    return lats, rows_ok


def _bulk_fleet(ds, bulk_pred, make_ctx_for, stop: threading.Event,
                scans_done: list[int]):
    """BULK_SCANNERS threads looping full-table scans until ``stop``."""

    def scanner(i: int):
        while not stop.is_set():
            (ds.query(format="pushdown", num_threads=BULK_THREADS,
                      queue_depth=BULK_QUEUE_DEPTH,
                      tenant=make_ctx_for(i))
             .filter(bulk_pred).select(["trip_id"])
             .to_table())
            scans_done[i] += 1

    threads = [threading.Thread(target=scanner, args=(i,), daemon=True)
               for i in range(BULK_SCANNERS)]
    for t in threads:
        t.start()
    return threads


def _p99(lats: list[float]) -> float:
    return float(np.percentile(np.array(lats), 99))


def run() -> dict:
    old_switch = sys.getswitchinterval()
    sys.setswitchinterval(0.001)
    try:
        return _run()
    finally:
        sys.setswitchinterval(old_switch)


def _run() -> dict:
    fs, ds, bulk_pred = _build()

    reg = TenantRegistry(slots_per_osd=SLOTS_PER_OSD,
                         preempt_slack=PREEMPT_SLACK)
    reg.register("app", weight=8.0, lane="interactive")
    for i in range(BULK_SCANNERS):
        reg.register(f"bulk{i}", weight=1.0, lane="bulk")

    # warmup: footer caches, zlib tables, code paths
    ds.query(format="pushdown", num_threads=4).select(
        ["trip_id"]).to_table()

    # -- arm 1: unloaded reference ----------------------------------------
    unloaded, ok_unloaded = _sample_interactive(
        ds, lambda: reg.context("app"), SAMPLES)

    # -- arm 2: QoS (shared registry) -------------------------------------
    stop = threading.Event()
    qos_scans = [0] * BULK_SCANNERS
    fleet = _bulk_fleet(ds, bulk_pred, lambda i: reg.context(f"bulk{i}"),
                        stop, qos_scans)
    time.sleep(0.3)                      # let the fleet saturate the queue
    qos, ok_qos = _sample_interactive(
        ds, lambda: reg.context("app"), SAMPLES)
    stop.set()
    for t in fleet:
        t.join()
    bulk_admitted = {
        t: st["admitted"]
        for t, st in reg.controller(fs.store).stats()["by_tenant"].items()
        if t.startswith("bulk")}

    # -- arm 3: tenant-blind baseline -------------------------------------
    stop = threading.Event()
    blind_scans = [0] * BULK_SCANNERS
    fleet = _bulk_fleet(ds, bulk_pred, lambda i: None, stop, blind_scans)
    time.sleep(0.3)
    blind, ok_blind = _sample_interactive(ds, lambda: None, SAMPLES)
    stop.set()
    for t in fleet:
        t.join()

    p99_unloaded, p99_qos, p99_blind = _p99(unloaded), _p99(qos), _p99(blind)
    return {
        "rows": ROWS, "nodes": NODES, "fragments": len(ds.fragments()),
        "bulk_scanners": BULK_SCANNERS, "straggle_factor": STRAGGLE,
        "slots_per_osd": SLOTS_PER_OSD, "samples": SAMPLES,
        "p99_unloaded_s": p99_unloaded,
        "p99_qos_s": p99_qos,
        "p99_blind_s": p99_blind,
        "p50_unloaded_s": float(np.median(unloaded)),
        "p50_qos_s": float(np.median(qos)),
        "p50_blind_s": float(np.median(blind)),
        "qos_over_unloaded": p99_qos / max(p99_unloaded, 1e-12),
        "blind_over_qos": p99_blind / max(p99_qos, 1e-12),
        "bulk_tasks_admitted": bulk_admitted,
        "bulk_scans_qos": qos_scans,
        "bulk_scans_blind": blind_scans,
        "rows_ok": ok_unloaded and ok_qos and ok_blind,
    }


def check_claims(out: dict) -> list[str]:
    every_bulk_moved = (len(out["bulk_tasks_admitted"]) == BULK_SCANNERS
                        and all(v > 0
                                for v in out["bulk_tasks_admitted"]
                                .values()))
    claims = [
        ("QoS interactive p99 within 1.25x of unloaded",
         out["qos_over_unloaded"] <= 1.25),
        ("tenant-blind interactive p99 at least 3x worse than QoS",
         out["blind_over_qos"] >= 3.0),
        ("every bulk scanner made progress under QoS",
         every_bulk_moved),
        ("interactive queries returned correct rows in every arm",
         out["rows_ok"]),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    out = run()
    out["claims"] = check_claims(out)
    save_result("multi_tenant", out)
    print(f"# multi_tenant: {out['rows']} rows, {out['fragments']} "
          f"fragments, {out['bulk_scanners']} bulk scanners, "
          f"straggle x{out['straggle_factor']:.0f}")
    for arm in ("unloaded", "qos", "blind"):
        print(f"{arm:9} p50 {out[f'p50_{arm}_s'] * 1e3:7.1f} ms   "
              f"p99 {out[f'p99_{arm}_s'] * 1e3:7.1f} ms")
    print(f"qos/unloaded p99: {out['qos_over_unloaded']:.2f}x   "
          f"blind/qos p99: {out['blind_over_qos']:.2f}x")
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
