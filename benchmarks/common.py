"""Shared benchmark scaffolding: the paper's workload + cluster replay.

The workload mirrors §3 of the paper: an NYC-yellow-taxi-shaped table
(17ish columns; we keep the analytically relevant ones), split-style flat
files with one row group per object, scanned at 100% / 10% / 1%
selectivity.  Every scan does the real decode/filter work on this host and
records per-fragment TaskRecords; the ClusterSpec replay (storage.perfmodel)
then maps those measured costs onto the paper's testbed (m510: 8-core
nodes, 10 GbE) to produce Fig. 5/6-comparable numbers.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import make_cluster, write_flat

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


def taxi_like_table(n_rows: int, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    return Table.from_pydict({
        "trip_id": np.arange(n_rows, dtype=np.int64),
        "vendor_id": rng.integers(1, 3, n_rows).astype(np.int32),
        "passenger_count": rng.integers(1, 7, n_rows).astype(np.int32),
        "trip_distance": rng.gamma(1.5, 2.0, n_rows).astype(np.float32),
        "rate_code": rng.integers(1, 7, n_rows).astype(np.int32),
        "pu_location": rng.integers(1, 266, n_rows).astype(np.int32),
        "do_location": rng.integers(1, 266, n_rows).astype(np.int32),
        "fare_amount": rng.gamma(2.0, 7.5, n_rows).astype(np.float64),
        "tip_amount": rng.gamma(1.2, 2.5, n_rows).astype(np.float32),
        "tolls_amount": (rng.random(n_rows) < 0.05).astype(np.float32)
        * rng.gamma(2.0, 3.0, n_rows).astype(np.float32),
        "total_amount": rng.gamma(2.2, 8.0, n_rows).astype(np.float64),
        "payment_type": rng.integers(1, 5, n_rows).astype(np.int32),
        "extra": rng.choice([0.0, 0.5, 1.0], n_rows).astype(np.float32),
        "mta_tax": np.full(n_rows, 0.5, np.float32),
        "congestion": (rng.random(n_rows) < 0.3).astype(np.float32) * 2.5,
        "airport_fee": (rng.random(n_rows) < 0.1).astype(np.float32) * 1.75,
        "duration_s": rng.gamma(2.0, 600.0, n_rows).astype(np.float32),
    })


# selectivity -> predicate on the synthetic distribution (gamma quantiles)
def selectivity_predicate(table: Table, frac: float):
    if frac >= 1.0:
        return None
    fares = table.column("fare_amount").values
    thr = float(np.quantile(fares, 1.0 - frac))
    return field("fare_amount") > thr


def build_cluster(num_nodes: int, table: Table, *, rows_per_file: int,
                  row_group_rows: int | None = None):
    """Flat layout, one row group per file per object (paper §3)."""
    fs = make_cluster(num_nodes)
    n = len(table)
    rgr = row_group_rows or rows_per_file
    for i, start in enumerate(range(0, n, rows_per_file)):
        part = table.slice(start, min(rows_per_file, n - start))
        write_flat(fs, f"/taxi/part{i:05d}.arw", part,
                   row_group_rows=rgr)
    return fs


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


@dataclasses.dataclass
class Timer:
    t0: float = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
