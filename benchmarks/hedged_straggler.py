"""Hedged-scan straggler benchmark: wall time under a 10x-slow OSD.

The point of hedging is tail-latency mitigation: when one storage node is
slow, a scan that races the straggling call against a replica should
finish in roughly the no-straggler time — the straggler's extra service
time is *overlapped*, not added.  The old sequential implementation ran
the backup only after the primary completed, so a "hedged" fragment cost
``primary + backup`` and the whole scan's wall time grew with the
straggle factor.

Measured here with *real* wall clocks (the straggle factor injects real
bounded delay into cls execution, see ``OSD.max_straggle_delay_s``):

  baseline   pushdown scan, healthy cluster, hedging armed
  straggler  same scan after one OSD is made 10x slow

Claims (emitted in the JSON report):
  (a) hedges fired against the straggler;
  (b) straggler wall time <= 1.5x the no-straggler wall time — the
      acceptance bar; the sequential implementation sat at >= 2x because
      every straggler-primary fragment paid primary then backup.

    PYTHONPATH=src:. python benchmarks/hedged_straggler.py
"""

from __future__ import annotations

import statistics
import time

from benchmarks.common import (build_cluster, save_result,
                               selectivity_predicate, taxi_like_table)
from repro.dataset import dataset
from repro.dataset.format import PushdownParquetFormat

ROWS = 120_000
ROWS_PER_FILE = 4_096
PROJECT = ["trip_id", "fare_amount", "tip_amount", "duration_s"]
SELECTIVITY = 0.1
NODES = 8
STRAGGLE = 10.0
NUM_THREADS = 8
REPS = 3


def timed_scan(ds, pred, hedge_threshold_s):
    fmt = PushdownParquetFormat(hedge_threshold_s=hedge_threshold_s)
    sc = ds.scanner(format=fmt, columns=PROJECT, predicate=pred,
                    num_threads=NUM_THREADS)
    t0 = time.perf_counter()
    out = sc.to_table()
    wall = time.perf_counter() - t0
    return wall, len(out), sc.metrics


def best_of(reps, fn):
    walls, rows, metrics = [], None, None
    for _ in range(reps):
        w, r, m = fn()
        walls.append(w)
        rows, metrics = r, m
    return min(walls), walls, rows, metrics


def run() -> dict:
    table = taxi_like_table(ROWS)
    fs = build_cluster(NODES, table, rows_per_file=ROWS_PER_FILE)
    ds = dataset(fs, "/taxi")
    pred = selectivity_predicate(table, SELECTIVITY)

    # warmup (allocator, zlib tables, footer caches)
    ds.scanner(format="pushdown", columns=PROJECT, num_threads=4).to_table()

    # hedge deadline: a generous multiple of the healthy per-fragment
    # latency, so it only fires on a genuine straggler
    probe = ds.scanner(format="pushdown", columns=PROJECT, predicate=pred,
                       num_threads=NUM_THREADS)
    probe.to_table()
    frag_lat = statistics.median(t.cpu_s + t.client_cpu_s
                                 for t in probe.metrics.tasks)
    hedge_threshold = max(5e-3, 4.0 * frag_lat)

    base_wall, base_walls, base_rows, _ = best_of(
        REPS, lambda: timed_scan(ds, pred, hedge_threshold))

    straggler = fs.store.osds[0]
    straggler.straggle_factor = STRAGGLE
    strag_wall, strag_walls, strag_rows, strag_metrics = best_of(
        REPS, lambda: timed_scan(ds, pred, hedge_threshold))
    straggler.straggle_factor = 1.0

    wasted = sum(o.stats.hedge_wasted_s for o in fs.store.osds)
    return {
        "rows": ROWS, "fragments": len(ds.fragments()),
        "selectivity": SELECTIVITY, "straggle_factor": STRAGGLE,
        "hedge_threshold_s": hedge_threshold,
        "baseline_wall_s": base_wall, "baseline_walls_s": base_walls,
        "straggler_wall_s": strag_wall, "straggler_walls_s": strag_walls,
        "ratio": strag_wall / max(base_wall, 1e-12),
        "hedged_tasks": strag_metrics.hedged_tasks,
        "hedge_wasted_cpu_s": wasted,
        "rows_match": base_rows == strag_rows,
    }


def check_claims(out: dict) -> list[str]:
    claims = [
        ("hedges fired against the straggling OSD",
         out["hedged_tasks"] > 0),
        ("straggler scan within 1.5x of no-straggler wall time",
         out["ratio"] <= 1.5),
        ("straggler scan returned identical rows", out["rows_match"]),
        ("duplicated storage CPU is accounted as hedge waste",
         out["hedge_wasted_cpu_s"] > 0),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    out = run()
    out["claims"] = check_claims(out)
    save_result("hedged_straggler", out)
    print(f"# hedged_straggler: {out['rows']} rows, {out['fragments']} "
          f"fragments, straggle x{out['straggle_factor']:.0f}")
    print(f"baseline  wall: {out['baseline_wall_s'] * 1e3:.1f} ms")
    print(f"straggler wall: {out['straggler_wall_s'] * 1e3:.1f} ms "
          f"({out['ratio']:.2f}x, {out['hedged_tasks']} hedged)")
    print(f"hedge waste: {out['hedge_wasted_cpu_s'] * 1e3:.1f} ms "
          f"duplicated storage CPU")
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
