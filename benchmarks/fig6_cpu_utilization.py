"""Fig. 6 reproduction: client vs storage CPU utilization, 100% selectivity.

Paper setup: 8 storage nodes, 16 client threads, a 100%-selectivity query;
they sample total CPU over 60s.  Claim: plain Parquet saturates the
*client's* CPU while the storage nodes idle; RADOS Parquet leaves the
client nearly idle and spreads the CPU across the storage nodes.

We report busy fractions per node over the replayed query window — the
same quantity their bar chart shows, normalized to the query duration.
"""

from __future__ import annotations

from benchmarks.common import build_cluster, save_result, taxi_like_table
from repro.dataset import dataset
from repro.storage.perfmodel import (ClusterSpec, rebalance_nodes,
                                     simulate_scan)

ROWS = 600_000
ROWS_PER_FILE = 4_096
NODES = 8
PROJECT = None               # 100% selectivity returns every column


def run(rows: int = ROWS) -> dict:
    table = taxi_like_table(rows)
    fs = build_cluster(NODES, table, rows_per_file=ROWS_PER_FILE)
    ds = dataset(fs, "/taxi")
    spec = ClusterSpec(nodes=NODES, client_threads=8)
    out: dict = {"rows": rows, "nodes": NODES, "formats": {}}
    ds.scanner(format="pushdown", num_threads=1).to_table()   # warmup
    for fmt in ("parquet", "pushdown"):
        sc = ds.scanner(format=fmt, columns=PROJECT, predicate=None,
                        num_threads=1)
        sc.to_table()
        replay = simulate_scan(rebalance_nodes(sc.metrics.tasks, NODES),
                               spec)
        out["formats"][fmt] = {
            "query_s": round(replay.makespan_s, 4),
            "client_util": round(replay.client_util(spec), 3),
            "storage_util": {f"S{n + 1}": round(u, 3) for n, u in
                             replay.node_util(spec).items()},
            "nic_util": round(replay.nic_util(), 3),
        }
    return out


def check_claims(out: dict) -> list[str]:
    pq = out["formats"]["parquet"]
    pd = out["formats"]["pushdown"]
    claims = [
        ("client scan saturates client CPU (>80%)",
         pq["client_util"] > 0.8),
        ("client scan leaves storage idle (<10%)",
         max(pq["storage_util"].values(), default=0) < 0.1),
        ("pushdown leaves client nearly idle (<25%)",
         pd["client_util"] < 0.25),
        ("pushdown spreads CPU across all storage nodes",
         min(pd["storage_util"].values()) > 0.1),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    out = run()
    out["claims"] = check_claims(out)
    save_result("fig6_cpu_utilization", out)
    print(f"# fig6: {out['rows']} rows, {NODES} storage nodes, 100% sel")
    for fmt, r in out["formats"].items():
        su = " ".join(f"{k}={v:.0%}" for k, v in r["storage_util"].items())
        print(f"{fmt:9s} query={r['query_s']}s client={r['client_util']:.0%} "
              f"nic={r['nic_util']:.0%} | {su}")
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
