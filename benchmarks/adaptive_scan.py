"""Adaptive-placement crossover benchmark (the paper's Fig. 5/6 regime).

The paper shows pushdown wins while storage CPUs have headroom and loses
once they saturate; its placement is static, so *somebody* always picks
wrong.  This benchmark sweeps simulated client count C.  Each policy's
per-fragment costs are measured once on this host (real decode/filter CPU,
real wire bytes), then replayed through the multi-client cluster model
(``storage.perfmodel.simulate_multi_client``): every client owns its CPU
and NIC, the storage node pools are shared — so pushdown latency grows
with C while the client-side scan stays flat, reproducing the crossover.

The adaptive policy is re-*run* at every C: the other clients' load is
presented to the scheduler as per-OSD background queue depth
(``OSD.background_load``, read back through ``ObjectStore.load_of``), and
a fresh scheduler must route fragments from those live signals alone.
Its per-fragment placement decisions are then priced with the same
static-policy measurements, so all three policies replay identical work
(run-to-run decode-CPU noise on a throttled host would otherwise swamp
the wire/queueing effects the model isolates).

Claims checked (emitted in the JSON report):
  (a) the static policies cross over inside the sweep;
  (b) adaptive tracks the better static policy (<= 1.1x) at both the
      lowest and highest client counts;
  (c) a repeated identical scan is served from the columnar result cache
      (hit count > 0) at a fraction of the cost;
  (d) with one straggling OSD, hedged re-issues fire and a replica
      serves the scan.

    PYTHONPATH=src python benchmarks/adaptive_scan.py
"""

from __future__ import annotations

import statistics

from benchmarks.common import (build_cluster, save_result,
                               selectivity_predicate, taxi_like_table)
from repro.dataset import AdaptiveFormat, dataset, modeled_latency
from repro.storage.perfmodel import ClusterSpec, simulate_multi_client

ROWS = 200_000
ROWS_PER_FILE = 4_096        # ~49 fragments, one row group per object
PROJECT = ["trip_id", "fare_amount", "tip_amount", "duration_s"]
SELECTIVITY = 0.1            # the paper's pushdown-friendly midpoint
NODES = 8
NODE_THREADS = 8
CLIENT_THREADS = 16          # per client (paper: 16 scan threads)
CLIENTS = (1, 2, 4, 8, 16, 32)
SPEC = ClusterSpec(nodes=NODES, node_threads=NODE_THREADS,
                   client_threads=CLIENT_THREADS)


def set_background_clients(fs, clients: int):
    """Present C-1 other tenants to the scheduler: each keeps roughly a
    node's worth of scan tasks in flight per OSD (a full pipeline), which
    is exactly the queue the replay's shared node pools will see."""
    for osd in fs.store.osds:
        osd.background_load = (clients - 1) * osd.threads


def mean_scan_latency(tasks, clients: int) -> float:
    """Mean per-client scan latency (makespan) under the cluster model."""
    return statistics.fmean(simulate_multi_client(tasks, SPEC, clients))


def measure_best(make_scan, reps: int = 3):
    """Run a scan ``reps`` times and keep the cheapest run's tasks: wall-
    clock-derived CPU accounting is noisy on a loaded 1-core host, and the
    minimum is the least-contended observation of the same fixed work."""
    best = None
    for _ in range(reps):
        sc, extra = make_scan()
        sc.to_table()
        cost = sum(t.cpu_s + t.client_cpu_s for t in sc.metrics.tasks)
        if best is None or cost < best[0]:
            best = (cost, sc.metrics.tasks, extra)
    return best[1], best[2]


def run() -> dict:
    table = taxi_like_table(ROWS)
    fs = build_cluster(NODES, table, rows_per_file=ROWS_PER_FILE)
    ds = dataset(fs, "/taxi")
    pred = selectivity_predicate(table, SELECTIVITY)
    out: dict = {"rows": ROWS, "fragments": len(ds.fragments()),
                 "selectivity": SELECTIVITY, "clients": list(CLIENTS),
                 "cells": []}

    # warmup: first-touch costs (allocator, zlib tables) out of the timings
    ds.scanner(format="pushdown", columns=PROJECT, num_threads=1).to_table()

    # static policies: measure the per-fragment costs once (they don't
    # depend on C; only the replay's contention does)
    static_tasks = {}
    for policy in ("parquet", "pushdown"):
        static_tasks[policy], _ = measure_best(
            lambda p=policy: (ds.scanner(format=p, columns=PROJECT,
                                         predicate=pred, num_threads=1),
                              None))

    for clients in CLIENTS:
        cell = {"clients": clients}
        for policy in ("parquet", "pushdown"):
            cell[policy + "_s"] = mean_scan_latency(static_tasks[policy],
                                                    clients)
        # adaptive: a fresh scheduler must find the right placement from
        # live load signals (and with a cold cache), not from having seen
        # this client count before.  Its *decisions* come from this live
        # run; each fragment's *cost* is then taken from the common
        # static measurement of the same placement, so all three policies
        # are replayed over identical per-fragment work and the
        # comparison is immune to run-to-run CPU noise on a loaded host.
        set_background_clients(fs, clients)
        fmt = AdaptiveFormat(client_threads=CLIENT_THREADS)
        sc = ds.scanner(format=fmt, columns=PROJECT, predicate=pred,
                        num_threads=1)
        sc.to_table()
        hybrid = [static_tasks["pushdown" if t.where == "osd"
                               else "parquet"][i]
                  for i, t in enumerate(sc.metrics.tasks)]
        cell["adaptive_s"] = mean_scan_latency(hybrid, clients)
        cell["decisions"] = fmt.stats()["decisions"]
        cell["best_static_s"] = min(cell["parquet_s"], cell["pushdown_s"])
        cell["adaptive_vs_best"] = (cell["adaptive_s"]
                                    / max(cell["best_static_s"], 1e-12))
        out["cells"].append(cell)
    set_background_clients(fs, 1)

    # -- result cache: repeat the identical scan at low load ------------------
    fmt = AdaptiveFormat(client_threads=CLIENT_THREADS)
    first = ds.scanner(format=fmt, columns=PROJECT, predicate=pred,
                       num_threads=1)
    first.to_table()
    second = ds.scanner(format=fmt, columns=PROJECT, predicate=pred,
                        num_threads=1)
    second.to_table()
    out["cache"] = {
        "first_scan_s": mean_scan_latency(first.metrics.tasks, 1),
        "repeat_scan_s": mean_scan_latency(second.metrics.tasks, 1),
        "repeat_hits": second.metrics.cache_hits,
        **fmt.stats()["cache"],
    }

    # -- hedging: one pathological straggler at low load ----------------------
    straggler = fs.store.osds[0]
    straggler.straggle_factor = 50.0
    fmt = AdaptiveFormat(client_threads=CLIENT_THREADS)
    sc = ds.scanner(format=fmt, columns=PROJECT, predicate=pred,
                    num_threads=1)
    sc.to_table()
    straggler.straggle_factor = 1.0
    out["hedging"] = {"hedged_tasks": sc.metrics.hedged_tasks,
                      "hedges": fmt.stats()["hedges"],
                      "mean_task_s": statistics.fmean(
                          modeled_latency(t) for t in sc.metrics.tasks)}
    return out


def check_claims(out: dict) -> list[str]:
    cells = out["cells"]
    lo, hi = cells[0], cells[-1]
    claims = [
        ("static policies cross over inside the sweep",
         (lo["pushdown_s"] < lo["parquet_s"])
         and (hi["parquet_s"] < hi["pushdown_s"])),
        ("adaptive <= 1.1x best static at low load",
         lo["adaptive_vs_best"] <= 1.1),
        ("adaptive <= 1.1x best static at saturation",
         hi["adaptive_vs_best"] <= 1.1),
        ("repeat scan served from result cache",
         out["cache"]["repeat_hits"] > 0
         and out["cache"]["repeat_scan_s"] < out["cache"]["first_scan_s"]),
        ("hedging fires against a straggling OSD",
         out["hedging"]["hedges"] > 0),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    out = run()
    out["claims"] = check_claims(out)
    save_result("adaptive_scan", out)
    print(f"# adaptive_scan: {out['rows']} rows, {out['fragments']} "
          f"fragments, selectivity {out['selectivity']}")
    print("clients,parquet_ms,pushdown_ms,adaptive_ms,adaptive_vs_best,"
          "decisions")
    for c in out["cells"]:
        print(f"{c['clients']},{c['parquet_s'] * 1e3:.3f},"
              f"{c['pushdown_s'] * 1e3:.3f},{c['adaptive_s'] * 1e3:.3f},"
              f"{c['adaptive_vs_best']:.3f},{c['decisions']}")
    print(f"cache: first {out['cache']['first_scan_s'] * 1e3:.3f} ms -> "
          f"repeat {out['cache']['repeat_scan_s'] * 1e3:.3f} ms "
          f"({out['cache']['repeat_hits']} hits)")
    print(f"hedging: {out['hedging']['hedges']} hedged re-issues")
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
