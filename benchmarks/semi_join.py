"""Semi-join pushdown benchmark: storage-filtered joins vs client joins.

A selective join (1% of probe keys appear on the build side) over a
striped store.  The same ``Query.join`` runs under three probe formats:

  (1) ``parquet``  — the client-side join baseline: raw probe bytes ship
      to the client, which decodes, filters, and joins locally;
  (2) ``pushdown`` — the build keys become a bloom filter (large build)
      or an exact IN-list (small build) conjoined into the probe
      ``scan_op``: storage nodes drop non-matching rows before IPC;
  (3) ``adaptive`` — the scheduler prices placements with the join's
      selectivity hint.

Probe wire bytes are counted from the probe plan's TaskRecords only —
``ScanMetrics.build`` keeps the build-side scan's accounting separate,
so the comparison is honest about the extra key-fetch traffic.

Claims (emitted in the JSON report):
  (a) every format returns byte-identical join results (semi and inner);
  (b) the semi join returns exactly the key-intersection rows;
  (c) bloom pushdown ships <5% of the client-join probe wire bytes;
  (d) IN-list pushdown (small build) also ships <5%;
  (e) the strategy picker chose bloom for the large build and IN-list
      for the small one.

    PYTHONPATH=src:. python benchmarks/semi_join.py
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import save_result, taxi_like_table
from repro.aformat.table import Table
from repro.core import dataset, make_cluster, write_flat, write_striped

ROWS = int(os.environ.get("SEMI_JOIN_BENCH_ROWS", 200_000))
ROWS_PER_GROUP = 4_096
NODES = 8
NUM_THREADS = 8
MATCH_FRAC = 0.01  # fraction of probe keys present on the build side
SMALL_KEYS = 64    # small build: exercises the exact IN-list path
FORMATS = ("parquet", "pushdown", "adaptive")


def build_cluster(table: Table):
    fs = make_cluster(NODES)
    per_file = ROWS_PER_GROUP * 4
    for i, start in enumerate(range(0, len(table), per_file)):
        part = table.slice(start, min(per_file, len(table) - start))
        write_striped(
            fs, f"/taxi/part{i:05d}.arw", part,
            row_group_rows=ROWS_PER_GROUP,
        )
    return fs


def _probe_wire(metrics) -> int:
    return sum(t.wire_bytes for t in metrics.tasks)


def _join_query(fs, probe_fmt: str, build_path: str, how: str):
    return dataset(fs, "/taxi").query(
        format=probe_fmt, num_threads=NUM_THREADS
    ).join(dataset(fs, build_path).query(), on="trip_id", how=how)


def _run_cell(q) -> tuple[Table, dict]:
    t0 = time.perf_counter()
    out = q.to_table()
    wall = time.perf_counter() - t0
    m = q.metrics
    return out, {
        "wall_s": wall,
        "probe_wire_bytes": _probe_wire(m),
        "build_wire_bytes": _probe_wire(m.build),
        "tasks": len(m.tasks),
        "fragments_total": m.fragments_total,
        "fragments_pruned": m.fragments_pruned,
        "rows": len(out),
    }


def run() -> dict:
    rng = np.random.default_rng(11)
    table = taxi_like_table(ROWS)
    fs = build_cluster(table)

    big_ids = np.sort(
        rng.choice(ROWS, int(ROWS * MATCH_FRAC), replace=False)
    ).astype(np.int64)
    small_ids = np.sort(
        rng.choice(ROWS, SMALL_KEYS, replace=False)
    ).astype(np.int64)
    write_flat(fs, "/keys_big/b0.arw", Table.from_pydict({
        "trip_id": big_ids,
        "weight": rng.random(len(big_ids)),
    }), row_group_rows=ROWS_PER_GROUP)
    write_flat(fs, "/keys_small/b0.arw", Table.from_pydict({
        "trip_id": small_ids,
        "weight": rng.random(len(small_ids)),
    }), row_group_rows=ROWS_PER_GROUP)

    # warmup (allocator, zlib tables, footer caches)
    dataset(fs, "/taxi").query(format="pushdown").select(
        "fare_amount"
    ).to_table()

    out: dict = {
        "rows": ROWS,
        "build_keys": len(big_ids),
        "small_keys": SMALL_KEYS,
        "fragments": len(dataset(fs, "/taxi").fragments()),
        "cells": {},
    }

    # strategy picked per build size (reported, then pinned by a claim)
    for name, path in (("big", "/keys_big"), ("small", "/keys_small")):
        q = _join_query(fs, "pushdown", path, "semi")
        _plan, ctx, _bq, _post = q._prepare_join()
        out[f"strategy_{name}"] = ctx.strategy.pushdown

    semi_results: dict[str, Table] = {}
    for fmt in FORMATS:
        tbl, cell = _run_cell(_join_query(fs, fmt, "/keys_big", "semi"))
        semi_results[fmt] = tbl
        out["cells"][f"semi_{fmt}"] = cell

    inner_results: dict[str, Table] = {}
    for fmt in ("parquet", "pushdown"):
        tbl, cell = _run_cell(_join_query(fs, fmt, "/keys_big", "inner"))
        inner_results[fmt] = tbl
        out["cells"][f"inner_{fmt}"] = cell

    small_tbl, cell = _run_cell(
        _join_query(fs, "pushdown", "/keys_small", "semi")
    )
    out["cells"]["semi_small_pushdown"] = cell
    small_base, cell = _run_cell(
        _join_query(fs, "parquet", "/keys_small", "semi")
    )
    out["cells"]["semi_small_parquet"] = cell

    # exactness: trip_id is unique, so the semi join is exactly the
    # build-key rows, in probe order
    out["semi_rows_ok"] = all(
        np.array_equal(t.column("trip_id").values, big_ids)
        for t in semi_results.values()
    )
    out["small_rows_ok"] = (
        np.array_equal(small_tbl.column("trip_id").values, small_ids)
        and small_tbl.equals(small_base)
    )
    out["formats_identical"] = all(
        semi_results[f].equals(semi_results["parquet"]) for f in FORMATS
    ) and inner_results["pushdown"].equals(inner_results["parquet"])
    return out


def check_claims(out: dict) -> list[str]:
    c = out["cells"]
    base = c["semi_parquet"]["probe_wire_bytes"]
    small_base = c["semi_small_parquet"]["probe_wire_bytes"]
    claims = [
        (
            "all probe formats return byte-identical join results",
            out["formats_identical"],
        ),
        (
            "semi join returns exactly the key-intersection rows",
            out["semi_rows_ok"] and out["small_rows_ok"],
        ),
        (
            "bloom pushdown ships <5% of the client-join probe wire",
            c["semi_pushdown"]["probe_wire_bytes"] < 0.05 * base,
        ),
        (
            "IN-list pushdown ships <5% of the client-join probe wire",
            c["semi_small_pushdown"]["probe_wire_bytes"]
            < 0.05 * small_base,
        ),
        (
            "strategy: bloom for the large build, IN-list for the small",
            out["strategy_big"] == "bloom"
            and out["strategy_small"] == "inlist",
        ),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    t0 = time.perf_counter()
    out = run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = check_claims(out)
    save_result("semi_join", out)
    print(
        f"# semi_join: {out['rows']} probe rows, {out['fragments']} "
        f"fragments, {out['build_keys']} build keys "
        f"(strategy={out['strategy_big']}), {out['small_keys']} small "
        f"keys (strategy={out['strategy_small']})"
    )
    print("cell,wall_ms,probe_wire_B,build_wire_B,rows,pruned/total")
    for name, cell in out["cells"].items():
        print(
            f"{name},{cell['wall_s'] * 1e3:.1f},"
            f"{cell['probe_wire_bytes']},{cell['build_wire_bytes']},"
            f"{cell['rows']},"
            f"{cell['fragments_pruned']}/{cell['fragments_total']}"
        )
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
