"""Aggregate-pushdown benchmark: client vs pushdown vs adaptive GROUP BY.

The paper's pushdown ships *filtered columns*; ``agg_op`` ships *partial
states* — a few dozen bytes per group per fragment.  This benchmark
measures the grouped-aggregate query

    SELECT count(*), sum(fare), mean(fare), min(fare), max(fare)
    FROM taxi [WHERE fare > q(selectivity)] GROUP BY passenger_count

over the striped layout at three selectivities, for all three
placements, recording wall time, wire bytes (task-level; discovery is
common to every policy) and client/storage CPU.  A ``to_table``
materialization of the same scan provides the wire baseline.

Claims (emitted in the JSON report):
  (a) all three placements return the same groups (exact on the integer
      aggregates, 1e-9 relative on float sums/means);
  (b) the adaptive grouped aggregate ships <5% of the ``to_table`` wire
      bytes (the acceptance bar, asserted in tests/test_aggregate.py
      too);
  (c) pushdown ships less wire than the client-side aggregate at every
      selectivity;
  (d) storage-side placement moves the decode CPU off the client.

    PYTHONPATH=src:. python benchmarks/aggregate_pushdown.py
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import (save_result, selectivity_predicate,
                               taxi_like_table)
from repro.core import AdaptiveFormat, dataset, make_cluster, write_striped

ROWS = int(os.environ.get("AGG_BENCH_ROWS", 120_000))
ROWS_PER_GROUP = 4_096          # one row group (= one object) per 4k rows
NODES = 8
NUM_THREADS = 8
SELECTIVITIES = (1.0, 0.1, 0.01)
GROUP_KEY = "passenger_count"
AGGS = ["count", ("sum", "fare_amount"), ("mean", "fare_amount"),
        ("min", "fare_amount"), ("max", "fare_amount")]
POLICIES = ("parquet", "pushdown", "adaptive")


def build_striped_cluster(table):
    fs = make_cluster(NODES)
    n = len(table)
    per_file = ROWS_PER_GROUP * 4          # 4 row groups per striped file
    for i, start in enumerate(range(0, n, per_file)):
        part = table.slice(start, min(per_file, n - start))
        write_striped(fs, f"/taxi/part{i:05d}.arw", part,
                      row_group_rows=ROWS_PER_GROUP)
    return fs


def numpy_reference(table, mask):
    keys = table.column(GROUP_KEY).values[mask]
    fare = table.column("fare_amount").values[mask]
    out = {}
    for k in np.unique(keys):
        m = keys == k
        out[int(k)] = {
            "count": int(m.sum()),
            "sum_fare_amount": float(fare[m].sum()),
            "mean_fare_amount": float(fare[m].mean()),
            "min_fare_amount": float(fare[m].min()),
            "max_fare_amount": float(fare[m].max()),
        }
    return out


def result_to_dict(out):
    keys = out.column(GROUP_KEY).values
    d = {}
    for i, k in enumerate(keys):
        d[int(k)] = {name: out.column(name).values[i].item()
                     if hasattr(out.column(name).values[i], "item")
                     else out.column(name).values[i]
                     for name in ("count", "sum_fare_amount",
                                  "mean_fare_amount", "min_fare_amount",
                                  "max_fare_amount")}
    return d


def matches_reference(got: dict, ref: dict) -> bool:
    if set(got) != set(ref):
        return False
    for k, cells in ref.items():
        g = got[k]
        if g["count"] != cells["count"]:
            return False
        for name in ("sum_fare_amount", "mean_fare_amount"):
            if abs(g[name] - cells[name]) > 1e-9 * max(1.0,
                                                       abs(cells[name])):
                return False
        for name in ("min_fare_amount", "max_fare_amount"):
            if g[name] != cells[name]:
                return False
    return True


def run() -> dict:
    table = taxi_like_table(ROWS)
    fs = build_striped_cluster(table)
    ds = dataset(fs, "/taxi")
    out: dict = {"rows": ROWS, "fragments": len(ds.fragments()),
                 "group_key": GROUP_KEY, "cells": []}

    # warmup (allocator, zlib tables, footer caches)
    ds.scanner(format="pushdown", columns=["fare_amount"],
               num_threads=4).to_table()

    # wire baseline: materialize the full-selectivity scan once
    base = ds.scanner(format=AdaptiveFormat(), num_threads=NUM_THREADS)
    base.to_table()
    table_wire = sum(t.wire_bytes for t in base.metrics.tasks)
    out["to_table_wire_bytes"] = table_wire

    for sel in SELECTIVITIES:
        pred = selectivity_predicate(table, sel)
        mask = np.ones(ROWS, "?") if pred is None else \
            table.column("fare_amount").values > pred.value
        ref = numpy_reference(table, mask)
        cell: dict = {"selectivity": sel}
        for policy in POLICIES:
            fmt = AdaptiveFormat() if policy == "adaptive" else policy
            sc = ds.scanner(format=fmt, predicate=pred,
                            num_threads=NUM_THREADS)
            t0 = time.perf_counter()
            res = sc.aggregate(AGGS, group_by=GROUP_KEY)
            wall = time.perf_counter() - t0
            cell[policy] = {
                "wall_s": wall,
                "wire_bytes": sum(t.wire_bytes
                                  for t in sc.metrics.tasks),
                "client_cpu_s": sc.metrics.client_cpu_s,
                "osd_cpu_s": sc.metrics.osd_cpu_s,
                "matches_reference": matches_reference(
                    result_to_dict(res), ref),
            }
        out["cells"].append(cell)
    return out


def check_claims(out: dict) -> list[str]:
    cells = out["cells"]
    full = cells[0]
    claims = [
        ("all placements match the NumPy reference at every selectivity",
         all(c[p]["matches_reference"] for c in cells for p in POLICIES)),
        ("adaptive grouped aggregate ships <5% of to_table wire bytes",
         full["adaptive"]["wire_bytes"]
         < 0.05 * out["to_table_wire_bytes"]),
        ("pushdown ships less wire than the client-side aggregate",
         all(c["pushdown"]["wire_bytes"] < c["parquet"]["wire_bytes"]
             for c in cells)),
        ("pushdown moves decode CPU off the client (full selectivity)",
         full["pushdown"]["client_cpu_s"] < full["parquet"]["client_cpu_s"]
         and full["pushdown"]["osd_cpu_s"] > 0),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    t0 = time.perf_counter()
    out = run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = check_claims(out)
    save_result("aggregate_pushdown", out)
    print(f"# aggregate_pushdown: {out['rows']} rows, "
          f"{out['fragments']} fragments, GROUP BY {out['group_key']}")
    print(f"to_table wire: {out['to_table_wire_bytes']} B")
    print("selectivity,policy,wall_ms,wire_B,client_cpu_ms,osd_cpu_ms,ok")
    for c in out["cells"]:
        for p in POLICIES:
            r = c[p]
            print(f"{c['selectivity']},{p},{r['wall_s'] * 1e3:.1f},"
                  f"{r['wire_bytes']},{r['client_cpu_s'] * 1e3:.1f},"
                  f"{r['osd_cpu_s'] * 1e3:.1f},"
                  f"{r['matches_reference']}")
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
