"""Render EXPERIMENTS.md tables from results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [--knobs baseline]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["llama-3.2-vision-90b", "mamba2-780m", "phi4-mini-3.8b",
              "gemma3-1b", "qwen2-72b", "starcoder2-7b", "mixtral-8x22b",
              "llama4-maverick-400b-a17b", "whisper-small", "zamba2-1.2b"]


def load(out_dir="results/dryrun", knobs="baseline"):
    recs = {}
    for path in glob.glob(os.path.join(out_dir, f"*__{knobs}.json")):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}µs"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def table(recs, mesh="single"):
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck "
        "| roofline frac | useful/HLO flops | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r.get("skipped"):
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP "
                             f"({r['reason'][:42]}) | — | — | — |")
                continue
            rf = r["roofline"]
            mem = r["memory"]["peak_bytes_est"] / 2 ** 30
            lines.append(
                f"| {arch} | {shape} | {fmt_s(rf['compute_s'])} | "
                f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
                f"{rf['bottleneck'].replace('_s', '')} | "
                f"{rf['roofline_fraction']:.3f} | "
                f"{r['useful_flops_ratio']:.2f} | {mem:.1f} GiB |")
    return "\n".join(lines)


def interesting(recs):
    """Worst roofline fraction / most collective-bound among heavy cells."""
    rows = []
    for (arch, shape, mesh), r in recs.items():
        if mesh != "single" or r.get("skipped"):
            continue
        rf = r["roofline"]
        if r["cost"]["flops_per_device"] < 1e12:
            continue   # decode cells: trivially memory-bound, not hillclimb
        rows.append((rf["roofline_fraction"], rf["bottleneck"],
                     rf["collective_s"] / max(rf["compute_s"], 1e-30),
                     arch, shape))
    rows.sort()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--knobs", default="baseline")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(knobs=args.knobs)
    print(table(recs, args.mesh))
    print("\n-- most interesting (worst fraction first, heavy cells) --")
    for frac, dom, ratio, arch, shape in interesting(recs)[:8]:
        print(f"{frac:.3f}  {dom:12s} coll/comp={ratio:5.2f}  "
              f"{arch} {shape}")


if __name__ == "__main__":
    main()
