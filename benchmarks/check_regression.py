"""Bench-smoke regression gate: fail CI on >25% wall-time regression.

Compares each ``results/bench/BENCH_<name>.json`` produced by
``bench_smoke.py`` against the checked-in ``benchmarks/bench_baseline.json``
and exits non-zero if any benchmark's ``wall_s`` regressed past the
tolerance (default 1.25x, override with BENCH_TOLERANCE).  A benchmark
with no baseline entry is reported but does not fail the gate — add its
measured wall to the baseline in the same PR that introduces it.

The committed baseline is a *budget*, not last run's measurement: CI
runners and dev machines differ, so the checked-in walls carry ~3x
headroom over a quiet reference run.  The gate therefore catches
algorithmic blowups (a scan going quadratic), not single-digit-percent
drift; tighten the budget with ``--update`` once runs on the actual CI
hardware establish its noise floor.

Refreshing the baseline after an intentional change:

    PYTHONPATH=src:. python benchmarks/bench_smoke.py
    python benchmarks/check_regression.py --update

    python benchmarks/check_regression.py
"""

from __future__ import annotations

import glob
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
BASELINE = os.path.join(HERE, "bench_baseline.json")
RESULTS = os.path.join(HERE, "..", "results", "bench")
TOLERANCE = float(os.environ.get("BENCH_TOLERANCE", 1.25))


def load_results() -> dict[str, float]:
    walls = {}
    for path in sorted(glob.glob(os.path.join(RESULTS, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        with open(path) as f:
            walls[name] = float(json.load(f)["wall_s"])
    return walls


def main(argv: list[str]) -> int:
    walls = load_results()
    if not walls:
        print("check_regression: no BENCH_*.json under results/bench/ — "
              "run benchmarks/bench_smoke.py first")
        return 2

    if "--update" in argv:
        with open(BASELINE, "w") as f:
            json.dump({n: {"wall_s": round(w, 3)}
                       for n, w in sorted(walls.items())}, f, indent=1)
        print(f"check_regression: baseline updated -> {BASELINE}")
        return 0

    try:
        with open(BASELINE) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"check_regression: no baseline at {BASELINE}; run with "
              "--update to create one")
        return 2

    failed = []
    print(f"check_regression: tolerance {TOLERANCE:.2f}x")
    print(f"{'benchmark':24} {'baseline':>10} {'now':>10} {'ratio':>7}")
    for name, wall in sorted(walls.items()):
        base = baseline.get(name, {}).get("wall_s")
        if base is None:
            print(f"{name:24} {'(none)':>10} {wall:>9.3f}s      — "
                  "no baseline entry; add one with --update")
            continue
        ratio = wall / max(base, 1e-9)
        flag = "FAIL" if ratio > TOLERANCE else "ok"
        print(f"{name:24} {base:>9.3f}s {wall:>9.3f}s {ratio:>6.2f}x "
              f"{flag}")
        if ratio > TOLERANCE:
            failed.append(name)
    if failed:
        print(f"check_regression: wall-time regression in "
              f"{', '.join(failed)} (>{TOLERANCE:.2f}x baseline)")
        return 1
    print("check_regression: all benchmarks within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
