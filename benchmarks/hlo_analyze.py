"""Hillclimb profiler: recompile one cell, dump roofline + biggest
collectives (with producer context) + HLO op histogram by bytes.

    PYTHONPATH=src python -m benchmarks.hlo_analyze --arch qwen2-72b \
        --shape train_4k --knobs baseline
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))

import argparse
import re
from collections import defaultdict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--knobs", default="baseline")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    from repro.launch import dryrun, roofline

    rec = dryrun.lower_cell(args.arch, args.shape, args.mesh, args.knobs)
    rf = rec["roofline"]
    print(f"== {args.arch} {args.shape} {args.mesh} {args.knobs}")
    print(f"compute {rf['compute_s']:.4f}s memory {rf['memory_s']:.4f}s "
          f"collective {rf['collective_s']:.4f}s "
          f"frac {rf['roofline_fraction']:.3f} "
          f"peak {rec['memory']['peak_bytes_est'] / 2**30:.1f} GiB "
          f"(temps {rec['memory']['temp_bytes'] / 2**30:.1f})")

    # re-lower to get text (lower_cell drops it); cheap relative to compile
    import jax
    # reuse the parsing on compiled text by recompiling through lower_cell's
    # internals would double work; instead re-run with text capture:
    from repro.launch.dryrun import _mesh_for  # noqa
    # --- quick second pass for text ---
    from repro.configs import SHAPES, get_config
    from repro.launch import knobs as knobs_mod
    from repro.sharding import default_rules
    from repro.train import optim, step as step_mod
    cfg = get_config(args.arch)
    kn = knobs_mod.get(args.knobs, args.arch, args.shape)
    cfg = kn.apply(cfg)
    rules = default_rules(**(kn.rules or {}))
    mesh = _mesh_for(args.mesh)
    shape = SHAPES[args.shape]
    opt = optim.OptConfig(moment_dtype=cfg.opt_moment_dtype)
    state_structs, state_shardings = step_mod.state_shardings(
        cfg, opt, mesh, rules)
    batch_structs = step_mod.batch_struct(cfg, shape)
    batch_shardings = step_mod.batch_specs(cfg, mesh, rules, batch_structs)
    fn = step_mod.make_train_step(cfg, mesh, rules, opt,
                                  num_microbatches=kn.num_microbatches)
    lowered = jax.jit(fn, in_shardings=(state_shardings, batch_shardings),
                      out_shardings=(state_shardings, None),
                      donate_argnums=(0,)).lower(state_structs,
                                                 batch_structs)
    text = lowered.compile().as_text()

    # biggest collectives with the line itself
    colls = []
    for line in text.splitlines():
        m = roofline._LINE_RE.search(line)
        if not m:
            continue
        nbytes = roofline._shape_bytes(m.group(1))
        gsize, crosses = roofline._parse_groups(line)
        if gsize > 1:
            colls.append((nbytes, m.group(2), gsize, crosses,
                          line.strip()[:240]))
    colls.sort(key=lambda t: -t[0])
    print(f"\n-- top {args.top} collectives --")
    seen = set()
    shown = 0
    for nbytes, op, g, crosses, line in colls:
        key = (nbytes, op, g)
        if key in seen:
            continue
        seen.add(key)
        count = sum(1 for c in colls if (c[0], c[1], c[2]) == key)
        print(f"{nbytes / 2**20:9.1f} MiB x{count:3d} {op} g={g} "
              f"{'DCN' if crosses else 'ici'}\n    {line[:200]}")
        shown += 1
        if shown >= args.top:
            break

    # op histogram by output bytes (fusion outputs = rough traffic map)
    hist = defaultdict(lambda: [0, 0])
    op_re = re.compile(r"^\s*(?:ROOT )?%?[\w.-]+ = (\S+?)\[([0-9,]*)\]\S* (\w+)")
    for line in text.splitlines():
        m = op_re.match(line)
        if not m:
            continue
        dt, dims, op = m.groups()
        if dt not in roofline.DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        hist[op][0] += n * roofline.DTYPE_BYTES[dt]
        hist[op][1] += 1
    print("\n-- output bytes by op --")
    for op, (b, c) in sorted(hist.items(), key=lambda kv: -kv[1][0])[:14]:
        print(f"{b / 2**30:9.2f} GiB  x{c:5d}  {op}")


if __name__ == "__main__":
    main()
