"""Compaction benchmark: many small appends vs a compacted snapshot.

Continuous ingest leaves a mutable dataset with one tiny row group per
appended file — the fragmentation regime where per-fragment overheads
(cls round-trips, footer decodes, IPC envelopes) dominate scan cost.
``MutableDataset.compact()`` merges those row groups into right-sized
ones *on the storage nodes* (``compact_op``): decode + re-encode + stats
regeneration happen next to the bytes, and only the new file's footer
metadata crosses the client wire.

Measured here:

  (1) append APPENDS small batches, scan HEAD      — the fragmented arm;
  (2) compact via ``compact_op``, scan HEAD again  — the compacted arm;
  (3) the same rewrite client-side                 — what the offload
      saves: every raw byte would round-trip through the client;
  (4) a reader pinned to the pre-compaction snapshot, run after the
      compaction commit                            — snapshot isolation.

Claims (emitted in the JSON report):
  (a) both scans return exactly the appended rows (vs the NumPy source);
  (b) the compacted scan ships fewer wire bytes than the fragmented one;
  (c) the compacted scan completes in lower wall time;
  (d) the ``compact_op`` rewrite moves only metadata-scale bytes over
      the client wire (<5% of the data bytes; the client-side rewrite
      arm moves >100%);
  (e) the pinned pre-compaction reader still returns exact results.

    PYTHONPATH=src:. python benchmarks/compaction.py
"""

from __future__ import annotations

import os
import time

from benchmarks.common import save_result, taxi_like_table
from repro.core import MutableDataset, make_cluster

APPENDS = int(os.environ.get("COMPACT_BENCH_APPENDS", 64))
ROWS_PER_APPEND = int(os.environ.get("COMPACT_BENCH_ROWS", 1500))
TARGET_ROWS = 16_384
NODES = 8
NUM_THREADS = 8


def _scan_cell(md, snapshot_id=None):
    ds = md.as_of(snapshot_id)
    q = ds.query(format="pushdown", num_threads=NUM_THREADS)
    t0 = time.perf_counter()
    out = q.to_table()
    wall = time.perf_counter() - t0
    m = q.metrics
    return out, {
        "wall_s": wall,
        "wire_bytes": sum(t.wire_bytes for t in m.tasks),
        "fragments": len(m.tasks),
        "rows": len(out),
        "osd_cpu_s": round(m.osd_cpu_s, 4),
        "client_cpu_s": round(m.client_cpu_s, 4),
    }


def _exact(out, table) -> bool:
    got = sorted(out.column("trip_id").values.tolist())
    want = sorted(table.column("trip_id").values.tolist())
    return got == want and len(out) == len(table)


def run() -> dict:
    table = taxi_like_table(APPENDS * ROWS_PER_APPEND)
    data_bytes = 0

    fs = make_cluster(NODES)
    md = MutableDataset.create(fs, "/ingest")
    for i in range(APPENDS):
        part = table.slice(i * ROWS_PER_APPEND, ROWS_PER_APPEND)
        md.append(part, row_group_rows=ROWS_PER_APPEND)
    head = md._read_head()[0]
    data_bytes = sum(
        rg.total_bytes for f in head.files for rg in f.footer.row_groups
    )
    pre_sid = md.snapshot()

    # warmup (allocator, zlib tables, footer caches)
    md.query(format="pushdown").select("fare_amount").to_table()

    out: dict = {
        "appends": APPENDS,
        "rows_per_append": ROWS_PER_APPEND,
        "data_bytes": data_bytes,
        "cells": {},
    }
    pre_tbl, cell = _scan_cell(md)
    cell["exact"] = _exact(pre_tbl, table)
    out["cells"]["fragmented_scan"] = cell

    t0 = time.perf_counter()
    report = md.compact(target_rows=TARGET_ROWS)
    out["cells"]["compact_op"] = {
        "wall_s": time.perf_counter() - t0,
        "files_in": report.files_in,
        "files_out": report.files_out,
        "groups": report.groups,
        "fallbacks": report.fallbacks,
        "wire_bytes": report.wire_bytes,
        "rewritten_bytes": report.rewritten_bytes,
    }

    post_tbl, cell = _scan_cell(md)
    cell["exact"] = _exact(post_tbl, table)
    out["cells"]["compacted_scan"] = cell

    # comparison arm: the identical rewrite forced through the client
    fs2 = make_cluster(NODES)
    md2 = MutableDataset.create(fs2, "/ingest")
    for i in range(APPENDS):
        part = table.slice(i * ROWS_PER_APPEND, ROWS_PER_APPEND)
        md2.append(part, row_group_rows=ROWS_PER_APPEND)

    # refuse the offload so every group takes the client-fallback path:
    # the same merge, but raw bytes round-trip through the client
    t0 = time.perf_counter()
    orig_cls = fs2.store._cls
    fs2.store._cls = dict(orig_cls)
    fs2.store._cls["compact_op"] = lambda obj, payload: b'{"ok": false}'
    report2 = md2.compact(target_rows=TARGET_ROWS)
    fs2.store._cls = orig_cls
    out["cells"]["client_rewrite"] = {
        "wall_s": time.perf_counter() - t0,
        "files_in": report2.files_in,
        "files_out": report2.files_out,
        "fallbacks": report2.fallbacks,
        "wire_bytes": report2.wire_bytes,
    }

    # snapshot isolation: the pre-compaction reader, after the commit
    pinned_tbl, cell = _scan_cell(md, pre_sid)
    cell["exact"] = _exact(pinned_tbl, table)
    out["cells"]["pinned_pre_compaction_scan"] = cell
    return out


def check_claims(out: dict) -> list[str]:
    c = out["cells"]
    data = out["data_bytes"]
    claims = [
        (
            "fragmented and compacted scans both return exact rows",
            c["fragmented_scan"]["exact"] and c["compacted_scan"]["exact"],
        ),
        (
            "compacted scan ships fewer wire bytes",
            c["compacted_scan"]["wire_bytes"]
            < c["fragmented_scan"]["wire_bytes"],
        ),
        (
            "compacted scan completes in lower wall time",
            c["compacted_scan"]["wall_s"] < c["fragmented_scan"]["wall_s"],
        ),
        (
            "compact_op rewrite wire <5% of data (client arm >100%)",
            c["compact_op"]["wire_bytes"] < 0.05 * data
            and c["client_rewrite"]["wire_bytes"] > data,
        ),
        (
            "pinned pre-compaction reader stays exact",
            c["pinned_pre_compaction_scan"]["exact"]
            and c["pinned_pre_compaction_scan"]["fragments"]
            == out["appends"],
        ),
    ]
    return [f"{'PASS' if ok else 'FAIL'}  {txt}" for txt, ok in claims]


def main():
    t0 = time.perf_counter()
    out = run()
    out["wall_s"] = time.perf_counter() - t0
    out["claims"] = check_claims(out)
    save_result("compaction", out)
    print(
        f"# compaction: {out['appends']} appends x "
        f"{out['rows_per_append']} rows, {out['data_bytes']} data bytes"
    )
    print("cell,wall_ms,wire_B,fragments")
    for name, cell in out["cells"].items():
        frags = cell.get("fragments", cell.get("files_out", "-"))
        print(
            f"{name},{cell['wall_s'] * 1e3:.1f},{cell['wire_bytes']},"
            f"{frags}"
        )
    for line in out["claims"]:
        print(line)
    return out


if __name__ == "__main__":
    main()
