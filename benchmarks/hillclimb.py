"""Hillclimb runner: lower one cell under explicit knob overrides and print
the roofline delta vs baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen2-72b \
        --shape train_4k --set prenorm_gather=1 --set num_microbatches=4
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))

import argparse
import dataclasses
import json


def parse_val(v: str):
    if v in ("1", "true", "True"):
        return True
    if v in ("0", "false", "False"):
        return False
    try:
        return int(v)
    except ValueError:
        return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", default=[],
                    help="knob=value overrides")
    ap.add_argument("--rules-preset", default=None,
                    choices=[None, "fsdp", "fsdp_tp4"],
                    help="AxisRules override preset")
    ap.add_argument("--tag", default="exp")
    args = ap.parse_args()

    from repro.launch import dryrun, knobs as knobs_mod

    overrides = {}
    for s in args.set:
        k, v = s.split("=", 1)
        overrides[k] = parse_val(v)
    if args.rules_preset == "fsdp":
        # pure DP over all 256/512 chips + parameters sharded over both
        # mesh axes (ZeRO-3): no TP activation collectives at all
        overrides["rules"] = {
            "batch": ("pod", "data", "model"),
            "embed": ("data", "model"),
            "sp_seq": (), "kv_seq": (), "heads": (), "kv_heads": (),
            "mlp": (), "vocab": (), "expert": (), "expert_mlp": (),
            "ssm_heads": (), "conv": (),
        }
    kn = dataclasses.replace(knobs_mod.Knobs(), **overrides)
    # temporarily install as a named table entry
    knobs_mod.TUNED[(args.arch, args.shape)] = kn

    base_path = (f"results/dryrun/{args.arch}__{args.shape}__{args.mesh}"
                 "__baseline.json")
    base = json.load(open(base_path)) if os.path.exists(base_path) else None

    rec = dryrun.lower_cell(args.arch, args.shape, args.mesh, "tuned")
    rf = rec["roofline"]
    os.makedirs("results/hillclimb", exist_ok=True)
    out = (f"results/hillclimb/{args.arch}__{args.shape}__{args.mesh}"
           f"__{args.tag}.json")
    rec["knob_overrides"] = overrides
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)

    def row(name, r):
        rr = r["roofline"]
        print(f"{name:10s} compute {rr['compute_s']:.4f}s  "
              f"memory {rr['memory_s']:.4f}s  "
              f"coll {rr['collective_s']:.4f}s  "
              f"frac {rr['roofline_fraction']:.3f}  "
              f"peak {r['memory']['peak_bytes_est'] / 2**30:6.1f} GiB  "
              f"wire {sum(c['wire'] for c in r['top_collectives']) / 2**30:.1f}+ GiB")

    if base and not base.get("skipped"):
        row("baseline", base)
    row(args.tag, rec)
    if base and not base.get("skipped"):
        b, t = base["roofline"], rf
        dom = b["bottleneck"]
        delta = (b[dom] - rf[dom]) / b[dom] * 100
        print(f"dominant term at baseline = {dom}: "
              f"{b[dom]:.4f}s -> {rf[dom]:.4f}s ({delta:+.1f}% better)")


if __name__ == "__main__":
    main()
