"""Benchmark harness: one module per paper table/figure + the adaptation.

    PYTHONPATH=src python -m benchmarks.run           # everything
    PYTHONPATH=src python -m benchmarks.run fig5      # one benchmark

Each module prints its own CSV/claims and writes results/bench/<name>.json.
"""

from __future__ import annotations

import sys
import time

from benchmarks import (adaptive_scan, compaction, decode_backend,
                        encoding_advisor, fig5_latency_scaling,
                        fig6_cpu_utilization, ingest_train, kernel_bench,
                        layout_compare, multi_tenant, semi_join)

BENCHES = {
    "fig5": fig5_latency_scaling.main,
    "fig6": fig6_cpu_utilization.main,
    "layout": layout_compare.main,
    "kernels": kernel_bench.main,
    "decode_backend": decode_backend.main,
    "ingest": ingest_train.main,
    "adaptive": adaptive_scan.main,
    "compaction": compaction.main,
    "semi_join": semi_join.main,
    "multi_tenant": multi_tenant.main,
    "encoding_advisor": encoding_advisor.main,
}


def main() -> int:
    names = sys.argv[1:] or list(BENCHES)
    failed = []
    for name in names:
        print(f"\n=== {name} " + "=" * (68 - len(name)), flush=True)
        t0 = time.perf_counter()
        try:
            BENCHES[name]()
        except Exception as e:  # noqa: BLE001 - report and continue
            failed.append(name)
            print(f"BENCH FAILED {name}: {type(e).__name__}: {e}")
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")
    if failed:
        print("\nFAILED:", ", ".join(failed))
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
