# Tier-1 verification and benchmark entry points (see ROADMAP.md).

PYTHON ?= python
export PYTHONPATH := src:.:$(PYTHONPATH)

.PHONY: test bench bench-adaptive bench-fig5 bench-fig6 deps

test:
	$(PYTHON) -m pytest -x -q

deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

bench: bench-fig5 bench-fig6 bench-adaptive

bench-fig5:
	$(PYTHON) benchmarks/fig5_latency_scaling.py

bench-fig6:
	$(PYTHON) benchmarks/fig6_cpu_utilization.py

bench-adaptive:
	$(PYTHON) benchmarks/adaptive_scan.py
