# Tier-1 verification and benchmark entry points (see ROADMAP.md).

PYTHON ?= python
export PYTHONPATH := src:.:$(PYTHONPATH)

.PHONY: test test-fast test-cov lint bench bench-adaptive bench-aggregate \
	bench-compact bench-decode bench-encoding bench-fig5 bench-fig6 \
	bench-hedged \
	bench-ingest bench-join bench-limit bench-qos bench-smoke deps

test:
	$(PYTHON) -m pytest -x -q

# fast lane: skip the slow jax/pallas kernel and end-to-end tests so the
# scan-path suite gives signal in minutes (CI runs this per push; the
# full suite stays the tier-1 gate and runs nightly)
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

# coverage lane: line coverage over the query-plan and format layers
# (the join/semi-join surface lives there).  The floor is the measured
# ~92% minus noise headroom — a PR that adds untested branches to those
# layers fails here
test-cov:
	$(PYTHON) -m pytest -q -m "not slow" \
		--cov=repro.dataset --cov=repro.aformat --cov=repro.kernels \
		--cov=repro.ingest --cov=repro.data \
		--cov-report=term-missing:skip-covered --cov-fail-under=85

# ruff config lives in ruff.toml (correctness rules everywhere; the
# format gate ratchets over files added after the lint lane landed)
lint:
	ruff check src tests benchmarks
	ruff format --check src tests benchmarks

deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

# CI per-push benchmark lane: small configs, BENCH_*.json artifacts,
# wall-time regression gate vs benchmarks/bench_baseline.json
bench-smoke:
	$(PYTHON) benchmarks/bench_smoke.py

bench: bench-fig5 bench-fig6 bench-adaptive bench-hedged bench-aggregate \
	bench-limit bench-compact bench-join bench-decode bench-qos \
	bench-ingest bench-encoding

# multi-tenant QoS: interactive p99 under a hostile bulk fleet, with and
# without the shared weighted-fair admission plane
bench-qos:
	$(PYTHON) benchmarks/multi_tenant.py

# distributed training ingest: sharded checkpointable readers — host-CPU
# and wire-byte placement comparison, resume exactness, QoS coexistence
bench-ingest:
	$(PYTHON) benchmarks/ingest_train.py

# client decode plane: NumPy vs Pallas backends (byte-identity, roofline
# rates, placement-crossover shift)
bench-decode:
	$(PYTHON) benchmarks/decode_backend.py

bench-aggregate:
	$(PYTHON) benchmarks/aggregate_pushdown.py

bench-compact:
	$(PYTHON) benchmarks/compaction.py

bench-encoding:
	$(PYTHON) benchmarks/encoding_advisor.py

bench-join:
	$(PYTHON) benchmarks/semi_join.py

bench-limit:
	$(PYTHON) benchmarks/limit_pushdown.py

bench-hedged:
	$(PYTHON) benchmarks/hedged_straggler.py

bench-fig5:
	$(PYTHON) benchmarks/fig5_latency_scaling.py

bench-fig6:
	$(PYTHON) benchmarks/fig6_cpu_utilization.py

bench-adaptive:
	$(PYTHON) benchmarks/adaptive_scan.py
