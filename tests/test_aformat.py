"""aformat: table/IPC/file-format/encoding round-trips + pruning logic.

Property tests (hypothesis) pin the invariants: any table survives an
IPC round-trip, any table survives an ARW1 write/scan round-trip under any
codec/row-group size, and stats-based pruning never lies (a pruned row
group provably contains no matching row).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.aformat import compression, encodings, parquet
from repro.aformat.expressions import ALL, NONE, Expr, field
from repro.aformat.schema import schema
from repro.aformat.statistics import compute_stats
from repro.aformat.table import Column, Table

# ---------------------------------------------------------------------------
# hypothesis strategies
# ---------------------------------------------------------------------------

_col_types = st.sampled_from(["int32", "int64", "float32", "float64",
                              "string"])


@st.composite
def tables(draw, max_rows=200, max_cols=4):
    n = draw(st.integers(1, max_rows))
    ncols = draw(st.integers(1, max_cols))
    data = {}
    for i in range(ncols):
        t = draw(_col_types)
        name = f"c{i}"
        if t == "string":
            data[name] = np.array(
                draw(st.lists(st.text(max_size=8), min_size=n, max_size=n)),
                object)
        elif t.startswith("int"):
            vals = draw(st.lists(
                st.integers(-2**31 + 1, 2**31 - 1), min_size=n, max_size=n))
            data[name] = np.array(vals, t)
        else:
            vals = draw(st.lists(
                st.floats(-1e6, 1e6, allow_nan=False), min_size=n,
                max_size=n))
            data[name] = np.array(vals, t)
    return Table.from_pydict(data)


# ---------------------------------------------------------------------------
# IPC
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(tables())
def test_ipc_roundtrip(tbl):
    back = Table.from_ipc(tbl.to_ipc())
    assert back.equals(tbl)


def test_ipc_validity_roundtrip():
    col = Column(schema(("x", "float32")).field("x"),
                 np.arange(5, dtype=np.float32),
                 np.array([1, 0, 1, 0, 1], bool))
    tbl = Table(schema(("x", "float32"), nullable=("x",)), [col])
    back = Table.from_ipc(tbl.to_ipc())
    assert back.columns[0].validity is not None
    assert (back.columns[0].validity == col.validity).all()


# ---------------------------------------------------------------------------
# ARW1 file format
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(tables(), st.sampled_from([compression.NONE, compression.ZLIB]),
       st.integers(7, 64))
def test_file_roundtrip(tbl, codec, rg_rows):
    data = parquet.write_table(tbl, row_group_rows=rg_rows, codec=codec)
    src = parquet.BytesSource(data)
    back = parquet.scan_file(src)
    assert back.equals(tbl)


def test_footer_stats_present(taxi_table):
    data = parquet.write_table(taxi_table, row_group_rows=4096)
    meta = parquet.read_footer(parquet.BytesSource(data))
    assert meta.num_rows == len(taxi_table)
    for rg in meta.row_groups:
        stats = rg.column_stats(meta.schema)
        assert stats["trip_id"].min is not None
        assert stats["trip_id"].max >= stats["trip_id"].min


def test_projection_and_predicate(taxi_table):
    data = parquet.write_table(taxi_table, row_group_rows=2048)
    src = parquet.BytesSource(data)
    pred = (field("fare_amount") > 20.0) & (field("passenger_count") <= 2)
    out = parquet.scan_file(src, columns=["trip_id"], predicate=pred)
    exp = ((taxi_table.column("fare_amount").values > 20.0)
           & (taxi_table.column("passenger_count").values <= 2))
    assert out.schema.names == ["trip_id"]
    assert np.array_equal(out.column("trip_id").values,
                          taxi_table.column("trip_id").values[exp])


def test_string_predicate(taxi_table):
    data = parquet.write_table(taxi_table, row_group_rows=2048)
    out = parquet.scan_file(parquet.BytesSource(data),
                            columns=["payment_type"],
                            predicate=field("payment_type") == "cash")
    assert set(out.column("payment_type").values) == {"cash"}


# ---------------------------------------------------------------------------
# encodings
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("values,expect", [
    (np.repeat(np.array([3, 1, 7], np.int64), 100), encodings.RLE),
    (np.arange(256, dtype=np.int64), encodings.DELTA),
    (np.array([5, 9, 5, 9, 5] * 40, np.int64), encodings.DICT),
])
def test_choose_encoding(values, expect):
    enc = encodings.choose_encoding("int64", values)
    assert enc == expect


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=300),
       st.sampled_from([encodings.PLAIN, encodings.DICT, encodings.DELTA,
                        encodings.RLE]))
def test_encoding_roundtrip_int64(vals, enc):
    arr = np.array(vals, np.int64)
    try:
        bufs = encodings.encode("int64", enc, arr)
    except ValueError:
        return  # encoding legitimately refused (e.g. delta overflow)
    back = encodings.decode("int64", enc, bufs, len(arr), np.dtype(np.int64))
    assert np.array_equal(back, arr)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(allow_nan=False, width=32), min_size=1,
                max_size=200))
def test_encoding_roundtrip_float(vals):
    arr = np.array(vals, np.float32)
    enc = encodings.choose_encoding("float32", arr)
    bufs = encodings.encode("float32", enc, arr)
    back = encodings.decode("float32", enc, bufs, len(arr),
                            np.dtype(np.float32))
    assert np.array_equal(back, arr)


# ---------------------------------------------------------------------------
# pruning is sound: NONE verdict => truly no matching rows
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=100),
       st.integers(-1200, 1200),
       st.sampled_from(["lt", "le", "gt", "ge", "eq", "ne"]))
def test_prune_soundness(vals, threshold, op):
    arr = np.array(vals, np.int64)
    tbl = Table.from_pydict({"x": arr})
    f = field("x")
    pred: Expr = {"lt": f < threshold, "le": f <= threshold,
                  "gt": f > threshold, "ge": f >= threshold,
                  "eq": f == threshold, "ne": f != threshold}[op]
    stats = {"x": compute_stats(tbl.columns[0])}
    verdict = pred.prune(stats)
    mask = pred.evaluate(tbl)
    if verdict == NONE:
        assert not mask.any()
    elif verdict == ALL:
        assert mask.all()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-100, 100), min_size=1, max_size=50),
       st.integers(-120, 120), st.integers(-120, 120))
def test_prune_soundness_compound(vals, a, b):
    arr = np.array(vals, np.int64)
    tbl = Table.from_pydict({"x": arr})
    pred = (field("x") > a) & (field("x") < b)
    stats = {"x": compute_stats(tbl.columns[0])}
    verdict = pred.prune(stats)
    mask = pred.evaluate(tbl)
    if verdict == NONE:
        assert not mask.any()
    elif verdict == ALL:
        assert mask.all()


def test_expr_json_roundtrip():
    pred = ((field("a") > 1.5) | ~(field("b") == "x")) & \
        field("c").isin([1, 2, 3])
    back = Expr.from_json(pred.to_json())
    assert back.to_json() == pred.to_json()
    assert back.columns() == {"a", "b", "c"}

