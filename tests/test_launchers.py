"""Launcher entry points run end-to-end in smoke mode (subprocess: they
own XLA_FLAGS / argv)."""

import pytest
import subprocess
import sys

# slow lane: jax/pallas compile-heavy; skipped by `make test-fast` / CI per-push
pytestmark = pytest.mark.slow

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}


def _run(args):
    # generous timeout: CI containers can be CPU-throttled ~10x, and the
    # launcher subprocesses re-pay jax compilation from scratch
    return subprocess.run([sys.executable, "-m", *args],
                          capture_output=True, text=True, env=ENV,
                          cwd="/root/repo", timeout=1800)


def test_train_launcher_smoke():
    out = _run(["repro.launch.train", "--arch", "starcoder2-7b", "--smoke",
                "--steps", "12", "--batch", "2", "--seq", "32",
                "--ckpt-every", "6"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done: ingest" in out.stdout
    assert "checkpoints=[6, 12]" in out.stdout


def test_serve_launcher_smoke():
    out = _run(["repro.launch.serve", "--arch", "starcoder2-7b", "--smoke",
                "--requests", "3", "--max-new", "4"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "served 3 requests" in out.stdout


def test_dryrun_single_cell():
    out = _run(["repro.launch.dryrun", "--arch", "whisper-small",
                "--shape", "decode_32k", "--mesh", "single",
                "--out", "/tmp/dryrun_test"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK   whisper-small__decode_32k__single" in out.stdout
