"""Decode engine: NumPy/Pallas backend equivalence + scheduler pricing.

The contract under test is byte-identity: for any row group the
``PallasBackend`` must return exactly what the ``NumPyBackend`` returns —
same dtypes, same bits, same validity — whether a column/predicate routed
through the accelerator kernels or fell back to the host path
(``interpret=True`` off-accelerator makes the kernels exact, and the
f32-domain gates keep everything else on the host).  The grid here spans
encoding x dtype x validity x predicate, including fallback mixes inside
one row group.

Also pinned: the straight-lined DELTA decode at 0/1 rows, the vectorized
string materialization (ASCII / multi-byte UTF-8 / empty), the
scheduler's per-side decode-rate split (observations cross sides only
when the engines match; a Pallas prior moves the placement crossover),
and the ``decode_backend=``
plumbing through Dataset / resolve_format / explain().
"""

import numpy as np
import pytest

from repro.aformat import encodings, parquet
from repro.aformat.decode import (NumPyBackend, PallasBackend,
                                  resolve_backend)
from repro.aformat.expressions import IsIn, field
from repro.aformat.schema import schema
from repro.aformat.table import Column, Table, strings_from_buffers
from repro.core import dataset, make_cluster, write_flat
from repro.dataset.format import ParquetFormat, resolve_format
from repro.dataset.scheduler import ScanScheduler

NUMPY = NumPyBackend()
PALLAS = PallasBackend()


# ---------------------------------------------------------------------------
# differential harness
# ---------------------------------------------------------------------------


def assert_bytes_identical(a: Table, b: Table):
    """Stronger than Table.equals: exact bit patterns, even for floats."""
    assert a.schema.names == b.schema.names
    assert len(a) == len(b)
    for ca, cb in zip(a.columns, b.columns):
        assert ca.values.dtype == cb.values.dtype, ca.field.name
        if ca.field.type == "string":
            assert list(map(str, ca.values)) == list(map(str, cb.values))
        else:
            assert ca.values.tobytes() == cb.values.tobytes(), ca.field.name
        va = ca.validity if ca.validity is not None else \
            np.ones(len(ca), "?")
        vb = cb.validity if cb.validity is not None else \
            np.ones(len(cb), "?")
        assert np.array_equal(va, vb), ca.field.name


def scan_both(tbl, columns=None, predicate=None, row_group_rows=256):
    """Scan every row group with both backends; assert byte-identity and
    return (numpy result, pallas result, last pallas routing report)."""
    data = parquet.write_table(tbl, row_group_rows=row_group_rows)
    src = parquet.BytesSource(data)
    meta = parquet.read_footer(src)
    outs_np, outs_pl, report = [], [], {}
    for rg in meta.row_groups:
        out_np = NUMPY.scan_row_group(src, meta, rg, columns, predicate)
        report = {}
        out_pl = PALLAS.scan_row_group(src, meta, rg, columns, predicate,
                                       report=report)
        assert_bytes_identical(out_np, out_pl)
        outs_np.append(out_np)
        outs_pl.append(out_pl)
    return Table.concat(outs_np), Table.concat(outs_pl), report


def mixed_table(n=600, seed=0, with_nulls=False):
    """One row group's worth of every encoding/dtype regime: DELTA int64,
    DICT int32 (kernel-eligible), RLE int64, PLAIN float32/float64, DICT
    string, BITPACK bool, plus an out-of-f32-domain DICT int64."""
    rng = np.random.default_rng(seed)
    cols = {
        "seq": np.arange(n, dtype=np.int64),                    # DELTA
        "cat": rng.integers(0, 8, n).astype(np.int32),          # DICT
        "run": np.repeat(np.arange(n // 50 + 1, dtype=np.int64) * 1000,
                         50)[:n],                               # RLE
        "f32": rng.normal(0, 10, n).astype(np.float32),         # PLAIN
        "f64": rng.normal(0, 10, n).astype(np.float64),         # PLAIN
        "pay": rng.choice(["card", "cash", "disp"], n),         # DICT str
        "big": (rng.integers(0, 4, n).astype(np.int64)
                * 2 ** 30 + 7),             # DICT, outside f32 domain
        "flag": rng.integers(0, 2, n).astype(bool),             # BITPACK
    }
    tbl = Table.from_pydict(cols)
    if with_nulls:
        out = []
        for c in tbl.columns:
            if c.field.name in ("cat", "f32"):
                validity = rng.random(n) > 0.25
                out.append(Column(c.field, c.values, validity))
            else:
                out.append(c)
        tbl = Table(schema(*[(f.name, f.type) for f in tbl.schema],
                           nullable=("cat", "f32")), out)
    return tbl


PREDICATES = {
    "none": None,
    "flat-and": (field("cat") >= 2) & (field("f32") < 5.0),
    "flat-or": (field("cat") == 1) | (field("cat") == 6),
    "not": ~(field("cat") < 3),
    "three-way-and": ((field("cat") >= 1) & (field("f32") < 8.0)
                      & (field("seq") < 450)),
    "bool-eq": field("flag") == True,                           # noqa: E712
    "string-cmp": field("pay") == "cash",       # host: string column
    "f64-cmp": field("f64") > 0.0,              # host: float64 column
    "big-int": field("big") >= 2 ** 30,         # host: f32 domain
    "inexact-const": field("f32") < 0.1,        # host: 0.1 not f32-exact
    "isin": IsIn("cat", [1, 3, 5]),             # host: unsupported node
    "mixed-logic": ((field("cat") > 1) & (field("f32") < 5.0))
    | (field("seq") < 10),                      # host: AND under OR
    "empty-result": field("cat") > 99,          # selects nothing
}

PROJECTIONS = {
    "all": None,
    "numeric": ["seq", "cat", "f32"],
    "strings-only": ["pay"],
    "pred-col-dropped": ["seq", "f64"],
}


@pytest.mark.parametrize("pred_name", sorted(PREDICATES))
@pytest.mark.parametrize("nulls", [False, True], ids=["dense", "nulls"])
def test_backends_byte_identical(pred_name, nulls):
    tbl = mixed_table(with_nulls=nulls)
    out_np, out_pl, _ = scan_both(tbl, predicate=PREDICATES[pred_name])
    assert len(out_np) == len(out_pl)


@pytest.mark.parametrize("proj_name", sorted(PROJECTIONS))
def test_backends_byte_identical_projected(proj_name):
    tbl = mixed_table()
    scan_both(tbl, columns=PROJECTIONS[proj_name],
              predicate=PREDICATES["flat-and"])


def test_fallback_mix_within_one_row_group():
    """One row group where kernel and host columns coexist: the DICT int32
    rides the gather kernel, DELTA/strings/f64/big-int fall back, and the
    routing report says so explicitly."""
    tbl = mixed_table()
    _, _, report = scan_both(tbl, predicate=PREDICATES["flat-and"],
                             row_group_rows=len(tbl))
    assert report["columns"]["cat"] == "kernel"
    assert report["columns"]["seq"] == "host"      # DELTA byte stream
    assert report["columns"]["pay"] == "host"      # strings
    assert report["columns"]["big"] == "host"      # dict > f32 domain
    assert report["predicate"] == "kernel"
    assert report["compact"]["cat"] == "kernel"
    assert report["compact"]["pay"] == "host"


@pytest.mark.parametrize("pred_name,reason", [
    ("string-cmp", "pay:string"),
    ("f64-cmp", "f64:float64"),
    ("big-int", "big:f32-domain"),
    ("inexact-const", "f32:value"),
    ("isin", "unsupported-node"),
    ("mixed-logic", "unsupported-node"),
])
def test_predicate_fallback_reasons(pred_name, reason):
    tbl = mixed_table()
    _, _, report = scan_both(tbl, predicate=PREDICATES[pred_name],
                             row_group_rows=len(tbl))
    assert report["predicate"] == f"host:{reason}"


def test_validity_or_falls_back_and_stays_fused_under_and():
    """Nulls distribute over AND (validities post-ANDed into the kernel
    mask) but not over OR/NOT — those predicates must take the host path,
    and both routes must agree bit-for-bit."""
    tbl = mixed_table(with_nulls=True)
    _, _, rep_and = scan_both(tbl, predicate=PREDICATES["flat-and"],
                              row_group_rows=len(tbl))
    assert rep_and["predicate"] == "kernel"
    _, _, rep_or = scan_both(tbl, predicate=PREDICATES["flat-or"],
                             row_group_rows=len(tbl))
    assert rep_or["predicate"] == "host:cat:validity"
    _, _, rep_not = scan_both(tbl, predicate=PREDICATES["not"],
                              row_group_rows=len(tbl))
    assert rep_not["predicate"] == "host:cat:validity"


def test_scan_file_backend_equivalence(taxi_table):
    data = parquet.write_table(taxi_table, row_group_rows=2048)
    pred = (field("fare_amount") > 20.0) & (field("passenger_count") <= 2)
    a = parquet.scan_file(parquet.BytesSource(data), predicate=pred)
    b = parquet.scan_file(parquet.BytesSource(data), predicate=pred,
                          backend="pallas")
    assert_bytes_identical(a, b)


def test_resolve_backend():
    assert resolve_backend(None) is resolve_backend("numpy")
    assert resolve_backend("pallas") is resolve_backend("pallas")
    assert resolve_backend(PALLAS) is PALLAS
    with pytest.raises(ValueError, match="unknown decode backend"):
        resolve_backend("cuda")


# ---------------------------------------------------------------------------
# DELTA straight-line decode (regression: dead-expression cumsum)
# ---------------------------------------------------------------------------


def _delta_roundtrip(values):
    bufs = encodings.encode("int64", encodings.DELTA, values)
    return encodings.decode("int64", encodings.DELTA, bufs, len(values),
                            np.int64)


def test_delta_zero_rows():
    out = _delta_roundtrip(np.array([], np.int64))
    assert out.dtype == np.int64 and len(out) == 0


def test_delta_one_row():
    out = _delta_roundtrip(np.array([41], np.int64))
    assert out.tolist() == [41]


def test_delta_many_rows():
    vals = np.array([5, 6, 8, 8, 100, 101], np.int64)
    assert _delta_roundtrip(vals).tolist() == vals.tolist()


@pytest.mark.parametrize("n", [0, 1, 2])
def test_delta_tiny_row_groups_full_scan(n):
    """A 1-row trailing row group exercises the n==1 DELTA branch through
    the whole write/scan path (sorted ints pick DELTA)."""
    tbl = Table.from_pydict({"seq": np.arange(256 + n, dtype=np.int64)})
    data = parquet.write_table(tbl, row_group_rows=256)
    out = parquet.scan_file(parquet.BytesSource(data))
    assert out.equals(tbl)


# ---------------------------------------------------------------------------
# vectorized string materialization
# ---------------------------------------------------------------------------


def _string_bufs(strs):
    offs, payload = encodings._string_buffers(np.asarray(strs, object))
    return np.frombuffer(offs, np.int64), payload


@pytest.mark.parametrize("strs", [
    [],
    [""],
    ["", "", ""],
    ["abc", "", "defg"],
    ["héllo", "wörld", "naïve", ""],          # 2-byte UTF-8
    ["日本語", "a日b", "🙂🙂", "mixed🙂ascii"],  # 3- and 4-byte UTF-8
], ids=["empty", "one-empty", "all-empty", "ascii", "latin", "multibyte"])
def test_strings_from_buffers(strs):
    offsets, payload = _string_bufs(strs)
    out = strings_from_buffers(offsets, payload, len(strs))
    assert out.dtype == object
    assert out.tolist() == strs


def test_strings_from_buffers_prefix():
    """n smaller than the offsets array decodes just the prefix (the
    row-group tail case)."""
    offsets, payload = _string_bufs(["aa", "béé", "cc"])
    assert strings_from_buffers(offsets, payload, 2).tolist() == \
        ["aa", "béé"]


# ---------------------------------------------------------------------------
# scheduler: per-side decode-rate estimators
# ---------------------------------------------------------------------------


def test_observations_cross_sides_only_for_matching_engines(fs):
    # a numpy client runs the same engine as the OSD host path, so one
    # side's scan teaches both estimators (observations transfer — the
    # pre-split shared-EWMA behavior, which keeps a saturated cluster
    # flipping to the client even before any OSD scan has landed)
    sched = ScanScheduler(fs)
    sched._observe("client", 10_000_000, 0.1, 1000)
    assert sched._decode_rate_osd.value(0) == pytest.approx(1e8)
    assert sched._decode_rate_client.value(0) == pytest.approx(1e8)
    # a pallas client is a different engine: observations stay per side
    sched = ScanScheduler(fs, decode_backend="pallas")
    sched._observe("osd", 10_000_000, 0.1, 1000)
    assert sched._decode_rate_osd.value(0) == pytest.approx(1e8)
    assert sched._decode_rate_client._v is None   # untouched prior
    sched._observe("client", 10_000_000, 0.01, 1000)
    assert sched._decode_rate_client.value(0) == pytest.approx(1e9)
    assert sched._decode_rate_osd.value(0) == pytest.approx(1e8)


def test_client_prior_follows_backend(fs):
    assert ScanScheduler(fs)._client_rate_prior == \
        NumPyBackend.decode_rate_prior
    assert ScanScheduler(fs, decode_backend="pallas")._client_rate_prior \
        == PallasBackend.decode_rate_prior


def test_pallas_prior_moves_crossover(taxi_table):
    """Under moderate storage pressure a numpy client still prefers
    pushdown (its own decode is the bottleneck) while a Pallas client —
    priced by its ~10x decode prior — flips to client placement: the
    crossover the split estimators exist to move."""
    fs = make_cluster(8)
    write_flat(fs, "/d/part.arw", taxi_table.slice(0, 5000),
               row_group_rows=1024)
    frag = dataset(fs, "/d").fragments()[0]
    for osd in fs.store.osds:
        osd.background_load = 15 * osd.threads     # pressure ~16x
    est_np = ScanScheduler(fs, client_threads=1).estimate(frag)
    est_pl = ScanScheduler(fs, client_threads=1,
                           decode_backend="pallas").estimate(frag)
    assert est_np.where == "osd"
    assert est_pl.where == "client"
    assert est_pl.est_client_s < est_np.est_client_s
    assert est_pl.est_osd_s == pytest.approx(est_np.est_osd_s)


def test_adaptive_pallas_results_match_numpy(taxi_table):
    fs = make_cluster(8)
    for i in range(2):
        write_flat(fs, f"/d/part{i}.arw", taxi_table.slice(i * 5000, 5000),
                   row_group_rows=1024)
    ds = dataset(fs, "/d")
    pred = (field("fare_amount") > 25.0) & (field("passenger_count") >= 4)
    out_np = ds.query(format="adaptive").filter(pred).to_table()
    out_pl = ds.query(format="adaptive",
                      decode_backend="pallas").filter(pred).to_table()
    o = np.argsort(out_np.column("trip_id").values)
    p = np.argsort(out_pl.column("trip_id").values)
    assert_bytes_identical(out_np.take(o), out_pl.take(p))


# ---------------------------------------------------------------------------
# plumbing: decode_backend= through the Dataset API + explain()
# ---------------------------------------------------------------------------


@pytest.fixture
def flat_ds(taxi_table):
    fs = make_cluster(8)
    write_flat(fs, "/d/part.arw", taxi_table.slice(0, 5000),
               row_group_rows=1024)
    return dataset(fs, "/d"), taxi_table.slice(0, 5000)


def test_scanner_decode_backend(flat_ds):
    ds, tbl = flat_ds
    pred = field("passenger_count") >= 4
    out_np = ds.scanner(format="parquet", predicate=pred).to_table()
    out_pl = ds.scanner(format="parquet", predicate=pred,
                        decode_backend="pallas").to_table()
    assert_bytes_identical(out_np, out_pl)
    exp = int((tbl.column("passenger_count").values >= 4).sum())
    assert len(out_pl) == exp


def test_resolve_format_backend_errors():
    with pytest.raises(ValueError, match="pushdown"):
        resolve_format("pushdown", decode_backend="pallas")
    with pytest.raises(ValueError, match="constructor"):
        resolve_format(ParquetFormat(), decode_backend="pallas")


def test_explain_names_backend_and_routing(flat_ds):
    ds, _ = flat_ds
    pred = field("passenger_count") >= 4
    plan = ds.query(format="parquet", decode_backend="pallas") \
        .filter(pred).select("trip_id").explain()
    assert "backend=pallas[" in plan
    assert "pred=fused" in plan
    assert "passenger_count" in plan
    host_plan = ds.query(format="parquet").filter(pred).explain()
    assert "backend=numpy" in host_plan


def test_explain_adaptive_names_both_sides(flat_ds):
    ds, _ = flat_ds
    plan = ds.query(format="adaptive", decode_backend="pallas") \
        .filter(field("fare_amount") > 30.0).explain()
    assert "backend[client]=pallas[" in plan
    assert "backend[osd]=numpy" in plan


def test_describe_matches_live_routing(flat_ds):
    """The static (footer-only) routing explain() prints must agree with
    what the live scan actually does for DICT columns."""
    ds, _ = flat_ds
    frag = ds.fragments()[0]
    meta = frag.client_meta
    rg = meta.row_groups[frag.client_rg_index]
    desc = PALLAS.describe(meta, rg, ["passenger_count", "payment_type"],
                           None)
    assert "kernel=passenger_count" in desc
    assert "payment_type(dict)" in desc
