"""Training substrate: optimizer semantics, microbatching, remat, memorization."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.mesh import make_local_mesh
from repro.sharding import default_rules
from repro.train import optim, step as step_mod


def _tiny_cfg(**kw):
    cfg = smoke_config("starcoder2-7b")
    base = dict(num_layers=2, d_model=64, d_ff=128, num_heads=2,
                num_kv_heads=2, head_dim=32, vocab_size=128, remat=False)
    base.update(kw)
    return dataclasses.replace(cfg, **base)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_schedule_warmup_and_decay():
    opt = optim.OptConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                          decay_steps=100)
    lrs = [float(optim.schedule(opt, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 1000]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-3)
    assert lrs[5] == pytest.approx(1e-4, rel=1e-3)


def test_adamw_descends_quadratic():
    opt = optim.OptConfig(peak_lr=0.1, warmup_steps=0, decay_steps=1000,
                          weight_decay=0.0)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = optim.init_opt_state(params, opt)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, _ = optim.adamw_update(opt, params, grads, state)
    assert np.abs(np.asarray(params["x"])).max() < 0.05


def test_grad_clipping():
    opt = optim.OptConfig(clip_norm=1.0, warmup_steps=0)
    params = {"x": jnp.zeros(3)}
    state = optim.init_opt_state(params, opt)
    _, _, mets = optim.adamw_update(opt, params,
                                    {"x": jnp.asarray([1e6, 0.0, 0.0])},
                                    state)
    assert float(mets["grad_norm"]) == pytest.approx(1e6)


def test_int8_moments_close_to_fp32():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))}
    grads = {"w": jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))}
    out = {}
    for dt in ("float32", "int8"):
        opt = optim.OptConfig(moment_dtype=dt, warmup_steps=0,
                              weight_decay=0.0)
        p, s = dict(params), optim.init_opt_state(params, opt)
        for _ in range(5):
            p, s, _ = optim.adamw_update(opt, p, grads, s)
        out[dt] = np.asarray(p["w"])
    # int8 block quantization tracks fp32 moments closely (<=1% of the
    # weight scale after 5 steps)
    np.testing.assert_allclose(out["int8"], out["float32"], atol=5e-3)
    # and the stored moments really are int8
    opt = optim.OptConfig(moment_dtype="int8")
    s = optim.init_opt_state(params, opt)
    assert s["m"]["w"]["q"].dtype == jnp.int8


# ---------------------------------------------------------------------------
# train step semantics
# ---------------------------------------------------------------------------


def _batch(cfg, b, s, key):
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size, jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_microbatching_matches_full_batch():
    cfg = _tiny_cfg()
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    opt = optim.OptConfig(warmup_steps=0)
    key = jax.random.key(0)
    state, _ = step_mod.init_state(cfg, opt, key)
    batch = _batch(cfg, 4, 32, key)

    f1 = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt,
                                          num_microbatches=1))
    f2 = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt,
                                          num_microbatches=2))
    s1, m1 = f1(jax.tree.map(jnp.copy, state), batch)
    s2, m2 = f2(jax.tree.map(jnp.copy, state), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    # atol: step-1 Adam normalizes by sqrt(v)+eps with v ~ g^2, so bf16
    # reduction-order differences between the two accumulation schemes are
    # amplified to ~lr scale on near-zero-grad coordinates
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_remat_matches_no_remat():
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    opt = optim.OptConfig(warmup_steps=0)
    key = jax.random.key(1)
    losses = {}
    for remat in (False, True):
        cfg = _tiny_cfg(remat=remat)
        state, _ = step_mod.init_state(cfg, opt, key)
        fn = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt))
        _, mets = fn(state, _batch(cfg, 2, 32, key))
        losses[remat] = float(mets["loss"])
    assert losses[False] == pytest.approx(losses[True], rel=1e-5)


def test_memorizes_fixed_batch():
    """A few hundred steps on one batch must drive loss well below init."""
    cfg = _tiny_cfg()
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    opt = optim.OptConfig(peak_lr=3e-3, warmup_steps=10, decay_steps=300,
                          weight_decay=0.0)
    key = jax.random.key(2)
    state, _ = step_mod.init_state(cfg, opt, key)
    batch = _batch(cfg, 2, 32, key)
    fn = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt),
                 donate_argnums=(0,))
    first = None
    for i in range(150):
        state, mets = fn(state, batch)
        if first is None:
            first = float(mets["loss"])
    last = float(mets["loss"])
    assert last < first * 0.5, (first, last)
