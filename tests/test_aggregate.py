"""Aggregate pushdown: Scanner.aggregate correctness across every
(layout x format x predicate) cell, vs a NumPy reference on the
materialized table.

The contract mirrors the paper's placement-equivalence claim, extended to
aggregation: switching where the partial aggregate runs (client decode,
storage-side ``agg_op``, or the adaptive scheduler's per-fragment choice)
never changes the result — while the pushdown placements ship partial
states of a few dozen bytes instead of materialized columns.  Exactness:
count/min/max and integer sum/mean are bit-exact under any merge order
(integer partials are exact Python ints); float sums/means are compared to
1e-9 relative (float addition is order-sensitive in the last ulp, as in
any parallel aggregation engine).
"""

import json

import numpy as np
import pytest

from repro.aformat.aggregate import (AggSpec, AggState, CardinalityError,
                                     parse_aggs, partial_aggregate)
from repro.aformat.expressions import field
from repro.aformat.schema import Field, Schema
from repro.aformat.table import Column, Table
from repro.core import (AdaptiveFormat, dataset, make_cluster, write_flat,
                        write_split, write_striped)

WRITERS = {"flat": write_flat, "striped": write_striped,
           "split": write_split}
FORMATS = ["parquet", "pushdown", "adaptive"]

AGGS = ["count", ("count", "fare_amount"), ("sum", "trip_id"),
        ("sum", "fare_amount"), ("mean", "fare_amount"),
        ("min", "trip_distance"), ("max", "fare_amount"),
        ("min", "payment_type")]

PREDICATES = {
    "none": None,
    "selective": field("fare_amount") > 25.0,
    "pruning": field("trip_id") < 3000,          # monotone: prunes groups
    "compound": (field("fare_amount") > 20.0)
    & (field("passenger_count") >= 4),
}


@pytest.fixture(params=["flat", "striped", "split"])
def populated(request, taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        part = taxi_table.slice(i * 5000, 5000)
        WRITERS[request.param](fs, f"/d/part{i}.arw", part,
                               row_group_rows=1024)
    return fs, taxi_table, request.param


def _mask(tbl, name):
    pred = PREDICATES[name]
    if pred is None:
        return np.ones(len(tbl), "?")
    cols = {f.name: tbl.column(f.name).values for f in tbl.schema}
    if name == "selective":
        return cols["fare_amount"] > 25.0
    if name == "pruning":
        return cols["trip_id"] < 3000
    return (cols["fare_amount"] > 20.0) & (cols["passenger_count"] >= 4)


def _reference_ungrouped(tbl, mask):
    """NumPy ground truth for AGGS over the masked table."""
    fare = tbl.column("fare_amount").values[mask]
    tid = tbl.column("trip_id").values[mask]
    dist = tbl.column("trip_distance").values[mask]
    pay = tbl.column("payment_type").values[mask]
    return {
        "count": int(mask.sum()),
        "count_fare_amount": int(mask.sum()),
        "sum_trip_id": int(tid.sum()) if len(tid) else 0,
        "sum_fare_amount": float(fare.sum()),
        "mean_fare_amount": float(fare.mean()) if len(fare) else None,
        "min_trip_distance": float(dist.min()) if len(dist) else None,
        "max_fare_amount": float(fare.max()) if len(fare) else None,
        "min_payment_type": min(pay) if len(pay) else None,
    }


def _check_row(out, row, ref):
    for name, want in ref.items():
        col = out.column(name)
        got = col.values[row]
        valid = col.validity is None or bool(col.validity[row])
        if want is None:
            assert not valid, name
        elif isinstance(want, float):
            assert valid, name
            assert got == pytest.approx(want, rel=1e-9), name
        else:
            assert valid, name
            assert got == want, (name, got, want)


# ---------------------------------------------------------------------------
# the full (layout x format x predicate) grid, ungrouped and grouped
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("pred_name", list(PREDICATES))
def test_ungrouped_matches_numpy(populated, fmt, pred_name):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    sc = ds.scanner(format=fmt, predicate=PREDICATES[pred_name],
                    num_threads=4)
    out = sc.aggregate(AGGS)
    assert len(out) == 1
    _check_row(out, 0, _reference_ungrouped(tbl, _mask(tbl, pred_name)))


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("pred_name", ["none", "selective", "pruning"])
def test_grouped_matches_numpy(populated, fmt, pred_name):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    sc = ds.scanner(format=fmt, predicate=PREDICATES[pred_name],
                    num_threads=4)
    out = sc.aggregate(AGGS, group_by="passenger_count")
    mask = _mask(tbl, pred_name)
    keys = tbl.column("passenger_count").values[mask]
    uk = np.unique(keys)
    assert np.array_equal(out.column("passenger_count").values, uk)
    for gi, k in enumerate(uk):
        sub = mask & (tbl.column("passenger_count").values == k)
        _check_row(out, gi, _reference_ungrouped(tbl, sub))


@pytest.mark.parametrize("fmt", FORMATS)
def test_string_group_key(populated, fmt):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    out = ds.scanner(format=fmt, num_threads=4).aggregate(
        ["count", ("sum", "trip_id")], group_by="payment_type")
    pay = np.asarray([str(v) for v in
                      tbl.column("payment_type").values])
    uk = sorted(set(pay))
    assert list(out.column("payment_type").values) == uk
    for gi, k in enumerate(uk):
        sub = pay == k
        assert out.column("count").values[gi] == int(sub.sum())
        assert out.column("sum_trip_id").values[gi] == \
            int(tbl.column("trip_id").values[sub].sum())


# ---------------------------------------------------------------------------
# empty results: all fragments pruned / predicate matches nothing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
def test_all_pruned_dataset(populated, fmt):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    sc = ds.scanner(format=fmt, predicate=field("fare_amount") < -5.0)
    out = sc.aggregate(AGGS)
    assert sc.metrics.fragments_pruned == sc.metrics.fragments_total
    assert not sc.metrics.tasks                  # zero I/O of any kind
    _check_row(out, 0, _reference_ungrouped(tbl, np.zeros(len(tbl), "?")))
    # grouped: no rows -> no groups
    g = ds.scanner(format=fmt,
                   predicate=field("fare_amount") < -5.0).aggregate(
        AGGS, group_by="passenger_count")
    assert len(g) == 0
    assert g.schema.names[0] == "passenger_count"


@pytest.mark.parametrize("fmt", FORMATS)
def test_empty_after_scan_not_prunable(populated, fmt):
    """Predicate the stats cannot prune but no row satisfies: fragments
    are scanned, the merged state is still the empty aggregate."""
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    pred = (field("trip_id") > 4998) & (field("trip_id") < 4999)
    sc = ds.scanner(format=fmt, predicate=pred)
    out = sc.aggregate(AGGS)
    assert sc.metrics.tasks                      # something was scanned
    _check_row(out, 0, _reference_ungrouped(tbl, np.zeros(len(tbl), "?")))


# ---------------------------------------------------------------------------
# metadata-only answers and wire accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
def test_metadata_only_aggregates_never_touch_storage(populated, fmt):
    """Ungrouped, predicate-free count/min/max over non-float columns are
    provable from footer stats: zero bytes on the wire, zero cls calls."""
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    calls_before = sum(o.stats.cls_calls for o in fs.store.osds)
    sc = ds.scanner(format=fmt)
    out = sc.aggregate(["count", ("min", "trip_id"), ("max", "trip_id"),
                        ("max", "payment_type"),
                        ("count", "fare_amount")])
    assert sum(o.stats.cls_calls for o in fs.store.osds) == calls_before
    assert all(t.wire_bytes == 0 for t in sc.metrics.tasks)
    assert out.column("count").values[0] == len(tbl)
    assert out.column("min_trip_id").values[0] == 0
    assert out.column("max_trip_id").values[0] == len(tbl) - 1
    assert out.column("max_payment_type").values[0] == "disp"
    assert out.column("count_fare_amount").values[0] == len(tbl)


def test_float_minmax_not_answered_from_stats(populated):
    """Footer stats skip non-finite floats, so float min/max must decode
    real data (stats would lie for a column holding inf)."""
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    sc = ds.scanner(format="pushdown")
    out = sc.aggregate([("min", "fare_amount")])
    assert sc.metrics.tasks                      # storage was consulted
    assert out.column("min_fare_amount").values[0] == pytest.approx(
        float(tbl.column("fare_amount").values.min()), rel=1e-12)


def test_grouped_pushdown_ships_partial_states_not_columns(populated):
    """The wire-bytes claim: a grouped aggregate ships orders of
    magnitude less than materializing the same fragments."""
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    scan = ds.scanner(format="pushdown",
                      columns=["passenger_count", "fare_amount",
                               "trip_id"])
    scan.to_table()
    scan_wire = sum(t.wire_bytes for t in scan.metrics.tasks)
    agg = ds.scanner(format="pushdown")
    agg.aggregate(["count", ("sum", "fare_amount"), ("sum", "trip_id")],
                  group_by="passenger_count")
    agg_wire = sum(t.wire_bytes for t in agg.metrics.tasks)
    assert agg_wire * 20 < scan_wire             # >20x reduction


# ---------------------------------------------------------------------------
# tier-1 acceptance: striped layout, adaptive format, grouped — exact
# match at <5% of the to_table wire bytes
# ---------------------------------------------------------------------------


def test_striped_adaptive_grouped_exact_and_under_5pct_wire(taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        write_striped(fs, f"/d/part{i}.arw", taxi_table.slice(i * 5000,
                                                              5000),
                      row_group_rows=1024)
    ds = dataset(fs, "/d")
    tbl = taxi_table
    pred = field("fare_amount") > 20.0
    mask = tbl.column("fare_amount").values > 20.0

    fmt = AdaptiveFormat()
    full = ds.scanner(format=fmt, predicate=pred, num_threads=4)
    full.to_table()
    table_wire = sum(t.wire_bytes for t in full.metrics.tasks)

    sc = ds.scanner(format=AdaptiveFormat(), predicate=pred,
                    num_threads=4)
    out = sc.aggregate(["count", ("sum", "trip_id"),
                        ("mean", "passenger_count"),
                        ("min", "trip_id"), ("max", "trip_id")],
                       group_by="passenger_count")
    agg_wire = sum(t.wire_bytes for t in sc.metrics.tasks)

    # exact NumPy reference (integer partials are exact in any order)
    keys = tbl.column("passenger_count").values[mask]
    tid = tbl.column("trip_id").values[mask]
    uk = np.unique(keys)
    assert np.array_equal(out.column("passenger_count").values, uk)
    for gi, k in enumerate(uk):
        m = keys == k
        assert out.column("count").values[gi] == int(m.sum())
        assert out.column("sum_trip_id").values[gi] == int(tid[m].sum())
        assert out.column("min_trip_id").values[gi] == int(tid[m].min())
        assert out.column("max_trip_id").values[gi] == int(tid[m].max())
        # mean over an int key column: exact int sum / exact count
        assert out.column("mean_passenger_count").values[gi] == \
            int(m.sum()) * float(k) / int(m.sum())
    assert agg_wire > 0
    assert agg_wire < 0.05 * table_wire, (agg_wire, table_wire)


# ---------------------------------------------------------------------------
# spill-to-scan: storage-side group-cardinality bound
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["pushdown", "adaptive"])
def test_cardinality_spill_falls_back_to_scan(populated, fmt):
    """group-by over a unique key exceeds the storage bound: every
    fragment spills to a scan, the client folds unbounded — the result
    must still be complete and exact."""
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    fmt_obj = AdaptiveFormat() if fmt == "adaptive" else fmt
    sc = ds.scanner(format=fmt_obj, num_threads=4)
    out = sc.aggregate([("count", None)], group_by="trip_id",
                       max_groups=64)
    assert len(out) == len(tbl)                  # every trip_id distinct
    assert np.array_equal(out.column("trip_id").values,
                          np.arange(len(tbl), dtype=np.int64))
    assert np.all(out.column("count").values == 1)
    # the spill path ran client-side folds, not agg_op replies
    assert any(t.where == "client" or t.wire_bytes > 1000
               for t in sc.metrics.tasks)
    if fmt == "adaptive":
        stats = fmt_obj.stats()
        assert stats["spills"] > 0
        # spills book their final placement once, never twice
        assert sum(stats["decisions"].values()) == len(sc.metrics.tasks)


# ---------------------------------------------------------------------------
# adaptive placement behaviours specific to aggregates
# ---------------------------------------------------------------------------


def test_adaptive_aggregate_result_cached(taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        write_flat(fs, f"/d/part{i}.arw", taxi_table.slice(i * 5000, 5000),
                   row_group_rows=1024)
    ds = dataset(fs, "/d")
    fmt = AdaptiveFormat()
    pred = field("fare_amount") > 25.0
    aggs = [("sum", "fare_amount"), ("count", None)]
    first = ds.scanner(format=fmt, predicate=pred, num_threads=4)
    a = first.aggregate(aggs, group_by="passenger_count")
    second = ds.scanner(format=fmt, predicate=pred, num_threads=4)
    b = second.aggregate(aggs, group_by="passenger_count")
    assert second.metrics.cache_hits == len(second.metrics.tasks)
    assert all(t.wire_bytes == 0 for t in second.metrics.tasks)
    assert a.equals(b)
    # an overwrite bumps the version: fragments of that object miss
    name = fs.object_names("/d/part0.arw")[0]
    fs.store.put(name, fs.store.get(name))
    third = ds.scanner(format=fmt, predicate=pred, num_threads=4)
    c = third.aggregate(aggs, group_by="passenger_count")
    assert 0 < third.metrics.cache_hits < len(third.metrics.tasks)
    assert a.equals(c)


def test_adaptive_aggregate_saturation_goes_client_side(taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        write_flat(fs, f"/d/part{i}.arw", taxi_table.slice(i * 5000, 5000),
                   row_group_rows=1024)
    for osd in fs.store.osds:
        osd.background_load = 64 * osd.threads
    ds = dataset(fs, "/d")
    fmt = AdaptiveFormat()
    sc = ds.scanner(format=fmt, predicate=field("fare_amount") > 25.0,
                    num_threads=4)
    out = sc.aggregate([("count", None)], group_by="passenger_count")
    dec = fmt.stats()["decisions"]
    assert dec["osd"] == 0 and dec["client"] > 0
    exp = (taxi_table.column("fare_amount").values > 25.0)
    assert int(out.column("count").values.sum()) == int(exp.sum())


def test_aggregate_survives_osd_failure(populated):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    fs.store.fail_osd(fs.store.osds[0].osd_id)
    fs.store.fail_osd(fs.store.osds[3].osd_id)
    out = ds.scanner(format="adaptive", num_threads=4).aggregate(
        [("sum", "trip_id"), ("count", None)])
    n = len(tbl)
    assert out.column("count").values[0] == n
    assert out.column("sum_trip_id").values[0] == n * (n - 1) // 2


# ---------------------------------------------------------------------------
# nullable columns
# ---------------------------------------------------------------------------


def test_aggregate_nullable_column():
    fs = make_cluster(4)
    n = 4000
    rng = np.random.default_rng(7)
    valid = rng.random(n) > 0.3
    vals = rng.integers(0, 100, n).astype(np.int64)
    keys = rng.integers(0, 5, n).astype(np.int32)
    sch = Schema((Field("k", "int32"), Field("v", "int64", nullable=True)))
    tbl = Table(sch, [Column(sch.fields[0], keys),
                      Column(sch.fields[1], vals, valid.copy())])
    write_flat(fs, "/n/part0.arw", tbl, row_group_rows=512)
    ds = dataset(fs, "/n")
    for fmt in FORMATS:
        out = ds.scanner(format=fmt).aggregate(
            ["count", ("count", "v"), ("sum", "v"), ("mean", "v")],
            group_by="k")
        for gi, k in enumerate(np.unique(keys)):
            m = keys == k
            mv = m & valid
            assert out.column("count").values[gi] == int(m.sum())
            assert out.column("count_v").values[gi] == int(mv.sum())
            assert out.column("sum_v").values[gi] == int(vals[mv].sum())
            assert out.column("mean_v").values[gi] == pytest.approx(
                vals[mv].mean())


# ---------------------------------------------------------------------------
# spec validation and the partial-state unit contract
# ---------------------------------------------------------------------------


def test_aggspec_validation():
    with pytest.raises(ValueError):
        AggSpec("median", "x")
    with pytest.raises(ValueError):
        AggSpec("sum")                           # sum needs a column
    assert parse_aggs(["count", "sum(x)", ("min", "y"),
                       AggSpec("max", "z")]) == [
        AggSpec("count"), AggSpec("sum", "x"), AggSpec("min", "y"),
        AggSpec("max", "z")]
    assert parse_aggs(["count(*)"]) == [AggSpec("count")]


def test_sum_over_string_raises(populated):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    with pytest.raises(TypeError):
        ds.scanner(format="parquet").aggregate([("sum", "payment_type")])


def test_unknown_column_raises(populated):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    with pytest.raises(KeyError):
        ds.scanner(format="pushdown").aggregate([("sum", "nope")])
    with pytest.raises(KeyError):
        ds.scanner(format="pushdown").aggregate(["count"], group_by="nope")


def test_partial_state_roundtrip_and_merge_associativity():
    rng = np.random.default_rng(0)
    tbl = Table.from_pydict({
        "k": rng.integers(0, 4, 300).astype(np.int32),
        "x": rng.integers(-50, 50, 300).astype(np.int64),
    })
    specs = parse_aggs(["count", ("sum", "x"), ("mean", "x"),
                        ("min", "x"), ("max", "x")])
    thirds = [tbl.slice(0, 100), tbl.slice(100, 100), tbl.slice(200, 100)]
    parts = [partial_aggregate(t, specs, "k") for t in thirds]
    ab_c = AggState.empty(specs, "k")
    ab_c.merge(parts[0]).merge(parts[1]).merge(parts[2])
    c_ba = AggState.empty(specs, "k")
    c_ba.merge(parts[2]).merge(parts[1]).merge(parts[0])
    assert ab_c.groups == c_ba.groups            # int partials: exact
    rt = AggState.deserialize(ab_c.serialize())
    assert rt.groups == ab_c.groups and rt.rows == ab_c.rows
    # the wire form is compact JSON, not columns
    assert len(ab_c.serialize()) < 1024
    assert json.loads(ab_c.serialize())["group_by"] == "k"


def test_cardinality_error_is_storage_side_only():
    tbl = Table.from_pydict({"k": np.arange(100, dtype=np.int64)})
    with pytest.raises(CardinalityError):
        partial_aggregate(tbl, parse_aggs(["count"]), "k", max_groups=10)
    # unbounded (client) path: fine
    st = partial_aggregate(tbl, parse_aggs(["count"]), "k")
    assert st.num_groups == 100


# ---------------------------------------------------------------------------
# the serving planner's sizing query (serve-layer integration)
# ---------------------------------------------------------------------------


def test_prompt_lengths_ships_counts_not_tokens():
    from repro.serve.engine import prompt_lengths
    fs = make_cluster(4)
    rng = np.random.default_rng(3)
    uids = np.repeat(np.arange(16, dtype=np.int64), 8)
    pos = np.tile(np.arange(8, dtype=np.int32), 16)
    toks = rng.integers(0, 1000, uids.size).astype(np.int32)
    tbl = Table.from_pydict({"uid": uids, "pos": pos, "token": toks})
    write_flat(fs, "/prompts/p0.arw", tbl, row_group_rows=32)
    ds = dataset(fs, "/prompts")
    lengths, metrics = prompt_lengths(ds)
    assert lengths == {u: 8 for u in range(16)}
    # counts on the wire, not token columns
    token_bytes = tbl.select(["token"]).nbytes()
    assert sum(t.wire_bytes for t in metrics.tasks) < token_bytes
