"""Sharding rules: divisibility fallback, spec resolution, axis reuse."""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.sharding import bytes_per_device, default_rules, resolve_spec


class FakeMesh:
    """Shape-only stand-in (resolve_spec touches .shape only)."""

    def __init__(self, **shape):
        self.shape = shape


def test_basic_resolution():
    mesh = FakeMesh(data=16, model=16)
    rules = default_rules()
    spec = resolve_spec(mesh, rules, ("batch", None, "mlp"), (256, 4, 4096))
    assert spec == P("data", None, "model")


def test_divisibility_fallback_drops_axis():
    mesh = FakeMesh(data=16, model=16)
    rules = default_rules()
    # 4 heads cannot shard 16 ways -> falls back to replicated
    spec = resolve_spec(mesh, rules, ("batch", "heads", None), (256, 4, 64))
    assert spec == P("data", None, None)
    # but 6912 mlp (gemma3) still shards: 6912 % 16 == 0
    spec = resolve_spec(mesh, rules, ("batch", "mlp"), (256, 6912))
    assert spec == P("data", "model")


def test_multi_axis_batch():
    mesh = FakeMesh(pod=2, data=16, model=16)
    rules = default_rules()
    spec = resolve_spec(mesh, rules, ("batch", None), (256, 128))
    assert spec == P(("pod", "data"), None)


def test_axis_used_once():
    mesh = FakeMesh(data=16, model=16)
    rules = default_rules()
    # both dims want "model": first (kv_seq) wins, second falls back
    spec = resolve_spec(mesh, rules, ("kv_seq", "mlp"), (4096, 4096))
    assert spec == P("model", None)


def test_odd_dims_replicate():
    mesh = FakeMesh(data=16, model=16)
    rules = default_rules()
    spec = resolve_spec(mesh, rules, ("batch", "vocab"), (7, 50257))
    assert spec == P(None, None)   # 7 % 16 != 0, 50257 % 16 != 0


def test_partial_product_fallback():
    """batch=32 on (pod=2, data=16): pod fits (32%2==0) and pod*data=32
    divides 32 -> both used."""
    mesh = FakeMesh(pod=2, data=16)
    rules = default_rules()
    assert resolve_spec(mesh, rules, ("batch",), (32,)) == P(("pod", "data"))
    # batch=8: pod fits, pod*data=32 does not divide 8 -> pod only
    assert resolve_spec(mesh, rules, ("batch",), (8,)) == P(("pod",))


def test_bytes_per_device_accounts_sharding():
    mesh = FakeMesh(data=4, model=4)
    rules = default_rules()
    params = {"w": jax.ShapeDtypeStruct((1024, 1024), np.dtype("float32"))}
    specs = {"w": ("embed", "mlp")}
    n = bytes_per_device(mesh, rules, params, specs)
    assert n == 1024 * 1024 * 4 // 16


def test_unknown_logical_axis_raises():
    mesh = FakeMesh(data=2)
    rules = default_rules()
    with pytest.raises(KeyError):
        resolve_spec(mesh, rules, ("no_such_axis",), (16,))
