"""Adaptive scan scheduler: crossover placement, hedging, result cache,
load accounting, and the serving-side ingest path.

The scheduler's contract extends the paper's: not only does switching
placement never change *what* a scan returns, but the placement itself is
now chosen per fragment from live OSD load — so these tests pin (a)
result equivalence with the static formats, (b) the decision direction
under idle vs saturated storage, (c) hedged re-issue against an injected
straggler, and (d) cache hits that survive only until an object is
overwritten.
"""

import numpy as np
import pytest

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import (AdaptiveFormat, dataset, make_cluster, write_flat,
                        write_split, write_striped)
from repro.dataset.scheduler import ResultCache, ScanScheduler

WRITERS = {"flat": write_flat, "striped": write_striped,
           "split": write_split}


@pytest.fixture(params=["flat", "striped", "split"])
def populated(request, taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        part = taxi_table.slice(i * 5000, 5000)
        WRITERS[request.param](fs, f"/d/part{i}.arw", part,
                               row_group_rows=1024)
    return fs, taxi_table


@pytest.fixture
def flat_ds(taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        write_flat(fs, f"/d/part{i}.arw", taxi_table.slice(i * 5000, 5000),
                   row_group_rows=1024)
    return fs, dataset(fs, "/d"), taxi_table


# ---------------------------------------------------------------------------
# equivalence: adaptive placement never changes results
# ---------------------------------------------------------------------------


def test_adaptive_matches_static(populated):
    fs, tbl = populated
    ds = dataset(fs, "/d")
    pred = (field("fare_amount") > 25.0) & (field("passenger_count") >= 4)
    mask = ((tbl.column("fare_amount").values > 25.0)
            & (tbl.column("passenger_count").values >= 4))
    out = ds.scanner(format="adaptive", columns=["trip_id", "fare_amount"],
                     predicate=pred, num_threads=4).to_table()
    exp = tbl.filter(mask).select(["trip_id", "fare_amount"])
    o = np.argsort(out.column("trip_id").values)
    e = np.argsort(exp.column("trip_id").values)
    assert np.array_equal(out.column("trip_id").values[o],
                          exp.column("trip_id").values[e])
    assert np.allclose(out.column("fare_amount").values[o],
                       exp.column("fare_amount").values[e])


# ---------------------------------------------------------------------------
# placement crossover
# ---------------------------------------------------------------------------


def test_low_load_prefers_storage(flat_ds):
    """Idle cluster + selective predicate: after the first (exploratory)
    client-side fragment teaches the scheduler the output ratio, the rest
    should be pushed down."""
    fs, ds, _ = flat_ds
    fmt = AdaptiveFormat()
    sc = ds.scanner(format=fmt, columns=["trip_id"],
                    predicate=field("fare_amount") > 30.0, num_threads=4)
    sc.to_table()
    dec = fmt.stats()["decisions"]
    assert dec["osd"] > dec["client"]


def test_saturation_prefers_client(flat_ds):
    """Storage-side queue depth far past thread capacity: the scan must
    run client-side (the paper's crossover, now taken automatically)."""
    fs, ds, tbl = flat_ds
    for osd in fs.store.osds:
        osd.background_load = 32 * osd.threads      # ~32 tenants deep
    fmt = AdaptiveFormat()
    sc = ds.scanner(format=fmt, columns=["trip_id"],
                    predicate=field("fare_amount") > 30.0, num_threads=4)
    out = sc.to_table()
    dec = fmt.stats()["decisions"]
    assert dec["osd"] == 0
    assert dec["client"] == len(sc.metrics.tasks)
    assert len(out) == int((tbl.column("fare_amount").values > 30.0).sum())


def test_decisions_follow_pressure_estimate(flat_ds):
    """The estimate itself flips direction with injected pressure."""
    fs, ds, _ = flat_ds
    sched = ScanScheduler(fs)
    frag = ds.fragments()[0]
    # teach the scheduler a selective output ratio so storage looks good
    sched._out_ratio.update(0.05)
    sched._decode_rate_osd.update(150e6)
    sched._decode_rate_client.update(150e6)
    idle = sched.estimate(frag)
    assert idle.where == "osd"
    for osd in fs.store.osds:
        osd.background_load = 64 * osd.threads
    saturated = sched.estimate(frag)
    assert saturated.where == "client"
    assert saturated.pressure > idle.pressure


# ---------------------------------------------------------------------------
# hedging
# ---------------------------------------------------------------------------


def test_hedging_fires_on_straggler(flat_ds):
    fs, ds, tbl = flat_ds
    fmt = AdaptiveFormat()
    # warm the latency history on an idle cluster
    ds.scanner(format=fmt, columns=["trip_id"],
               predicate=field("fare_amount") > 30.0,
               num_threads=2).to_table()
    # now one node straggles pathologically; min-pressure over replicas
    # keeps the placement storage-side, so hedging must save the tail
    name = fs.object_names("/d/part0.arw")[0]
    straggler = fs.store.primary_of(name)
    straggler.straggle_factor = 1e6
    sc = ds.scanner(format=fmt, columns=["trip_id"],
                    predicate=field("fare_amount") > 60.0, num_threads=2)
    out = sc.to_table()
    assert sc.metrics.hedged_tasks > 0
    assert fmt.stats()["hedges"] > 0
    # every hedged task was ultimately served: the result is complete
    assert len(out) == int((tbl.column("fare_amount").values > 60.0).sum())


# ---------------------------------------------------------------------------
# EWMA units: both paths observe (stored fragment bytes -> IPC bytes)
# ---------------------------------------------------------------------------


def test_ewma_units_consistent_across_paths(flat_ds):
    """The storage node runs the same decode code as the client, so the
    shared selectivity estimator must see the *same units* from both
    paths: stored fragment bytes in, Arrow-IPC bytes out.  A client-only
    and a storage-only scheduler therefore learn (near-)identical output
    ratios over the same fragments."""
    fs, ds, _ = flat_ds
    cols = ["trip_id", "fare_amount"]
    pred = field("fare_amount") > 30.0
    frags = ds.fragments()[:6]

    osd_sched = ScanScheduler(fs)
    for f in frags:
        osd_sched._scan_osd(f, cols, pred, osd_sched.estimate(f))
    client_sched = ScanScheduler(fs)
    for f in frags:
        client_sched._scan_client(f, cols, pred)

    r_osd = osd_sched._out_ratio.value(0.0)
    r_client = client_sched._out_ratio.value(0.0)
    assert r_osd > 0 and r_client > 0
    assert r_osd == pytest.approx(r_client, rel=0.05)


def test_ewma_converges_under_mixed_traffic(flat_ds):
    """Alternating storage- and client-routed scans feed one estimator;
    it must converge on the true stored->IPC ratio, not oscillate between
    incompatible unit systems."""
    fs, ds, _ = flat_ds
    cols = ["trip_id", "fare_amount"]
    pred = field("fare_amount") > 30.0
    sched = ScanScheduler(fs)
    for i, f in enumerate(ds.fragments()[:10]):
        if i % 2 == 0:
            sched._scan_osd(f, cols, pred, sched.estimate(f))
        else:
            sched._scan_client(f, cols, pred)
    # ground truth from one fragment: decoded IPC bytes per stored byte
    f0 = ds.fragments()[0]
    tbl, _, ipc = sched._scan_client(f0, cols, pred)
    truth = len(ipc) / sched._frag_bytes(f0)
    assert sched._out_ratio.value(0.0) == pytest.approx(truth, rel=0.35)


# ---------------------------------------------------------------------------
# aggregate pushdown through the scheduler
# ---------------------------------------------------------------------------


def test_adaptive_count_rows_matches_scan(flat_ds):
    fs, ds, tbl = flat_ds
    fmt = AdaptiveFormat()
    pred = field("fare_amount") > 25.0
    exp = int((tbl.column("fare_amount").values > 25.0).sum())
    sc = ds.scanner(format=fmt, predicate=pred)
    assert sc.count_rows() == exp
    assert sc.count_rows() == len(
        ds.scanner(format=fmt, predicate=pred).to_table())
    # the adaptive count ships integers, not materialized fragments
    assert sc.metrics.tasks
    assert all(t.wire_bytes < 64 for t in sc.metrics.tasks)


def test_adaptive_count_rows_is_cached(flat_ds):
    fs, ds, tbl = flat_ds
    fmt = AdaptiveFormat()
    pred = field("fare_amount") > 25.0
    first = ds.scanner(format=fmt, predicate=pred)
    second = ds.scanner(format=fmt, predicate=pred)
    assert first.count_rows() == second.count_rows()
    assert sum(1 for t in second.metrics.tasks if t.cached) == \
        len(second.metrics.tasks)
    assert all(t.wire_bytes == 0 for t in second.metrics.tasks)


def test_adaptive_count_rows_metadata_only_without_predicate(flat_ds):
    fs, ds, tbl = flat_ds
    sc = ds.scanner(format=AdaptiveFormat())
    assert sc.count_rows() == len(tbl)
    assert not sc.metrics.tasks                 # zero storage calls


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------


def test_cache_hits_on_repeat_scan(flat_ds):
    fs, ds, _ = flat_ds
    fmt = AdaptiveFormat()
    pred = field("fare_amount") > 30.0
    a = ds.scanner(format=fmt, columns=["trip_id"], predicate=pred,
                   num_threads=2).to_table()
    sc = ds.scanner(format=fmt, columns=["trip_id"], predicate=pred,
                    num_threads=2)
    b = sc.to_table()
    assert sc.metrics.cache_hits == len(sc.metrics.tasks)
    assert np.array_equal(np.sort(a.column("trip_id").values),
                          np.sort(b.column("trip_id").values))
    # a different projection/predicate must not hit the same entries
    sc2 = ds.scanner(format=fmt, columns=["trip_id", "fare_amount"],
                     predicate=pred, num_threads=2)
    sc2.to_table()
    assert sc2.metrics.cache_hits == 0


def test_cache_invalidated_by_overwrite(flat_ds):
    fs, ds, _ = flat_ds
    fmt = AdaptiveFormat()
    pred = field("fare_amount") > 30.0
    ds.scanner(format=fmt, columns=["trip_id"], predicate=pred,
               num_threads=2).to_table()
    # touch one object in place: same bytes, new version
    name = fs.object_names("/d/part0.arw")[0]
    before = fs.store.version_of(name)
    fs.store.put(name, fs.store.get(name))
    assert fs.store.version_of(name) > before
    sc = ds.scanner(format=fmt, columns=["trip_id"], predicate=pred,
                    num_threads=2)
    out = sc.to_table()
    # fragments of the touched object miss; everything else still hits
    assert 0 < sc.metrics.cache_hits < len(sc.metrics.tasks)
    assert len(out) == len(ds.scanner(format="parquet", columns=["trip_id"],
                                      predicate=pred).to_table())


def test_result_cache_lru_eviction():
    cache = ResultCache(capacity_bytes=100)
    cache.put(("a",), b"x" * 60)
    cache.put(("b",), b"y" * 60)          # evicts a
    assert cache.get(("a",)) is None
    assert cache.get(("b",)) == b"y" * 60
    assert cache.evictions == 1
    cache.put(("huge",), b"z" * 1000)     # larger than capacity: not stored
    assert cache.get(("huge",)) is None
    assert cache.nbytes <= 100


# ---------------------------------------------------------------------------
# load accounting
# ---------------------------------------------------------------------------


def test_load_of_pressure_signals():
    from repro.core import make_cluster
    fs = make_cluster(4)
    store = fs.store
    osd = store.osds[0]
    idle = store.load_of(0)
    assert idle.pressure == 1.0
    osd.background_load = osd.threads            # one pipeline deep
    assert store.load_of(0).pressure == pytest.approx(2.0)
    osd.straggle_factor = 3.0
    assert store.load_of(0).pressure == pytest.approx(6.0)
    osd.down = True
    assert store.load_of(0).pressure == float("inf")


def test_inflight_returns_to_zero(flat_ds):
    fs, ds, _ = flat_ds
    ds.scanner(format="pushdown", columns=["trip_id"],
               num_threads=4).to_table()
    assert all(o.inflight == 0 for o in fs.store.osds)


# ---------------------------------------------------------------------------
# serving-side ingest through the scheduler
# ---------------------------------------------------------------------------


def test_ingest_prompts_through_adaptive_scan():
    from repro.serve.engine import ingest_prompts
    fs = make_cluster(4)
    rng = np.random.default_rng(3)
    uids = np.repeat(np.arange(16, dtype=np.int64), 8)
    pos = np.tile(np.arange(8, dtype=np.int32), 16)
    toks = rng.integers(0, 1000, uids.size).astype(np.int32)
    tbl = Table.from_pydict({"uid": uids, "pos": pos, "token": toks})
    write_flat(fs, "/prompts/p0.arw", tbl, row_group_rows=32)
    ds = dataset(fs, "/prompts")
    fmt = AdaptiveFormat()
    reqs, metrics = ingest_prompts(ds, format=fmt)
    assert len(reqs) == 16
    for r in reqs:
        sel = uids == r.uid
        expect = toks[sel][np.argsort(pos[sel], kind="stable")]
        assert np.array_equal(r.prompt, expect)
    # repeat ingest is served from the scheduler's result cache
    reqs2, metrics2 = ingest_prompts(ds, format=fmt)
    assert metrics2.cache_hits == len(metrics2.tasks)
    assert len(reqs2) == 16
