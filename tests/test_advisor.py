"""Measured encoding advisor: candidate costing, compaction re-encode,
and the advisor-soundness differential (whatever the advisor picks, the
decoded rows are byte-identical across parquet/pushdown/adaptive)."""

import numpy as np
import pytest

from repro.aformat import compression, encodings, parquet
from repro.aformat.advisor import Advice, advise_column, \
    candidate_encodings
from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import dataset, make_cluster
from repro.dataset.snapshot import MutableDataset


def _advisor_table(n=12_000, seed=11):
    """Taxi-like shape where the one-shot heuristic leaves bytes on the
    table: a quantized fare PLAIN-encodes 8 bytes wide (sample uniq >
    len/16) where DICTP packs it, a bounded odometer PLAIN-encodes where
    BITPACK fits 17 bits, jittered timestamps defeat the heuristic's
    monotone-DELTA check, and the int/string dictionary columns all pay
    int32 code buffers where packed indices do."""
    rng = np.random.default_rng(seed)
    return Table.from_pydict({
        "fare_amount": np.round(
            np.clip(rng.gamma(2.0, 7.5, n), 0, 74.75) * 4) / 4,
        "odometer": rng.integers(0, 1 << 17, n).astype(np.int64),
        "vendor": rng.integers(1, 3, n).astype(np.int64),
        "passenger_count": rng.integers(1, 7, n).astype(np.int32),
        "payment_type": rng.choice(["card", "cash", "disp"], n),
        "pickup_ts": (10 ** 9 + np.arange(n) * 7
                      + rng.integers(-10, 11, n)).astype(np.int64),
    })


def _keyed_table(n=6000, seed=4):
    """The same shape plus a unique key for row-identity checks."""
    t = _advisor_table(n, seed)
    d = {"trip_id": np.arange(n, dtype=np.int64)}
    d.update({f.name: t.column(f.name).values for f in t.schema})
    return Table.from_pydict(d)


# ---------------------------------------------------------------------------
# advise_column
# ---------------------------------------------------------------------------


def test_advice_is_cheapest_candidate():
    vals = np.arange(2000, dtype=np.int64)
    adv = advise_column("int64", vals, compression.ZLIB)
    assert isinstance(adv, Advice)
    assert adv.encoding == adv.candidates[0].encoding
    min_stored = min(c.stored_bytes for c in adv.candidates)
    # stored bytes are primary: the pick never inflates past the slack
    assert adv.stored_bytes <= 1.10 * min_stored
    # a unique sequential key: DELTA compresses to ~nothing, and no
    # kernel-rate prior may excuse a multi-x DICT instead
    assert adv.encoding == encodings.DELTA


def test_advice_buffers_decode_back():
    rng = np.random.default_rng(1)
    cases = [
        ("int64", rng.integers(0, 50, 5000).astype(np.int64)),
        ("int32", rng.integers(-3, 3, 5000).astype(np.int32)),
        ("float64", np.repeat(rng.normal(size=10), 500)),
        ("string", np.asarray(
            rng.choice(["a", "bb", "ccc"], 5000), object)),
        ("bool", (rng.integers(0, 2, 5000) == 0)),
    ]
    for ftype, vals in cases:
        adv = advise_column(ftype, vals, compression.ZLIB)
        dtype = {"int64": np.int64, "int32": np.int32,
                 "float64": np.float64, "bool": np.bool_,
                 "string": object}[ftype]
        back = encodings.decode(ftype, adv.encoding, list(adv.buffers),
                                len(vals), np.dtype(dtype)
                                if dtype is not object else None)
        if ftype == "string":
            assert [str(v) for v in back] == [str(v) for v in vals]
        else:
            assert np.array_equal(np.asarray(back, dtype), vals)


def test_advisor_beats_or_matches_heuristic_bytes():
    """Per column, the advisor's compressed data bytes are never worse
    than the one-shot heuristic's pick (it measures every candidate,
    including the heuristic's)."""
    t = _advisor_table(8000)
    for col in t.columns:
        ftype, vals = col.field.type, col.values
        adv = advise_column(ftype, vals, compression.ZLIB)
        heur = encodings.choose_encoding(ftype, vals)
        try:
            bufs = encodings.encode(ftype, heur, vals)
        except ValueError:
            bufs = encodings.encode(ftype, encodings.PLAIN, vals)
        heur_bytes = sum(len(compression.compress(compression.ZLIB, b))
                         for b in bufs)
        # DICT/DICTP kernel-route priors may trade a few stored bytes
        # for decode rate; bound the regression at 5%
        assert adv.stored_bytes <= heur_bytes * 1.05, \
            (col.field.name, adv.encoding, heur)


def test_candidate_sets_per_type():
    assert encodings.BITPACK in candidate_encodings("int64")
    assert encodings.DICTP in candidate_encodings("string")
    assert encodings.BITPACK in candidate_encodings("bool")
    assert encodings.PLAIN in candidate_encodings("float32")
    for t in ("int64", "int32", "float64", "float32", "string", "bool"):
        assert encodings.PLAIN in candidate_encodings(t)


# ---------------------------------------------------------------------------
# compaction: the advisor's main customer
# ---------------------------------------------------------------------------


def _build_fragmented(fs, prefix, table, piece=800):
    md = MutableDataset.create(fs, prefix)
    for start in range(0, len(table), piece):
        md.append(table.slice(start, min(piece, len(table) - start)),
                  row_group_rows=piece)
    return md


def test_compact_advisor_cuts_bytes_and_reports():
    fs = make_cluster(4)
    t = _advisor_table(12_000)
    md = _build_fragmented(fs, "/adv", t)
    report = md.compact(target_rows=12_000)
    assert report.groups > 0 and report.files_out >= 1
    assert report.bytes_before > 0 and report.bytes_after > 0
    # the acceptance bar: >=25% stored-byte cut on the taxi-like table
    assert report.bytes_after <= 0.75 * report.bytes_before, \
        (report.bytes_before, report.bytes_after)
    assert set(report.encodings) == set(t.schema.names)
    # near-constant and tiny-range ints must leave PLAIN behind
    assert report.encodings["vendor"] != encodings.PLAIN
    assert report.encodings["passenger_count"] != encodings.PLAIN


def test_compact_advisor_vs_heuristic_arm():
    t = _advisor_table(10_000)
    fs_a, fs_b = make_cluster(4), make_cluster(4)
    ra = _build_fragmented(fs_a, "/a", t).compact(
        target_rows=10_000, advisor=True)
    rb = _build_fragmented(fs_b, "/b", t).compact(
        target_rows=10_000, advisor=False)
    assert ra.bytes_after <= rb.bytes_after


def test_compacted_data_scans_identically():
    """Advisor re-encode must be lossless: post-compaction scans match
    pre-compaction scans row-for-row across all three formats."""
    fs = make_cluster(4)
    t = _keyed_table(6000, seed=4)
    md = _build_fragmented(fs, "/c", t)
    before = md.query(num_threads=2).to_table()
    md.compact(target_rows=6000)
    pred = field("passenger_count") >= 5
    mask = t.column("passenger_count").values >= 5
    expect_ids = np.sort(t.column("trip_id").values[mask])
    for fmt in ("parquet", "pushdown", "adaptive"):
        out = md.query(format=fmt, num_threads=2).filter(pred).to_table()
        got = np.sort(out.column("trip_id").values)
        assert np.array_equal(got, expect_ids), fmt
        # string column survives dictionary re-encode byte-identically
        o = np.argsort(out.column("trip_id").values)
        rows = np.argsort(t.column("trip_id").values[mask])
        assert [str(v) for v in out.column("payment_type").values[o]] \
            == [str(v) for v in
                t.column("payment_type").values[mask][rows]]
    after = md.query(num_threads=2).to_table()
    assert len(after) == len(before)


def test_compact_regenerates_indexes_on_osd():
    """The rewritten object's own footer carries fresh index blocks
    (storage-side pruning keeps working), while the reply footer the
    manifest stores is index-free (lean wire/manifest)."""
    fs = make_cluster(4)
    t = _advisor_table(5000, seed=8)
    md = _build_fragmented(fs, "/r", t)
    md.compact(target_rows=5000)
    head, _ = md._read_head()
    # the compacted successor is the biggest file in the new snapshot
    df = max(head.files, key=lambda f: f.rows)
    assert df.rows > 1000
    # manifest footer: stripped
    assert all(c.index is None
               for rg in df.footer.row_groups for c in rg.chunks)
    # the object itself: indexed
    raw = fs.read_file(df.path)
    meta = parquet.read_footer(parquet.BytesSource(raw))
    assert all(c.index is not None
               for rg in meta.row_groups for c in rg.chunks)


@pytest.mark.parametrize("fmt", ["parquet", "pushdown", "adaptive"])
def test_advisor_soundness_differential(fmt):
    """Whatever encodings the advisor picks, scan results are
    byte-identical to the never-compacted dataset, per format."""
    t = _keyed_table(6000, seed=13)
    fs_c, fs_u = make_cluster(4), make_cluster(4)
    md = _build_fragmented(fs_c, "/d", t)
    md.compact(target_rows=1500)   # several advisor-encoded row groups
    mu = _build_fragmented(fs_u, "/d", t)
    pred = (field("fare_amount") > 20.0) & (field("vendor") == 1)
    outs = []
    for m in (md, mu):
        out = m.query(format=fmt, num_threads=2).filter(pred).to_table()
        o = np.argsort(out.column("trip_id").values)
        outs.append((out, o))
    (a, oa), (b, ob) = outs
    assert len(a) == len(b) > 0
    for name in t.schema.names:
        va = a.column(name).values[oa]
        vb = b.column(name).values[ob]
        if a.column(name).field.type == "string":
            assert [str(x) for x in va] == [str(x) for x in vb], name
        else:
            assert np.array_equal(va, vb), name
