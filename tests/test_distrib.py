"""Fault tolerance: checkpoint round-trips, health, elastic downsize."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

# slow lane: jax/pallas compile-heavy; skipped by `make test-fast` / CI per-push
pytestmark = pytest.mark.slow

from repro.core import make_cluster
from repro.distrib import (CheckpointManager, HealthMonitor,
                           InsufficientDevicesError, plan_downsize)


@pytest.fixture
def state():
    return {
        "params": {"w": jnp.arange(24.0).reshape(4, 6),
                   "b": jnp.full((6,), 0.5),
                   "scan": jnp.ones((3, 2, 2))},
        "opt": {"m": jnp.zeros((4, 6)), "count": jnp.array(3, jnp.int32)},
        "step": jnp.array(17, jnp.int32),
    }


def _structs(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def test_checkpoint_roundtrip(state):
    fs = make_cluster(4)
    cm = CheckpointManager(fs, "/ck")
    cm.save(state, 17)
    out = cm.restore(_structs(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last_k(state):
    fs = make_cluster(4)
    cm = CheckpointManager(fs, "/ck", keep=2)
    for s in (1, 2, 3, 4):
        cm.save(state, s)
    assert cm.steps() == [3, 4]
    # old files actually deleted from the store
    assert not [p for p in fs.listdir("/ck") if "step_0000000001" in p]


def test_checkpoint_crc_detects_corruption(state):
    fs = make_cluster(4)
    cm = CheckpointManager(fs, "/ck")
    m = cm.save(state, 1)
    victim = m["leaves"][0]["file"]
    ino = fs.stat(victim)
    name = fs.object_name(ino, 0)
    for osd in fs.store.acting_set(name):      # corrupt every replica
        if osd.contains(name):
            osd._objects[name] = b"\x00" * len(osd._objects[name])
    with pytest.raises(IOError, match="CRC"):
        cm.restore(_structs(state))


def test_checkpoint_async(state):
    fs = make_cluster(4)
    cm = CheckpointManager(fs, "/ck")
    cm.save_async(state, 5)
    cm.wait()
    assert cm.latest_step() == 5
    out = cm.restore(_structs(state), 5)
    assert np.asarray(out["step"]) == 17


def test_checkpoint_survives_osd_loss(state):
    fs = make_cluster(6)
    cm = CheckpointManager(fs, "/ck")
    cm.save(state, 9)
    fs.store.fail_osd(0)
    fs.store.fail_osd(1)
    out = cm.restore(_structs(state))
    assert np.array_equal(np.asarray(out["params"]["w"]),
                          np.asarray(state["params"]["w"]))


def test_restore_missing_leaf_raises(state):
    fs = make_cluster(4)
    cm = CheckpointManager(fs, "/ck")
    cm.save(state, 1)
    bigger = dict(state, extra=jnp.zeros(3))
    with pytest.raises(KeyError):
        cm.restore(_structs(bigger))


# ---------------------------------------------------------------------------
# health + downsize planning
# ---------------------------------------------------------------------------


def test_health_monitor_timeout():
    hm = HealthMonitor(range(4), timeout_s=10.0)
    t0 = 1000.0
    for h in range(4):
        hm.heartbeat(h, now=t0)
    assert hm.dead_hosts(now=t0 + 5) == []
    hm.heartbeat(0, now=t0 + 12)
    hm.heartbeat(1, now=t0 + 12)
    assert hm.dead_hosts(now=t0 + 12) == [2, 3]
    assert hm.healthy_hosts(now=t0 + 12) == [0, 1]


def test_health_mark_down_and_rejoin():
    hm = HealthMonitor(range(3), timeout_s=1e9)
    hm.mark_down(1)
    hm.heartbeat(1)              # ignored while marked down
    assert 1 in hm.dead_hosts()
    hm.rejoin(1)
    assert hm.dead_hosts() == []


def test_plan_downsize_shrinks_data_axis_pow2():
    # fabricate shape arithmetic via a stand-in object
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    plan = plan_downsize(FakeMesh(), 16 * 13)
    assert plan.new_shape == (8, 16)            # floor-pow2 of 13
    plan = plan_downsize(FakeMesh(), 16 * 16)
    assert not plan.changed
    with pytest.raises(InsufficientDevicesError):
        plan_downsize(FakeMesh(), 7)


def test_elastic_downsize_end_to_end_subprocess():
    """Real 8-device resharding (device count needs its own process)."""
    import subprocess, sys, textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distrib import elastic_downsize
        from repro.sharding import default_rules
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = default_rules()
        state = {"w": jnp.arange(64.0).reshape(8, 8)}
        specs = {"w": ("embed", "mlp")}
        from repro.sharding import tree_shardings
        state = jax.device_put(state, tree_shardings(mesh, rules, state,
                                                     specs))
        healthy = list(jax.devices())[:4]       # lost half the fleet
        new_mesh, new_state, plan = elastic_downsize(
            state, specs, mesh, rules, healthy)
        assert plan.new_shape == (2, 2), plan
        assert np.array_equal(np.asarray(new_state["w"]),
                              np.arange(64.0).reshape(8, 8))
        ns = new_state["w"].sharding
        assert ns.mesh.shape["data"] == 2
        print("ELASTIC_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                         cwd="/root/repo")
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
