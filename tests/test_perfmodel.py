"""Cluster performance model + roofline HLO parsing."""

import pytest

from repro.dataset.format import TaskRecord
from repro.launch import roofline
from repro.storage.perfmodel import (ClusterSpec, rebalance_nodes,
                                     simulate_scan)


def _osd_tasks(n, nodes, cpu=0.1, wire=1000, client=0.001):
    return [TaskRecord("osd", i % nodes, cpu, wire, client, 10)
            for i in range(n)]


def test_client_scan_is_cpu_bound():
    tasks = [TaskRecord("client", -1, 0.1, 1000, 0.1, 10)
             for _ in range(64)]
    r = simulate_scan(tasks, ClusterSpec(nodes=8, client_threads=16))
    assert r.bottleneck == "client_cpu"
    # 64 tasks x 0.1s over 16 threads = 0.4s lower bound
    assert r.makespan_s == pytest.approx(0.4, rel=0.05)
    assert r.client_util(ClusterSpec(nodes=8)) > 0.9


def test_pushdown_scales_with_nodes():
    base = _osd_tasks(256, 4)
    t4 = simulate_scan(rebalance_nodes(base, 4), ClusterSpec(nodes=4))
    t8 = simulate_scan(rebalance_nodes(base, 8), ClusterSpec(nodes=8))
    t16 = simulate_scan(rebalance_nodes(base, 16), ClusterSpec(nodes=16))
    assert t8.makespan_s < t4.makespan_s * 0.6
    assert t16.makespan_s < t8.makespan_s * 0.7


def test_network_bound_at_full_selectivity():
    # 30 MB IPC results swamp the 1.25 GB/s NIC
    tasks = _osd_tasks(64, 8, cpu=0.01, wire=30_000_000)
    r = simulate_scan(tasks, ClusterSpec(nodes=8))
    assert r.bottleneck == "network"
    assert r.makespan_s == pytest.approx(64 * 30e6 / (10e9 / 8), rel=0.1)


def test_straggler_shows_up():
    tasks = _osd_tasks(32, 8)
    slow = list(tasks)
    slow[5] = TaskRecord("osd", 5 % 8, 3.0, 1000, 0.001, 10)
    a = simulate_scan(tasks, ClusterSpec(nodes=8))
    b = simulate_scan(slow, ClusterSpec(nodes=8))
    assert b.makespan_s > a.makespan_s + 2.5


# ---------------------------------------------------------------------------
# roofline HLO parsing
# ---------------------------------------------------------------------------

HLO = """
  x = bf16[256,4096]{1,0} all-gather(bf16[16,4096]{1,0} p), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  y = f32[1024]{0} all-reduce(f32[1024]{0} q), replica_groups=[32,16]<=[512], to_apply=add
  z = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} a, f32[8,8]{1,0} b), replica_groups={{0,256},{1,257}}
"""


def test_parse_collectives():
    colls = roofline.parse_collectives(HLO)
    assert len(colls) == 3
    ag = next(c for c in colls if c.op == "all-gather")
    assert ag.group_size == 16 and not ag.crosses_pod
    assert ag.result_bytes == 256 * 4096 * 2
    assert ag.wire_bytes == pytest.approx(ag.result_bytes * 15 / 16)
    ar = next(c for c in colls if c.op == "all-reduce")
    assert ar.group_size == 16
    assert ar.wire_bytes == pytest.approx(1024 * 4 * 2 * 15 / 16)
    a2a = next(c for c in colls if c.op == "all-to-all")
    assert a2a.crosses_pod                       # 0 and 256 straddle pods


def test_cost_analysis_counts_loops_once_and_text_model_corrects():
    """The motivation for roofline.text_costs: XLA's cost_analysis counts
    a while body once; the text model weights it by known_trip_count."""
    import jax
    import jax.numpy as jnp

    from repro.models import scanner

    def g(x, w):
        return scanner.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                            length=10)[0].sum()

    jax.clear_caches()
    c = jax.jit(g).lower(jnp.zeros((64, 128)), jnp.zeros((128, 128))
                         ).compile()
    one = 2 * 64 * 128 * 128
    ca = roofline.cost_analysis_dict(c)
    assert ca["flops"] / one < 1.5                         # body once
    tc = roofline.text_costs(c.as_text())
    assert abs(tc["flops"] / one - 10.0) < 0.1             # body x10


def test_text_costs_match_cost_analysis_loop_free():
    import jax
    import jax.numpy as jnp

    def f(x, w1, w2):
        return (jnp.tanh(x @ w1) @ w2).sum()

    jax.clear_caches()
    c = jax.jit(f).lower(jnp.zeros((128, 512)), jnp.zeros((512, 256)),
                         jnp.zeros((256, 64))).compile()
    ca = roofline.cost_analysis_dict(c)
    tc = roofline.text_costs(c.as_text())
    assert abs(tc["flops"] - ca["flops"]) / ca["flops"] < 0.02
    assert abs(tc["bytes"] - ca["bytes accessed"]) / \
        ca["bytes accessed"] < 0.05


def test_roofline_terms_bottleneck():
    terms = roofline.roofline_terms(1e15, 1e10, [])
    assert terms["bottleneck"] == "compute_s"
    assert terms["roofline_fraction"] == 1.0
    terms = roofline.roofline_terms(1e12, 1e12, [])
    assert terms["bottleneck"] == "memory_s"
    assert terms["roofline_fraction"] < 0.01
