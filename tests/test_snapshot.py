"""Mutable datasets: snapshot isolation, tombstones, optimistic commits,
and the storage-side compaction engine (``compact_op``).

The invariants under test are the subsystem's contract: every query runs
against exactly one immutable snapshot no matter what commits land under
it, deleted rows never resurface at any placement, concurrent writers
never lose updates (CAS on the manifest head), and compaction rewrites
bytes *inside* the cluster — only footer metadata crosses the client
wire — without perturbing pinned readers or the adaptive scheduler's
version-keyed result cache.
"""

import threading

import numpy as np
import pytest

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import (
    AdaptiveFormat,
    CommitConflict,
    MutableDataset,
    dataset,
    make_cluster,
)
from repro.dataset.snapshot import Manifest, head_object, is_mutable
from repro.storage.objstore import VersionConflictError


def make_part(lo: int, n: int) -> Table:
    """Deterministic rows: k identifies the row, v = k * 0.5."""
    k = np.arange(lo, lo + n, dtype=np.int64)
    return Table.from_pydict({"k": k, "v": k.astype(np.float64) * 0.5})


def keys_of(table: Table) -> list[int]:
    return sorted(table.column("k").values.tolist())


def check_values(table: Table) -> None:
    k = table.column("k").values.astype(np.float64)
    assert np.array_equal(table.column("v").values, k * 0.5)


@pytest.fixture
def mut():
    fs = make_cluster(8)
    md = MutableDataset.create(fs, "/mut")
    for i in range(8):
        md.append(make_part(i * 100, 100), row_group_rows=100)
    return fs, md


# ---------------------------------------------------------------------------
# append / snapshot basics
# ---------------------------------------------------------------------------


def test_append_and_scan_all_formats(mut):
    _fs, md = mut
    for fmt in ("parquet", "pushdown", "adaptive"):
        out = md.query(format=fmt).to_table()
        assert keys_of(out) == list(range(800))
        check_values(out)


def test_every_query_pins_its_snapshot(mut):
    _fs, md = mut
    q = md.query(format="pushdown")
    md.append(make_part(800, 100))
    # planned and executed after the append, but pinned at build time
    assert len(q.to_table()) == 800
    assert len(md.query(format="pushdown").to_table()) == 900


def test_as_of_time_travel(mut):
    _fs, md = mut
    sid = md.snapshot()
    md.append(make_part(800, 100))
    assert md.as_of(sid).num_rows == 800
    assert md.as_of().num_rows == 900
    with pytest.raises(KeyError):
        md.as_of(10_000)


def test_discovery_reads_manifest_not_listing(mut):
    fs, md = mut
    assert is_mutable(fs, "/mut")
    ds = dataset(fs, "/mut")
    assert ds.layout == "mutable"
    assert ds.snapshot_id == md.snapshot()
    assert keys_of(ds.query(format="pushdown").to_table()) == \
        list(range(800))
    # a stray uncommitted file under the prefix stays invisible
    fs.write_file("/mut/data/orphan.arw", b"junk" * 16)
    assert dataset(fs, "/mut").num_rows == 800


def test_append_validates_schema(mut):
    _fs, md = mut
    bad = Table.from_pydict({"x": np.arange(4, dtype=np.int64)})
    with pytest.raises(ValueError, match="schema mismatch"):
        md.append(bad)
    with pytest.raises(ValueError, match="empty"):
        md.append(make_part(0, 100).slice(0, 0))


def test_empty_dataset_answers_or_refuses_cleanly():
    """A freshly created store (no appends, no schema yet) must answer
    schema-free queries and refuse column-referencing ones loudly."""
    fs = make_cluster(4)
    md = MutableDataset.create(fs, "/fresh")
    assert md.scanner(format="pushdown").count_rows() == 0
    assert md.query(format="pushdown").to_table().num_rows == 0
    agg = md.query(format="pushdown").aggregate(["count"]).to_table()
    assert int(agg.column("count").values[0]) == 0
    with pytest.raises(ValueError, match="no schema"):
        md.query().select("k")
    with pytest.raises(ValueError, match="no schema"):
        md.query().aggregate([("sum", "k")])
    # serving: sizing an empty prompt store is zero waves, not a crash
    from repro.serve.engine import prompt_lengths

    lens, _ = prompt_lengths(md, format="pushdown")
    assert lens == {}


def test_failed_append_leaks_no_file(mut):
    fs, md = mut
    files_before = set(fs.listdir("/mut/data"))
    bad = Table.from_pydict({"x": np.arange(4, dtype=np.int64)})
    with pytest.raises(ValueError, match="schema mismatch"):
        md.append(bad)
    assert set(fs.listdir("/mut/data")) == files_before


def test_create_twice_fails():
    fs = make_cluster(4)
    MutableDataset.create(fs, "/d")
    with pytest.raises(FileExistsError):
        MutableDataset.create(fs, "/d")
    with pytest.raises(FileNotFoundError):
        MutableDataset.open(fs, "/other")


# ---------------------------------------------------------------------------
# snapshot isolation under concurrent writes
# ---------------------------------------------------------------------------


def test_reader_streams_pinned_snapshot_while_writer_appends(mut):
    """A to_batches() stream started before an append never sees it."""
    _fs, md = mut
    q = md.query(format="pushdown", num_threads=2)
    stream = q.to_batches(max_inflight=1)
    got = [next(stream)]  # stream is live before the writer commits
    md.append(make_part(5000, 64))
    md.delete(field("k") >= 5000)
    got.extend(stream)
    merged = Table.concat(got)
    assert keys_of(merged) == list(range(800))
    check_values(merged)


def test_concurrent_appenders_lose_no_update():
    fs = make_cluster(8)
    MutableDataset.create(fs, "/c")
    writers, per_writer, rows = 4, 6, 50
    errors = []

    def work(w: int) -> None:
        md = MutableDataset.open(fs, "/c")
        try:
            for j in range(per_writer):
                lo = (w * per_writer + j) * rows
                md.append(make_part(lo, rows), row_group_rows=rows)
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    threads = [
        threading.Thread(target=work, args=(w,)) for w in range(writers)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    md = MutableDataset.open(fs, "/c")
    assert md.snapshot() == writers * per_writer
    out = md.query(format="pushdown").to_table()
    assert keys_of(out) == list(range(writers * per_writer * rows))
    check_values(out)


def test_optimistic_commit_retries_on_conflict(mut):
    """A commit that loses the HEAD CAS race rebases and retries."""
    _fs, md = mut
    md2 = MutableDataset.open(md.fs, "/mut")
    before = md.snapshot()
    sneaked = {"done": False}

    def mutate(head: Manifest) -> Manifest:
        if not sneaked["done"]:
            sneaked["done"] = True
            md2.append(make_part(9000, 10))  # commits under us
        sid = head.snapshot_id + 1
        return Manifest(
            sid, head.snapshot_id, list(head.files), list(head.tombstones)
        )

    new = md._commit(mutate)
    assert md.commit_conflicts == 1
    assert new.snapshot_id == before + 2  # sneaked commit + ours


def test_put_if_version_is_the_commit_token(mut):
    fs, md = mut
    name = head_object("/mut")
    stale = fs.store.version_of(name)
    md.append(make_part(9000, 10))
    with pytest.raises(VersionConflictError):
        fs.store.put_if_version(name, b"stale manifest", stale)


# ---------------------------------------------------------------------------
# tombstones
# ---------------------------------------------------------------------------


def test_deleted_rows_never_resurface_any_format(mut):
    _fs, md = mut
    pre = md.snapshot()
    md.delete((field("k") >= 150) & (field("k") < 250))
    md.delete(field("k") == 700)
    live = [k for k in range(800) if not (150 <= k < 250) and k != 700]
    for fmt in ("parquet", "pushdown", "adaptive"):
        out = md.query(format=fmt).to_table()
        assert keys_of(out) == live
        check_values(out)
        n = md.scanner(format=fmt).count_rows()
        assert n == len(live)
    # aggregates see the tombstones too
    agg = md.query(format="pushdown").aggregate([("sum", "k")]).to_table()
    assert int(agg.column("sum_k").values[0]) == sum(live)
    # the pre-delete snapshot still has them
    assert md.as_of(pre).scanner(format="pushdown").count_rows() == 800


def test_tombstone_applies_only_to_older_files(mut):
    _fs, md = mut
    md.delete(field("k") < 100)  # tombstones file 0 (k 0..99)
    md.append(make_part(0, 50))  # re-inserts k 0..49 *after* the delete
    out = md.query(format="pushdown").to_table()
    assert keys_of(out) == sorted(
        list(range(50)) + list(range(100, 800))
    )


def test_tombstone_pruning_is_exact(mut):
    """Stats-provable tombstones prune whole fragments; untouched
    fragments keep their metadata-only answers."""
    _fs, md = mut
    md.delete(field("k") < 100)  # exactly file 0: stats prove ALL
    q = md.query(format="pushdown").count()
    assert q.to_scalar() == 700
    m = q.metrics
    assert m.fragments_pruned == 1  # the fully-deleted fragment
    # every surviving fragment is metadata-answered (tombstone proven
    # NONE by stats) — zero I/O for the whole count
    assert m.metadata_answers == 7
    assert len(m.tasks) == 0


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------


def test_compact_exact_and_metadata_only_wire():
    fs = make_cluster(8)
    md = MutableDataset.create(fs, "/big")
    for i in range(8):
        md.append(make_part(i * 2000, 2000), row_group_rows=2000)
    before = md.query(format="pushdown").to_table()
    data_bytes = sum(
        rg.total_bytes
        for f in md._read_head()[0].files
        for rg in f.footer.row_groups
    )
    report = md.compact(target_rows=8000)
    # greedy replica-set binning packs nearly everything; files the
    # cluster topology strands as singletons may legitimately remain
    assert report.files_in >= 6
    assert report.files_out < report.files_in
    assert report.fallbacks == 0 and report.fallback_wire_bytes == 0
    # the offload contract: raw row-group bytes never round-trip to the
    # client — only payload JSON out and footer metadata back
    assert report.wire_bytes < 0.10 * data_bytes
    assert report.rewritten_bytes > 0
    after = md.query(format="pushdown").to_table()
    assert keys_of(after) == keys_of(before) == list(range(16000))
    check_values(after)
    # fewer, right-sized fragments
    assert len(md.as_of().fragments()) < 8


def test_compact_drops_tombstoned_rows_physically(mut):
    _fs, md = mut
    md.delete((field("k") >= 0) & (field("k") < 300))
    report = md.compact(target_rows=400)
    assert report.tombstones_dropped == 1
    head = md._read_head()[0]
    assert head.tombstones == []
    assert sum(f.rows for f in head.files) == 500  # physically gone
    out = md.query(format="pushdown").to_table()
    assert keys_of(out) == list(range(300, 800))


def test_compact_all_rows_deleted_retires_files():
    fs = make_cluster(8)
    md = MutableDataset.create(fs, "/gone")
    for i in range(4):
        md.append(make_part(i * 10, 10))
    md.delete(field("k") >= 0)
    report = md.compact(target_rows=1000)
    assert report.files_in == 4 and report.files_out == 0
    head = md._read_head()[0]
    assert head.files == [] and head.tombstones == []
    assert md.query(format="pushdown").to_table().num_rows == 0


def test_pinned_reader_survives_compaction_and_expire(mut):
    _fs, md = mut
    pre = md.snapshot()
    md.delete(field("k") < 100)
    md.compact(target_rows=400)
    pinned = md.as_of(pre)
    out = pinned.query(format="pushdown").to_table()
    assert keys_of(out) == list(range(800))  # pre-delete, pre-compact
    removed = md.expire()
    assert removed  # the compacted-away small files are gone
    with pytest.raises(KeyError):
        md.as_of(pre)
    # HEAD is untouched by the GC
    assert keys_of(md.query(format="pushdown").to_table()) == \
        list(range(100, 800))


def test_compact_conflicts_with_concurrent_delete(mut):
    """A delete committing mid-compaction must abort the rewrite (its
    keep-predicates are stale), and the orphaned output is cleaned up."""
    _fs, md = mut
    md2 = MutableDataset.open(md.fs, "/mut")
    orig_commit = md._commit

    def racing_commit(mutate, **kw):
        md2.delete(field("k") < 50)
        return orig_commit(mutate, **kw)

    md._commit = racing_commit
    with pytest.raises(CommitConflict):
        md.compact(target_rows=400)
    md._commit = orig_commit
    # nothing committed, nothing leaked: the dataset still answers
    # exactly, and a re-run compacts against the fresh tombstone
    assert keys_of(md.query(format="pushdown").to_table()) == \
        list(range(50, 800))
    report = md.compact(target_rows=400)
    assert report.files_in == 8
    assert keys_of(md.query(format="pushdown").to_table()) == \
        list(range(50, 800))


def test_result_cache_stays_correct_across_compaction(mut):
    """The adaptive scheduler's version-keyed cache: entries for the
    retired objects become unreachable — measured, not assumed."""
    _fs, md = mut
    fmt = AdaptiveFormat()
    warm = md.query(format=fmt).to_table()
    again = md.query(format=fmt).to_table()
    assert keys_of(again) == keys_of(warm)
    sched = fmt.scheduler_for(md.fs)
    hits_before = sched.cache.stats()["hits"]
    assert hits_before > 0  # the repeat scan was served from cache

    head_before = md._read_head()[0]
    md.compact(target_rows=400)
    head_after = md._read_head()[0]
    surviving = {f.path for f in head_before.files} & {
        f.path for f in head_after.files
    }
    expected_hits = sum(
        len(f.footer.row_groups)
        for f in head_after.files
        if f.path in surviving
    )
    q = md.query(format=fmt)
    post = q.to_table()
    assert keys_of(post) == list(range(800))
    check_values(post)
    # only fragments of files the compaction left untouched may hit the
    # cache; every retired object's entry is unreachable (new names, new
    # versions) — measured via the scheduler's own hit counter
    assert q.metrics.cache_hits == expected_hits
    assert sched.cache.stats()["hits"] == hits_before + expected_hits
    # and the new objects' results cache normally afterwards
    q2 = md.query(format=fmt)
    q2.to_table()
    assert q2.metrics.cache_hits == len(q2.metrics.tasks) > 0


# ---------------------------------------------------------------------------
# serving ingest through the transactional path
# ---------------------------------------------------------------------------


def test_append_prompts_and_pinned_ingest():
    from repro.serve.engine import (
        Request,
        append_prompts,
        ingest_prompts,
        prompt_lengths,
    )

    fs = make_cluster(8)
    store = MutableDataset.create(fs, "/prompts")
    rng = np.random.default_rng(7)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, 999, 6 + i).astype(np.int32))
        for i in range(5)
    ]
    sid = append_prompts(store, reqs)
    lens, _ = prompt_lengths(store, format="pushdown")
    assert lens == {i: 6 + i for i in range(5)}
    # second wave commits; the first boundary replays exactly via as_of
    append_prompts(store, [Request(uid=9, prompt=np.arange(3, dtype=np.int32))])
    wave1, _ = ingest_prompts(store.as_of(sid), format="pushdown")
    assert [r.uid for r in wave1] == [0, 1, 2, 3, 4]
    for r, want in zip(wave1, reqs):
        assert np.array_equal(r.prompt, want.prompt)
    wave2, _ = ingest_prompts(store, format="pushdown")
    assert [r.uid for r in wave2] == [0, 1, 2, 3, 4, 9]
