"""End-to-end system test: the paper's storage stack feeding real training.

corpus -> object store (3-way replicated) -> pushdown-filtered sharded
reader -> train a tiny model -> checkpoint model+reader into the same
object store -> kill an OSD mid-run -> restore and continue.  This is
the full integration path of DESIGN.md §3 on one CPU device.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.aformat.expressions import field
from repro.configs import smoke_config
from repro.core import dataset, make_cluster
from repro.data import synth_corpus, write_corpus
from repro.distrib import CheckpointManager
from repro.ingest import ReaderConfig, ReaderState, ShardedReader
from repro.launch.mesh import make_local_mesh
from repro.sharding import default_rules
from repro.train import optim, step as step_mod


def test_end_to_end_train_with_pushdown_ingest():
    # --- storage: corpus into the simulated Ceph cluster -------------------
    fs = make_cluster(6)
    vocab = 256
    corpus = synth_corpus(150, mean_doc_len=300, vocab_size=vocab, seed=0)
    write_corpus(fs, "/corpus", corpus, num_shards=3, row_group_rows=8192)
    ds = dataset(fs, "/corpus")

    # --- ingest: storage-side quality filtering through the query plan -----
    rcfg = ReaderConfig(seq_len=32, local_batch=4,
                        predicate=field("quality") > 0.3,
                        format="pushdown", num_threads=2, seed=1)
    pipe = ShardedReader(ds, rcfg)

    # --- model + train step -------------------------------------------------
    cfg = smoke_config("starcoder2-7b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=64, d_ff=128,
                              num_heads=2, num_kv_heads=2, head_dim=32,
                              vocab_size=vocab, remat=False)
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    opt = optim.OptConfig(peak_lr=1e-3, warmup_steps=5, decay_steps=100)
    state, spec_tree = step_mod.init_state(cfg, opt, jax.random.key(0))
    fn = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt))

    cm = CheckpointManager(fs, "/ckpt", keep=2)
    losses = []
    for step in range(8):
        batch = next(pipe)
        state, mets = fn(state, {k: jnp.asarray(v)
                                 for k, v in batch.items()})
        losses.append(float(mets["loss"]))
        if step == 4:
            # one commit point holds the model and the reader cut
            cm.save({"model": state,
                     "reader": pipe.checkpoint().to_arrays()}, step)

    assert all(np.isfinite(losses))
    # ingest really ran on the storage nodes
    st = pipe.stats()
    assert st["osd_cpu_s"] > 0 and st["client_cpu_s"] < st["osd_cpu_s"] * 5
    pipe.close()

    # --- failure + restore ----------------------------------------------------
    fs.store.fail_osd(0)
    structs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           state)
    restored = cm.restore({"model": structs,
                           "reader": ReaderState.restore_structs()}, 4)
    assert int(np.asarray(restored["model"]["step"])) == 5
    rstate = ReaderState.from_arrays(restored["reader"])
    # the restored reader continues the stream through the degraded store
    pipe2 = ShardedReader(ds, rcfg, state=rstate)
    batch = next(pipe2)
    state2, mets = fn(restored["model"], {k: jnp.asarray(v)
                                          for k, v in batch.items()})
    assert np.isfinite(float(mets["loss"]))
    pipe2.close()


def test_scan_consistency_under_failure_and_hedging():
    """Pushdown scans agree with client scans even with a down OSD and a
    straggling primary (hedged to a replica)."""
    fs = make_cluster(8)
    corpus = synth_corpus(60, mean_doc_len=150, vocab_size=100, seed=2)
    write_corpus(fs, "/c", corpus, num_shards=2, row_group_rows=2048)
    ds = dataset(fs, "/c")
    pred = field("domain") == 2

    ref = ds.scanner(format="parquet", columns=["token"],
                     predicate=pred, num_threads=1).to_table()
    fs.store.fail_osd(1)
    fs.store.osds[2].straggle_factor = 50.0
    from repro.dataset import PushdownParquetFormat
    sc = ds.scanner(format=PushdownParquetFormat(hedge_threshold_s=1e-4),
                    columns=["token"], predicate=pred, num_threads=2)
    out = sc.to_table()
    assert np.array_equal(np.sort(out.column("token").values),
                          np.sort(ref.column("token").values))
