"""Per-kernel shape/dtype sweeps: pallas (interpret) vs pure-jnp oracle."""

import numpy as np
import pytest

# slow lane: jax/pallas compile-heavy; skipped by `make test-fast` / CI per-push
pytestmark = pytest.mark.slow

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.dict_decode import ops as dd_ops
from repro.kernels.dict_decode.ref import dict_decode_ref
from repro.kernels.predicate_fused import ops as pf_ops
from repro.kernels.predicate_fused.predicate_fused import Program, Term
from repro.kernels.predicate_fused.ref import predicate_mask_ref
from repro.kernels.token_pack import ops as tp_ops
from repro.kernels.token_pack.ref import pack_ref, tile_pack_ref
from repro.kernels.token_pack.token_pack import TILE as TP_TILE, tile_pack


# ---------------------------------------------------------------------------
# predicate_fused
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 100, 2048, 2049, 7777, 65536])
@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.float64])
def test_predicate_shapes(n, dtype):
    rng = np.random.default_rng(n)
    cols = [rng.uniform(-100, 100, n).astype(dtype),
            rng.integers(0, 10, n).astype(np.int32)]
    prog = pf_ops.build_program([(0, "gt", 3.0), (1, "ne", 7)], "and")
    got = np.asarray(pf_ops.fused_predicate(cols, prog))
    exp = (cols[0].astype(np.float32) > 3.0) & (cols[1] != 7)
    assert np.array_equal(got, exp)


@pytest.mark.parametrize("op", ["lt", "le", "gt", "ge", "eq", "ne"])
@pytest.mark.parametrize("combine", ["and", "or"])
def test_predicate_ops(op, combine):
    rng = np.random.default_rng(3)
    cols = [rng.integers(-5, 5, 4096).astype(np.int32),
            rng.integers(-5, 5, 4096).astype(np.int32)]
    prog = pf_ops.build_program([(0, op, 0), (1, "ge", 2)], combine)
    stacked = jnp.stack([jnp.asarray(c, jnp.float32) for c in cols])
    got = np.asarray(pf_ops.fused_predicate(cols, prog))
    exp = np.asarray(predicate_mask_ref(stacked, prog)).astype(bool)
    assert np.array_equal(got, exp)


def test_predicate_negate():
    cols = [np.arange(2048, dtype=np.float32)]
    prog = Program((Term(0, "lt", 100.0),), "and", negate=True)
    got = np.asarray(pf_ops.fused_predicate(cols, prog))
    assert np.array_equal(got, np.arange(2048) >= 100)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5000), st.floats(-50, 50), st.floats(-50, 50))
def test_predicate_property(n, t1, t2):
    rng = np.random.default_rng(n)
    cols = [rng.uniform(-60, 60, n).astype(np.float32),
            rng.uniform(-60, 60, n).astype(np.float32)]
    prog = pf_ops.build_program([(0, "ge", t1), (1, "lt", t2)], "or")
    got = np.asarray(pf_ops.fused_predicate(cols, prog))
    exp = (cols[0] >= np.float32(t1)) | (cols[1] < np.float32(t2))
    assert np.array_equal(got, exp)


# ---------------------------------------------------------------------------
# dict_decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 1024, 1025, 50_000])
@pytest.mark.parametrize("d", [1, 7, 128, 2048, 2049, 60_000])
def test_dict_decode_shapes(n, d):
    rng = np.random.default_rng(n + d)
    dic = rng.normal(size=d).astype(np.float32)
    codes = rng.integers(0, d, n).astype(np.int32)
    got = np.asarray(dd_ops.decode_dictionary(codes, dic))
    exp = np.asarray(dict_decode_ref(jnp.asarray(codes), jnp.asarray(dic)))
    np.testing.assert_allclose(got, exp, rtol=1e-6)


@pytest.mark.parametrize("dtype", [np.int32, np.int64, np.float64])
def test_dict_decode_dtypes(dtype):
    rng = np.random.default_rng(0)
    if np.issubdtype(dtype, np.integer):
        dic = rng.integers(0, 2 ** 20, 500).astype(dtype)
    else:
        dic = rng.normal(size=500).astype(dtype)
    codes = rng.integers(0, 500, 3000)
    got = np.asarray(dd_ops.decode_dictionary(codes, dic))
    assert got.dtype == dtype
    if np.issubdtype(dtype, np.integer):
        assert np.array_equal(got, dic[codes])
    else:
        np.testing.assert_allclose(got, dic[codes].astype(np.float32),
                                   rtol=1e-6)


def test_dict_decode_rejects_inexact_ints():
    dic = np.array([2 ** 25], np.int64)
    with pytest.raises(ValueError):
        dd_ops.decode_dictionary(np.zeros(10, np.int32), dic)


# ---------------------------------------------------------------------------
# token_pack
# ---------------------------------------------------------------------------


def test_tile_pack_kernel_stage():
    rng = np.random.default_rng(1)
    n = 4 * TP_TILE
    v = rng.normal(size=n).astype(np.float32)
    m = (rng.random(n) < 0.4).astype(np.uint8)
    packed, counts = tile_pack(jnp.asarray(v), jnp.asarray(m),
                               interpret=True)
    exp_p, exp_c = tile_pack_ref(v, m, TP_TILE)
    assert np.array_equal(np.asarray(counts), exp_c)
    np.testing.assert_allclose(np.asarray(packed), exp_p, rtol=1e-6)


@pytest.mark.parametrize("n", [1, 511, 512, 513, 10_000])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5, 1.0])
def test_pack_tokens_shapes(n, density):
    rng = np.random.default_rng(int(n + density * 10))
    vals = rng.integers(0, 2 ** 20, n).astype(np.int32)
    mask = rng.random(n) < density
    cap = max(64, n // 2)
    got, cnt = tp_ops.pack_tokens(vals, mask, cap)
    exp, exp_cnt = pack_ref(vals, mask, cap)
    assert int(cnt) == exp_cnt
    assert np.array_equal(np.asarray(got), exp)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3000), st.floats(0, 1), st.integers(16, 2000))
def test_pack_tokens_property(n, density, cap):
    rng = np.random.default_rng(n)
    vals = rng.normal(size=n).astype(np.float32)
    mask = rng.random(n) < density
    got, cnt = tp_ops.pack_tokens(vals, mask, cap)
    exp, exp_cnt = pack_ref(vals, mask, cap)
    assert int(cnt) == exp_cnt
    np.testing.assert_allclose(np.asarray(got), exp, rtol=1e-6)


def test_pack_preserves_order():
    vals = np.arange(2000, dtype=np.int32)
    mask = vals % 3 == 0
    got, cnt = tp_ops.pack_tokens(vals, mask, 1024)
    kept = np.asarray(got)[: int(cnt)]
    assert np.array_equal(kept, vals[mask][:1024])
    assert (np.diff(kept) > 0).all()
