"""int8 error-feedback gradient compression: wire dtype + convergence."""

import pytest
import subprocess
import sys

# slow lane: jax/pallas compile-heavy; skipped by `make test-fast` / CI per-push
pytestmark = pytest.mark.slow
import textwrap

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.train import compress

    mesh = jax.make_mesh((4,), ("pod",))

    # --- quadratic regression: compressed DP matches exact DP -------------
    rng = np.random.default_rng(0)
    X = rng.normal(size=(64, 16)).astype(np.float32)
    w_true = rng.normal(size=(16,)).astype(np.float32)
    y = X @ w_true

    def loss_fn(params, batch):
        pred = batch["tokens"] @ params["w"]
        return jnp.mean((pred - batch["labels"]) ** 2)

    grad_fn = jax.jit(compress.make_compressed_grad_fn(loss_fn, mesh))
    batch = {"tokens": jnp.asarray(X), "labels": jnp.asarray(y)}

    params = {"w": jnp.zeros(16)}
    err = compress.init_error_state(params)
    params_ref = {"w": jnp.zeros(16)}
    for i in range(300):
        loss, grads, err = grad_fn(params, batch, err)
        params = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
        _, g_ref = jax.value_and_grad(loss_fn)(params_ref, batch)
        params_ref = jax.tree.map(lambda p, g: p - 0.05 * g, params_ref,
                                  g_ref)
    err_c = float(jnp.linalg.norm(params["w"] - w_true))
    err_e = float(jnp.linalg.norm(params_ref["w"] - w_true))
    assert err_c < err_e + 0.05, (err_c, err_e)   # converged comparably

    # --- the wire really is int8 ------------------------------------------
    hlo = grad_fn.lower(params, batch, err).compile().as_text()
    assert any("s8[" in l and "all-gather" in l for l in hlo.splitlines()),\\
        "no int8 all-gather on the wire"
    f32_ag = [l for l in hlo.splitlines()
              if "all-gather" in l and "f32[4,64" in l]
    assert not f32_ag, "full-width gradient all-gather still present"
    print("COMPRESS_OK", round(err_c, 4), round(err_e, 4))
""")


def test_compressed_allreduce():
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                         cwd="/root/repo")
    assert "COMPRESS_OK" in out.stdout, (out.stdout[-1000:],
                                         out.stderr[-2500:])
