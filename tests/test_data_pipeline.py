"""Training ingest pipeline: packing, filtering, rank-disjointness."""

import numpy as np
import pytest

from repro.aformat.expressions import field
from repro.core import dataset, make_cluster
from repro.data import (PipelineConfig, Prefetcher, TokenPipeline,
                        synth_corpus, write_corpus)


@pytest.fixture(scope="module")
def corpus_fs():
    fs = make_cluster(4)
    tbl = synth_corpus(300, mean_doc_len=200, vocab_size=1000, seed=3)
    write_corpus(fs, "/c", tbl, num_shards=4, row_group_rows=4096)
    return fs, tbl


def test_batches_shapes_and_shift(corpus_fs):
    fs, tbl = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=64, local_batch=8, format="pushdown",
                         num_threads=2)
    pipe = TokenPipeline(ds, cfg)
    for _, b in zip(range(6), pipe.batches()):
        assert b["tokens"].shape == (8, 64)
        assert b["labels"].shape == (8, 64)
        assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()
        assert b["tokens"].dtype == np.int32


def test_quality_filter_reduces_stream(corpus_fs):
    fs, tbl = corpus_fs
    ds = dataset(fs, "/c")
    base = PipelineConfig(seq_len=64, local_batch=4)
    filt = PipelineConfig(seq_len=64, local_batch=4,
                          predicate=field("quality") > 0.8)
    p_all = TokenPipeline(ds, base)
    p_filt = TokenPipeline(ds, filt)
    next(iter(p_all.batches()))
    next(iter(p_filt.batches()))
    # filtered pipeline ships fewer rows per fragment
    r_all = p_all.stats()["rows"] / p_all.stats()["fragments_scanned"]
    r_f = p_filt.stats()["rows"] / p_filt.stats()["fragments_scanned"]
    assert r_f < r_all * 0.6


def test_filtered_tokens_match_oracle(corpus_fs):
    """Every token the pipeline emits must come from a quality>t doc."""
    fs, tbl = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=32, local_batch=2,
                         predicate=field("quality") > 0.9, seed=5)
    good = set(tbl.column("token").values[
        tbl.column("quality").values > 0.9].tolist())
    pipe = TokenPipeline(ds, cfg)
    for _, b in zip(range(3), pipe.batches()):
        assert set(b["tokens"].ravel().tolist()) <= good


def test_rank_disjoint_and_complete(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=32, local_batch=2)
    all_frags = {(f.path, f.obj_idx, f.rg_in_object)
                 for f in ds.fragments()}
    seen = set()
    for r in range(4):
        p = TokenPipeline(ds, cfg, dp_rank=r, dp_size=4)
        ids = {(f.path, f.obj_idx, f.rg_in_object) for f in p.fragments}
        assert not ids & seen
        seen |= ids
    assert seen == all_frags


def test_epoch_determinism(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=32, local_batch=2, seed=11)
    a = [b["tokens"] for _, b in zip(range(4),
                                     TokenPipeline(ds, cfg).batches())]
    b = [b["tokens"] for _, b in zip(range(4),
                                     TokenPipeline(ds, cfg).batches())]
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


def test_prefetcher_propagates_errors():
    def gen():
        yield 1
        raise RuntimeError("boom")

    p = Prefetcher(gen(), depth=2)
    assert next(p) == 1
    with pytest.raises(RuntimeError, match="boom"):
        next(p)


def test_prefetcher_overlap():
    import time

    def slow():
        for i in range(4):
            time.sleep(0.02)
            yield i

    p = Prefetcher(slow(), depth=2)
    time.sleep(0.1)                     # producer runs ahead while we wait
    t0 = time.perf_counter()
    out = list(p)
    elapsed = time.perf_counter() - t0
    assert out == [0, 1, 2, 3]
    assert elapsed < 0.06               # most items were already buffered
