"""Training ingest pipeline: packing, filtering, rank-disjointness —
now exercised through the deprecated TokenPipeline wrapper over
repro.ingest.ShardedReader."""

import numpy as np
import pytest

from repro.aformat.expressions import field
from repro.core import dataset, make_cluster
from repro.data import (PipelineConfig, Prefetcher, TokenPipeline,
                        synth_corpus, write_corpus)

# the module under test *is* the deprecated shim
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def corpus_fs():
    fs = make_cluster(4)
    tbl = synth_corpus(300, mean_doc_len=200, vocab_size=1000, seed=3)
    write_corpus(fs, "/c", tbl, num_shards=4, row_group_rows=4096)
    return fs, tbl


def test_batches_shapes_and_shift(corpus_fs):
    fs, tbl = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=64, local_batch=8, format="pushdown",
                         num_threads=2)
    pipe = TokenPipeline(ds, cfg)
    for _, b in zip(range(6), pipe.batches()):
        assert b["tokens"].shape == (8, 64)
        assert b["labels"].shape == (8, 64)
        assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()
        assert b["tokens"].dtype == np.int32


def test_quality_filter_reduces_stream(corpus_fs):
    fs, tbl = corpus_fs
    ds = dataset(fs, "/c")
    base = PipelineConfig(seq_len=64, local_batch=4)
    filt = PipelineConfig(seq_len=64, local_batch=4,
                          predicate=field("quality") > 0.8)
    p_all = TokenPipeline(ds, base)
    p_filt = TokenPipeline(ds, filt)
    next(iter(p_all.batches()))
    next(iter(p_filt.batches()))
    # filtered pipeline ships fewer rows per fragment
    r_all = p_all.stats()["rows"] / p_all.stats()["fragments_scanned"]
    r_f = p_filt.stats()["rows"] / p_filt.stats()["fragments_scanned"]
    assert r_f < r_all * 0.6


def test_filtered_tokens_match_oracle(corpus_fs):
    """Every token the pipeline emits must come from a quality>t doc."""
    fs, tbl = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=32, local_batch=2,
                         predicate=field("quality") > 0.9, seed=5)
    good = set(tbl.column("token").values[
        tbl.column("quality").values > 0.9].tolist())
    pipe = TokenPipeline(ds, cfg)
    for _, b in zip(range(3), pipe.batches()):
        assert set(b["tokens"].ravel().tolist()) <= good


def test_rank_disjoint_and_complete(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=32, local_batch=2)
    all_frags = {(f.path, f.obj_idx, f.rg_in_object)
                 for f in ds.fragments()}
    seen = set()
    for r in range(4):
        p = TokenPipeline(ds, cfg, dp_rank=r, dp_size=4)
        ids = {(f.path, f.obj_idx, f.rg_in_object) for f in p.fragments}
        assert not ids & seen
        seen |= ids
    assert seen == all_frags


def test_epoch_determinism(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=32, local_batch=2, seed=11)
    a = [b["tokens"] for _, b in zip(range(4),
                                     TokenPipeline(ds, cfg).batches())]
    b = [b["tokens"] for _, b in zip(range(4),
                                     TokenPipeline(ds, cfg).batches())]
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


def test_deprecation_warning_fires(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    with pytest.warns(DeprecationWarning, match="ShardedReader"):
        TokenPipeline(ds, PipelineConfig(seq_len=32, local_batch=2))


def test_empty_shard_is_legal(corpus_fs):
    """dp_size > fragment count used to raise; now the starved ranks
    yield nothing and the populated ranks still cover every fragment."""
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = PipelineConfig(seq_len=32, local_batch=2)
    n_frags = len(ds.fragments())
    dp = n_frags + 3
    pipes = [TokenPipeline(ds, cfg, dp_rank=r, dp_size=dp)
             for r in range(dp)]
    empty = [p for p in pipes if not p.fragments]
    assert empty, "expected at least one starved rank"
    for p in empty:
        assert list(p.batches()) == []
    covered = {(f.path, f.obj_idx, f.rg_in_object)
               for p in pipes for f in p.fragments}
    assert covered == {(f.path, f.obj_idx, f.rg_in_object)
                       for f in ds.fragments()}


def test_wrapper_matches_direct_reader(corpus_fs):
    """The shim is a veneer: same batches as ShardedReader itself."""
    from repro.ingest import ReaderConfig, ShardedReader

    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    pipe = TokenPipeline(ds, PipelineConfig(seq_len=32, local_batch=2,
                                            seed=7))
    reader = ShardedReader(ds, ReaderConfig(seq_len=32, local_batch=2,
                                            seed=7))
    for _, a, b in zip(range(4), pipe.batches(), reader):
        assert np.array_equal(a["tokens"], b["tokens"])
        assert np.array_equal(a["labels"], b["labels"])
    pipe.close()
    reader.close()


def test_prefetcher_propagates_errors():
    def gen():
        yield 1
        raise RuntimeError("boom")

    p = Prefetcher(gen(), depth=2)
    assert next(p) == 1
    with pytest.raises(RuntimeError, match="boom"):
        next(p)


def test_prefetcher_overlap():
    import time

    def slow():
        for i in range(4):
            time.sleep(0.02)
            yield i

    p = Prefetcher(slow(), depth=2)
    time.sleep(0.1)                     # producer runs ahead while we wait
    t0 = time.perf_counter()
    out = list(p)
    elapsed = time.perf_counter() - t0
    assert out == [0, 1, 2, 3]
    assert elapsed < 0.06               # most items were already buffered


def test_prefetcher_close_unblocks_producer():
    """An abandoned iterator must not park its thread on queue.put
    forever: close() wakes the producer, joins it, and closes the
    source generator."""
    import itertools

    closed = []

    def endless():
        try:
            for i in itertools.count():
                yield i
        finally:
            closed.append(True)

    p = Prefetcher(endless(), depth=1)
    assert next(p) == 0                 # producer alive and parked on put
    p.close()
    assert not p._thread.is_alive()
    assert closed == [True]
    with pytest.raises(StopIteration):  # closed iterator is exhausted
        next(p)
    p.close()                           # idempotent


def test_prefetcher_context_manager():
    def gen():
        while True:
            yield 1

    with Prefetcher(gen(), depth=1) as p:
        assert next(p) == 1
        thread = p._thread
    assert not thread.is_alive()
