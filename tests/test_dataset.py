"""Dataset API: discovery, pruning, and placement-equivalence.

The paper's core claim is behavioural: switching ParquetFormat ->
PushdownParquetFormat changes *where* the scan runs, never *what* it
returns.  These tests pin that equivalence across all three layouts, plus
the pruning, queue-depth, metrics, and failover behaviour.
"""

import numpy as np
import pytest

from repro.aformat.expressions import field
from repro.core import (ParquetFormat, PushdownParquetFormat, dataset,
                        make_cluster, write_flat, write_split, write_striped)

WRITERS = {"flat": write_flat, "striped": write_striped,
           "split": write_split}


@pytest.fixture(params=["flat", "striped", "split"])
def populated(request, taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        part = taxi_table.slice(i * 5000, 5000)
        WRITERS[request.param](fs, f"/d/part{i}.arw", part,
                               row_group_rows=1024)
    return fs, taxi_table, request.param


def _expected(tbl, mask, cols):
    return tbl.filter(mask).select(cols)


def test_discovery(populated):
    fs, tbl, layout = populated
    ds = dataset(fs, "/d")
    assert ds.layout == layout
    assert ds.num_rows == len(tbl)
    assert len(ds.fragments()) == 4 * (5000 // 1024 + 1)


@pytest.mark.parametrize("fmt", ["parquet", "pushdown"])
def test_scan_equivalence(populated, fmt):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    pred = (field("fare_amount") > 25.0) & (field("passenger_count") >= 4)
    mask = ((tbl.column("fare_amount").values > 25.0)
            & (tbl.column("passenger_count").values >= 4))
    out = ds.scanner(format=fmt, columns=["trip_id", "fare_amount"],
                     predicate=pred, num_threads=4).to_table()
    exp = _expected(tbl, mask, ["trip_id", "fare_amount"])
    # row order may differ across parallel scans: sort by key
    o = np.argsort(out.column("trip_id").values)
    e = np.argsort(exp.column("trip_id").values)
    assert np.array_equal(out.column("trip_id").values[o],
                          exp.column("trip_id").values[e])
    assert np.allclose(out.column("fare_amount").values[o],
                       exp.column("fare_amount").values[e])


def test_both_placements_agree(populated):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    pred = field("payment_type") == "cash"
    a = ds.scanner(format="parquet", predicate=pred,
                   num_threads=2).to_table()
    b = ds.scanner(format="pushdown", predicate=pred,
                   num_threads=2).to_table()
    ka = np.sort(a.column("trip_id").values)
    kb = np.sort(b.column("trip_id").values)
    assert np.array_equal(ka, kb)


def test_pruning_skips_row_groups(populated):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    # trip_id is monotonically increasing: a range predicate must prune
    pred = field("trip_id") < 1024
    sc = ds.scanner(format="pushdown", predicate=pred)
    out = sc.to_table()
    assert len(out) == 1024
    assert sc.metrics.fragments_pruned > 0
    assert sc.metrics.fragments_pruned + len(sc.metrics.tasks) == \
        sc.metrics.fragments_total


def test_pushdown_moves_cpu_to_storage(populated):
    # numeric projection: the paper's workload (their taxi table is numeric;
    # our simulated IPC string decode is a Python loop, which would blur the
    # client-idle claim that real zero-copy Arrow IPC provides).
    # min-of-3: wall-clock-derived CPU accounting is noisy on a loaded
    # 1-core CI host.
    cols = ["trip_id", "fare_amount", "passenger_count"]
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")

    def run(fmt):
        best = None
        for _ in range(3):
            sc = ds.scanner(format=fmt, columns=cols, num_threads=2)
            sc.to_table()
            if best is None or sc.metrics.client_cpu_s < \
                    best.metrics.client_cpu_s:
                best = sc
        return best

    sc_c = run("parquet")
    sc_p = run("pushdown")
    # client path: all CPU on client, none on OSDs
    assert sc_c.metrics.osd_cpu_s == 0
    assert sc_c.metrics.client_cpu_s > 0
    # pushdown: decode CPU on OSDs, client does only IPC materialize
    assert sc_p.metrics.osd_cpu_s > 0
    assert sc_p.metrics.client_cpu_s < sc_c.metrics.client_cpu_s


def test_pushdown_wire_is_larger_at_full_selectivity(populated):
    """Paper Fig. 5, 100% case: Arrow IPC on the wire > compressed ARW1."""
    fs, tbl, layout = populated
    ds = dataset(fs, "/d")
    sc_c = ds.scanner(format="parquet", num_threads=2)
    sc_c.to_table()
    sc_p = ds.scanner(format="pushdown", num_threads=2)
    sc_p.to_table()
    assert sc_p.metrics.wire_bytes > sc_c.metrics.wire_bytes


def test_scan_survives_osd_failure(populated):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    fs.store.fail_osd(fs.store.osds[0].osd_id)
    fs.store.fail_osd(fs.store.osds[3].osd_id)
    out = ds.scanner(format="pushdown", num_threads=4).to_table()
    assert len(out) == len(tbl)               # replicas served everything


def test_empty_result_schema(populated):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    out = ds.scanner(format="pushdown", columns=["trip_id"],
                     predicate=field("fare_amount") < -5).to_table()
    assert len(out) == 0
    assert out.schema.names == ["trip_id"]


def test_count_pushdown_matches_scan(populated):
    """COUNT(*) via rowcount_op must equal the materializing count, ship
    only integers, and use metadata-only counts where stats prove ALL."""
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    pred = field("fare_amount") > 25.0
    exp = int((tbl.column("fare_amount").values > 25.0).sum())

    sc = ds.scanner(format="pushdown", predicate=pred)
    got = sc.count_rows()
    assert got == exp
    # only tiny integer payloads crossed the wire
    assert all(t.wire_bytes < 64 for t in sc.metrics.tasks)

    # unfiltered count: pure metadata, zero storage calls
    sc2 = ds.scanner(format="pushdown")
    assert sc2.count_rows() == len(tbl)
    assert not sc2.metrics.tasks

    # range predicate on the monotone column: mix of pruned / ALL / edge
    sc3 = ds.scanner(format="pushdown", predicate=field("trip_id") < 3000)
    assert sc3.count_rows() == 3000
    assert sc3.metrics.fragments_pruned > 0
    # client format falls back to a materializing count
    sc4 = ds.scanner(format="parquet", predicate=pred)
    assert sc4.count_rows() == exp


def test_projection_only(populated):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    out = ds.scanner(format="pushdown",
                     columns=["payment_type"]).to_table()
    assert out.schema.names == ["payment_type"]
    assert len(out) == len(tbl)
